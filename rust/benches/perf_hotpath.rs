//! Bench: §Perf hot-path microbenchmarks (not a paper artifact).
//!
//! Measures the latency/throughput of every component on the request
//! path, per the performance deliverable:
//!
//! * L3: sim-engine step rate, fair-share allocation, scheduler ops,
//!   recorder hot path;
//! * runtime: per-call latency of each XLA artifact (the optimizer
//!   executes `throughput_window` + one controller step per probe —
//!   these must be ≪ the 3–5 s probing interval);
//! * end-to-end: simulated seconds per wall second on the heaviest
//!   scenario (fabric-c, 1 TB aggregate).

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use fastbiodl::coordinator::scheduler::{ChunkScheduler, SchedulerMode};
use fastbiodl::experiments::runner::{run_tool_once, Tool};
use fastbiodl::experiments::scenario;
use fastbiodl::metrics::recorder::ThroughputRecorder;
use fastbiodl::netsim::link::max_min_fair;

fn bench_loop(name: &str, iters: usize, mut f: impl FnMut()) -> f64 {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let unit = if per < 1e-6 {
        format!("{:.0} ns", per * 1e9)
    } else if per < 1e-3 {
        format!("{:.2} µs", per * 1e6)
    } else {
        format!("{:.3} ms", per * 1e3)
    };
    println!("  {name:<44} {unit:>12}/iter  ({iters} iters)");
    per
}

fn main() {
    common::banner(
        "§Perf hot-path microbenchmarks",
        "controller step ≪ probing interval; sim ≫ real time",
    );
    let rt = common::runtime();

    println!("[runtime] XLA artifact call latency:");
    let c = vec![1.0f32; 16];
    let t = vec![500.0f32; 16];
    let w = vec![1.0f32; 16];
    let params = [1.02f32, 3.0, 4.0, 1.0, 64.0, 4.0, 0.0, 0.0];
    let gd_per = bench_loop("gd_step (L1 utility+slope kernels)", 2000, || {
        rt.gd_step(&c, &t, &w, &params).unwrap();
    });
    let grid: Vec<f32> = (1..=64).map(|i| i as f32).collect();
    let bparams = [1.02f32, 4.0, 1e-3, 0.01, 1.0, 32.0, 500.0, 0.0];
    bench_loop("bayes_step (L1 RBF + Cholesky)", 500, || {
        rt.bayes_step(&c, &t, &w, &grid, &bparams).unwrap();
    });
    let samples = vec![100.0f32; 256];
    let valid = vec![1.0f32; 256];
    let weights = vec![1.0f32; 256];
    bench_loop("throughput_window (L1 reduction)", 2000, || {
        rt.throughput_window(&samples, &valid, &weights).unwrap();
    });
    let tg: Vec<f32> = (0..64).map(|i| 10.0 * i as f32).collect();
    bench_loop("utility_surface 64x64 (L1 2-D tiles)", 500, || {
        rt.utility_surface(&tg, &grid, 1.02).unwrap();
    });
    println!(
        "  -> probe-interval budget used by one GD probe: {:.4}% of 5 s",
        gd_per / 5.0 * 100.0
    );

    println!("\n[L3] coordinator primitives:");
    let demands: Vec<f64> = (0..32).map(|i| 100.0 + 13.0 * i as f64).collect();
    bench_loop("max_min_fair (32 flows)", 200_000, || {
        std::hint::black_box(max_min_fair(2_000.0, &demands));
    });
    let recorder = ThroughputRecorder::new();
    bench_loop("recorder.add_bytes (worker hot path)", 1_000_000, || {
        recorder.add_bytes(4096);
    });
    let records: Vec<fastbiodl::accession::RunRecord> = (0..64)
        .map(|i| {
            fastbiodl::accession::RunRecord::new(format!("SRR{i:07}"), "P", 1 << 30, "sim://x")
        })
        .collect();
    bench_loop("scheduler next_chunk+done (32 MiB chunks)", 50_000, || {
        let mut s = ChunkScheduler::new(
            &records[..1],
            SchedulerMode::Chunked {
                chunk_bytes: 32 << 20,
                max_open_files: 4,
            },
        );
        while let Some(chk) = s.next_chunk() {
            s.chunk_done(&chk);
        }
    });

    println!("\n[L3] sim-engine raw step rate (20 active flows, post-optimization):");
    {
        use fastbiodl::netsim::engine::{BackgroundConfig, NetSim, NetSimConfig};
        use fastbiodl::netsim::{ClientProfile, ServerProfile};
        let cfg = NetSimConfig {
            link_capacity_mbps: 20_000.0,
            background: BackgroundConfig {
                mean_mbps: 400.0,
                theta: 0.3,
                sigma: 100.0,
                max_mbps: 1_000.0,
            },
            server: ServerProfile {
                setup_latency_s: 0.1,
                first_byte_latency_s: 0.0,
                per_conn_cap_mbps: 1_400.0,
                long_request_decay_per_min: 0.1,
                decay_floor: 0.5,
                max_connections: 64,
            },
            client: ClientProfile::default(),
            flow_jitter_frac: 0.05,
            flow_failure_rate_per_min: 0.0,
            faults: fastbiodl::netsim::FaultSchedule::none(),
            dt_s: 0.05,
        };
        let mut sim = NetSim::new(cfg, 1).unwrap();
        let ids: Vec<_> = (0..20).map(|_| sim.open_flow().unwrap()).collect();
        for _ in 0..100 {
            sim.step(None);
        }
        for (i, id) in ids.iter().enumerate() {
            sim.begin_request(*id, 1e15, false, i as u64).unwrap();
        }
        for _ in 0..10_000 {
            sim.step(None);
        }
        let n = 500_000usize;
        let t0 = Instant::now();
        for _ in 0..n {
            std::hint::black_box(sim.step(None));
        }
        let per = t0.elapsed().as_secs_f64() / n as f64;
        println!(
            "  step: {:.0} ns ({:.0}x real time at dt=50ms)  [§Perf: 514 ns before optimization]",
            per * 1e9,
            0.05 / per
        );
    }

    println!("\n[end-to-end] heaviest scenario (fabric-c, 1 TB):");
    let s = scenario::fabric('c', 1).expect("scenario");
    let t0 = Instant::now();
    let report = run_tool_once(&s, &Tool::fastbiodl(&s), &rt, 99).expect("run");
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "  simulated {:.0}s of 20 Gbps transfer in {:.2}s wall -> {:.0}x real time",
        report.duration_s,
        wall,
        report.duration_s / wall
    );
    println!("  mean {:.0} Mbps, C̄={:.1}", report.mean_throughput_mbps, report.mean_concurrency);

    let shape = if report.duration_s / wall > 20.0 {
        Ok(())
    } else {
        Err(format!(
            "sim engine only {:.1}x real time (target ≥20x)",
            report.duration_s / wall
        ))
    };
    common::finish("perf_hotpath", shape);
}
