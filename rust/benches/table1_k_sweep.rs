//! Bench: regenerate **Table 1** — the penalty-coefficient sweep.
//!
//! Paper rows: k ∈ {1.01, 1.02, 1.05} → speed {701.2, 815.8, 743.9}
//! Mbps, concurrency {6.77, 6.23, 4.64}; k = 1.02 selected.

#[path = "common/mod.rs"]
mod common;

use fastbiodl::experiments::table1;
use fastbiodl::report::{write_series_csv, Table};

fn main() {
    common::banner(
        "Table 1 (penalty coefficient k)",
        "k=1.02 fastest; k=1.01 over-aggressive (more threads, less speed); \
         k=1.05 conservative (fewest threads)",
    );
    let rt = common::runtime();
    let runs = common::bench_runs();
    let (rows, wall) = common::timed(|| {
        table1::run(&rt, runs, common::SEED_BASE).expect("table1 failed")
    });

    let mut t = Table::new(vec!["K", "Avg Download Speed (Mbps)", "Avg Concurrency"]);
    for r in &rows {
        t.row(vec![
            format!("{:.2}", r.k),
            r.summary.speed_mbps.to_string(),
            r.summary.concurrency.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("paper:   1.01 -> 701.2 Mbps @ C 6.77");
    println!("paper:   1.02 -> 815.8 Mbps @ C 6.23   <- selected");
    println!("paper:   1.05 -> 743.9 Mbps @ C 4.64");

    let sim_s: f64 = rows
        .iter()
        .map(|r| r.summary.duration_s.mean * r.summary.reports.len() as f64)
        .sum();
    write_series_csv(
        "table1_k_sweep",
        &["k", "speed_mbps", "speed_std", "concurrency", "concurrency_std"],
        rows.iter().map(|r| {
            vec![
                r.k,
                r.summary.speed_mbps.mean,
                r.summary.speed_mbps.std,
                r.summary.concurrency.mean,
                r.summary.concurrency.std,
            ]
        }),
    )
    .expect("csv");
    common::report_wall("table1", wall, sim_s);
    common::finish("table1", table1::check_shape(&rows));
}
