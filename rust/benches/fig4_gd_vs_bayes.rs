//! Bench: regenerate **Figure 4** — gradient descent vs Bayesian
//! optimization as the concurrency controller.
//!
//! Paper: BO's surrogate never stabilizes under drifting conditions;
//! total copy time ends ≈20 % behind gradient descent (average of 5).

#[path = "common/mod.rs"]
mod common;

use fastbiodl::experiments::fig4::{self, Fig4Result};
use fastbiodl::report::{write_series_csv, Table};

fn main() {
    common::banner(
        "Figure 4 (gradient descent vs Bayesian optimization)",
        "GD's small local moves beat BO's surrogate-driven jumps by ~20% \
         total copy time; BO's concurrency trace shows large swings",
    );
    let rt = common::runtime();
    let runs = common::bench_runs();
    let (r, wall) =
        common::timed(|| fig4::run(&rt, runs, common::SEED_BASE).expect("fig4 failed"));

    let mut t = Table::new(vec![
        "Optimizer",
        "Copy time (s)",
        "Speed (Mbps)",
        "Concurrency",
        "ΣΔC (movement)",
    ]);
    for (s, label) in [(&r.gd, "gradient-descent"), (&r.bayes, "bayesian")] {
        t.row(vec![
            label.to_string(),
            s.duration_s.to_string(),
            s.speed_mbps.to_string(),
            s.concurrency.to_string(),
            format!("{:.1}", Fig4Result::movement(s)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Bayesian slowdown: {:.1}%  (paper ≈20%)",
        (r.bayes_slowdown() - 1.0) * 100.0
    );

    // Per-second mean timelines for the figure.
    let gd_tl = &r.gd.reports[0].timeline.values;
    let bo_tl = &r.bayes.reports[0].timeline.values;
    let horizon = gd_tl.len().max(bo_tl.len());
    write_series_csv(
        "fig4_gd_vs_bayes",
        &["t_s", "gd_mbps", "bayes_mbps"],
        (0..horizon).map(|i| {
            vec![
                i as f64,
                gd_tl.get(i).copied().unwrap_or(0.0),
                bo_tl.get(i).copied().unwrap_or(0.0),
            ]
        }),
    )
    .expect("csv");

    let sim_s = (r.gd.duration_s.mean + r.bayes.duration_s.mean) * runs as f64;
    common::report_wall("fig4", wall, sim_s);
    common::finish("fig4", fig4::check_shape(&r));
}
