//! Bench: regenerate **Table 3** (and Table 2's dataset inventory) —
//! FastBioDL vs prefetch vs pysradb on the three public BioProjects.
//!
//! Paper: FastBioDL ≈1.9×/1.3× (Breast), ≈2.4×/2.7× (HiFi), ≈4×/4×
//! (Amplicon) over prefetch/pysradb.

#[path = "common/mod.rs"]
mod common;

use fastbiodl::accession::datasets::TABLE2_PRESETS;
use fastbiodl::experiments::table3;
use fastbiodl::report::{write_series_csv, Table};

fn main() {
    common::banner(
        "Table 3 (comparison with state-of-the-art)",
        "FastBioDL wins on all three datasets; baselines tie on Amplicon; \
         pysradb beats prefetch on Breast but not on HiFi",
    );

    println!("Table 2 — evaluation datasets (regenerated):");
    for p in &TABLE2_PRESETS {
        println!("  {}", p.describe());
    }
    println!();

    let rt = common::runtime();
    let runs = common::bench_runs();
    let (rows, wall) = common::timed(|| {
        table3::run(&rt, runs, common::SEED_BASE).expect("table3 failed")
    });

    let mut t = Table::new(vec!["Dataset", "Tool", "Concurrency", "Speed (Mbps)"]);
    for r in &rows {
        for s in [&r.prefetch, &r.pysradb, &r.fastbiodl] {
            t.row(vec![
                r.dataset.to_string(),
                s.tool.clone(),
                s.concurrency.to_string(),
                s.speed_mbps.to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    println!("speedups (FastBioDL vs baselines):");
    for r in &rows {
        println!(
            "  {:<18} vs prefetch {:.2}x   vs pysradb {:.2}x   (paper: {})",
            r.dataset,
            r.speedup_vs(&r.prefetch),
            r.speedup_vs(&r.pysradb),
            match r.dataset {
                "Breast-RNA-seq" => "1.9x / 1.3x",
                "HiFi-WGS" => "2.4x / 2.7x",
                _ => "4.0x / 4.0x",
            }
        );
    }

    let sim_s: f64 = rows
        .iter()
        .flat_map(|r| [&r.prefetch, &r.pysradb, &r.fastbiodl])
        .map(|s| s.duration_s.mean * s.reports.len() as f64)
        .sum();
    write_series_csv(
        "table3_sota",
        &[
            "dataset_idx",
            "tool_idx",
            "concurrency",
            "concurrency_std",
            "speed_mbps",
            "speed_std",
        ],
        rows.iter().enumerate().flat_map(|(di, r)| {
            [&r.prefetch, &r.pysradb, &r.fastbiodl]
                .into_iter()
                .enumerate()
                .map(move |(ti, s)| {
                    vec![
                        di as f64,
                        ti as f64,
                        s.concurrency.mean,
                        s.concurrency.std,
                        s.speed_mbps.mean,
                        s.speed_mbps.std,
                    ]
                })
                .collect::<Vec<_>>()
        }),
    )
    .expect("csv");
    common::report_wall("table3", wall, sim_s);
    common::finish("table3", table3::check_shape(&rows));
}
