//! Bench: regenerate **Figure 1** — single-threaded downloads
//! underutilize the network.

#[path = "common/mod.rs"]
mod common;

use fastbiodl::experiments::fig1;
use fastbiodl::report::{sparkline, write_series_csv};

fn main() {
    common::banner(
        "Figure 1 (single-stream underutilization)",
        "a single-threaded FTP/HTTP download uses a small fraction of the \
         bandwidth iperf3 reports available",
    );
    let duration = 120.0;
    let (r, wall) = common::timed(|| fig1::run(duration, common::SEED_BASE).expect("fig1"));

    println!("available  {}", sparkline(&r.available_mbps, 72));
    println!("single     {}", sparkline(&r.single_stream_mbps, 72));
    println!();
    println!("mean available bandwidth : {:>8.1} Mbps", r.mean_available);
    println!("mean single-stream       : {:>8.1} Mbps", r.mean_single);
    println!(
        "utilization              : {:>8.1} %  (the Figure 1 gap)",
        r.utilization() * 100.0
    );

    write_series_csv(
        "fig1_single_stream",
        &["t_s", "single_stream_mbps", "available_mbps"],
        r.t_s
            .iter()
            .zip(&r.single_stream_mbps)
            .zip(&r.available_mbps)
            .map(|((t, s), a)| vec![*t, *s, *a]),
    )
    .expect("csv");

    common::report_wall("fig1", wall, duration);
    let shape = if r.utilization() < 0.35 {
        Ok(())
    } else {
        Err(format!(
            "single stream used {:.0}% of available — not underutilized",
            r.utilization() * 100.0
        ))
    };
    common::finish("fig1", shape);
}
