//! Bench: regenerate **Figure 5** — per-second throughput with 68 %
//! confidence bands for the three tools on Breast-RNA-seq.
//!
//! Paper: FastBioDL peaks ≈1800 Mbps (vs ≈1400), completes at ≈160 s —
//! 38 % / 43 % faster than pysradb / prefetch.

#[path = "common/mod.rs"]
mod common;

use fastbiodl::experiments::fig5;
use fastbiodl::report::{sparkline, write_series_csv, Table};

fn main() {
    common::banner(
        "Figure 5 (throughput timelines + 68% CI, Breast-RNA-seq)",
        "FastBioDL sustains the highest per-second throughput and finishes \
         38%/43% sooner than pysradb/prefetch",
    );
    let rt = common::runtime();
    let runs = common::bench_runs();
    let (r, wall) =
        common::timed(|| fig5::run(&rt, runs, common::SEED_BASE).expect("fig5 failed"));

    for band in [&r.fastbiodl, &r.prefetch, &r.pysradb] {
        println!("{:<10} {}", band.tool, sparkline(&band.mean, 64));
    }
    println!();
    let mut t = Table::new(vec!["Tool", "Peak (Mbps)", "Completion (s)", "Speed (Mbps)"]);
    for band in [&r.fastbiodl, &r.pysradb, &r.prefetch] {
        t.row(vec![
            band.tool.clone(),
            format!("{:.0}", band.peak()),
            band.summary.duration_s.to_string(),
            band.summary.speed_mbps.to_string(),
        ]);
    }
    println!("{}", t.render());
    let f = r.fastbiodl.completion_s();
    println!(
        "completion advantage: {:.0}% vs pysradb (paper 38%), {:.0}% vs prefetch (paper 43%)",
        (1.0 - f / r.pysradb.completion_s()) * 100.0,
        (1.0 - f / r.prefetch.completion_s()) * 100.0,
    );

    // CSV: per-second mean + band for each tool.
    let horizon = [&r.fastbiodl, &r.prefetch, &r.pysradb]
        .iter()
        .map(|b| b.mean.len())
        .max()
        .unwrap();
    let get = |v: &Vec<f64>, i: usize| v.get(i).copied().unwrap_or(0.0);
    write_series_csv(
        "fig5_throughput_timeline",
        &[
            "t_s",
            "fastbiodl_mean", "fastbiodl_lo", "fastbiodl_hi",
            "prefetch_mean", "prefetch_lo", "prefetch_hi",
            "pysradb_mean", "pysradb_lo", "pysradb_hi",
        ],
        (0..horizon).map(|i| {
            vec![
                i as f64,
                get(&r.fastbiodl.mean, i), get(&r.fastbiodl.lo, i), get(&r.fastbiodl.hi, i),
                get(&r.prefetch.mean, i), get(&r.prefetch.lo, i), get(&r.prefetch.hi, i),
                get(&r.pysradb.mean, i), get(&r.pysradb.lo, i), get(&r.pysradb.hi, i),
            ]
        }),
    )
    .expect("csv");

    let sim_s = [&r.fastbiodl, &r.prefetch, &r.pysradb]
        .iter()
        .map(|b| b.summary.duration_s.mean * runs as f64)
        .sum();
    common::report_wall("fig5", wall, sim_s);
    common::finish("fig5", fig5::check_shape(&r));
}
