//! Shared helpers for the bench harnesses.
//!
//! criterion is unavailable offline, so every bench is a plain
//! `harness = false` binary built on these helpers: deterministic
//! multi-run experiment execution, paper-style table printing, CSV
//! emission under `results/`, and simple wall-clock timing.

// Each bench binary uses a subset of these helpers.
#![allow(dead_code)]

use std::sync::Arc;
use std::time::Instant;

use fastbiodl::runtime::{SharedRuntime, XlaRuntime};

/// Number of runs per configuration (paper: 5 round-robin runs).
/// Override with `FASTBIODL_BENCH_RUNS` for quick iterations.
pub fn bench_runs() -> usize {
    std::env::var("FASTBIODL_BENCH_RUNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5)
}

/// Base seed for the round-robin (fixed for reproducibility).
pub const SEED_BASE: u64 = 1000;

/// Load the XLA runtime once.
pub fn runtime() -> SharedRuntime {
    Arc::new(XlaRuntime::load_default().expect(
        "artifacts missing — run `make artifacts` before `cargo bench`",
    ))
}

/// Print the bench banner.
pub fn banner(id: &str, paper_claim: &str) {
    println!("\n================================================================");
    println!("reproducing {id}");
    println!("paper claim: {paper_claim}");
    println!("================================================================");
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Report the wall cost of regenerating the artifact (the "bench" part
/// of a paper-figure bench: how fast the harness replays the paper).
pub fn report_wall(id: &str, wall_s: f64, sim_seconds: f64) {
    if sim_seconds > 0.0 {
        println!(
            "\n[bench] {id}: regenerated in {wall_s:.2}s wall ({:.0}x real time)",
            sim_seconds / wall_s
        );
    } else {
        println!("\n[bench] {id}: regenerated in {wall_s:.2}s wall");
    }
}

/// Shape-check outcome printer: benches never panic on shape drift —
/// they report PASS/FAIL and exit nonzero so CI notices.
pub fn finish(id: &str, shape: Result<(), String>) {
    match shape {
        Ok(()) => println!("[shape] {id}: PASS — paper-shape assertions hold"),
        Err(e) => {
            println!("[shape] {id}: FAIL — {e}");
            std::process::exit(1);
        }
    }
}
