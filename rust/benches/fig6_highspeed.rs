//! Bench: regenerate **Figure 6** — adaptive vs fixed concurrency on
//! high-speed (FABRIC-like) networks.
//!
//! Paper: (a) 10 Gbps/500 Mbps-thread, C*=20 — adaptive 44%/67% faster
//! than fixed-5/3; (b) 10 Gbps/1400, C*≈7 — adaptive ≈9300 vs ≈7300
//! Mbps for fixed-5; (c) 20 Gbps/1400, C*≈14.3 — adaptive ≈14 threads,
//! 1.3×/2.1× over fixed-5/3.

#[path = "common/mod.rs"]
mod common;

use fastbiodl::experiments::fig6;
use fastbiodl::report::{write_series_csv, Table};

fn main() {
    common::banner(
        "Figure 6 (adaptive vs fixed on high-speed networks)",
        "adaptive converges near C* = link/per-thread-cap and beats fixed \
         3/5 by 1.3–2.1x; gaps grow with available headroom",
    );
    let rt = common::runtime();
    let runs = common::bench_runs();
    let (rows, wall) =
        common::timed(|| fig6::run(&rt, runs, common::SEED_BASE).expect("fig6 failed"));

    let mut t = Table::new(vec![
        "Scenario", "C*", "Arm", "Speed (Mbps)", "Duration (s)", "Concurrency",
    ]);
    for r in &rows {
        for (arm, s) in [
            ("adaptive", &r.adaptive),
            ("fixed-5", &r.fixed5),
            ("fixed-3", &r.fixed3),
        ] {
            t.row(vec![
                r.scenario.to_string(),
                format!("{:.1}", r.c_star),
                arm.to_string(),
                s.speed_mbps.to_string(),
                s.duration_s.to_string(),
                s.concurrency.to_string(),
            ]);
        }
    }
    println!("{}", t.render());
    for r in &rows {
        println!(
            "  {:<9} adaptive vs fixed-5: {:.2}x   vs fixed-3: {:.2}x",
            r.scenario,
            r.speedup_vs_fixed5(),
            r.speedup_vs_fixed3()
        );
    }
    println!("  paper:    (a) 1.44x/1.67x   (b) small/—   (c) 1.3x/2.1x");

    // CSV: timelines of run 0 for each scenario/arm.
    for r in &rows {
        let a = &r.adaptive.reports[0].timeline.values;
        let f5 = &r.fixed5.reports[0].timeline.values;
        let f3 = &r.fixed3.reports[0].timeline.values;
        let horizon = a.len().max(f5.len()).max(f3.len());
        let get = |v: &Vec<f64>, i: usize| v.get(i).copied().unwrap_or(0.0);
        write_series_csv(
            &format!("fig6_{}", r.scenario),
            &["t_s", "adaptive_mbps", "fixed5_mbps", "fixed3_mbps"],
            (0..horizon).map(|i| vec![i as f64, get(a, i), get(f5, i), get(f3, i)]),
        )
        .expect("csv");
    }

    let sim_s: f64 = rows
        .iter()
        .flat_map(|r| [&r.adaptive, &r.fixed5, &r.fixed3])
        .map(|s| s.duration_s.mean * runs as f64)
        .sum();
    common::report_wall("fig6", wall, sim_s);
    common::finish("fig6", fig6::check_shape(&rows));
}
