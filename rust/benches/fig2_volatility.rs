//! Bench: regenerate **Figure 2** — available bandwidth is volatile on
//! probe timescales (the motivation for adaptive concurrency).

#[path = "common/mod.rs"]
mod common;

use fastbiodl::experiments::fig2;
use fastbiodl::report::{sparkline, write_series_csv};

fn main() {
    common::banner(
        "Figure 2 (bandwidth volatility over two minutes)",
        "iperf3-measured available bandwidth moves substantially within \
         seconds; any static concurrency is suboptimal most of the time",
    );
    let duration = 120.0;
    let (r, wall) = common::timed(|| fig2::run(duration, common::SEED_BASE).expect("fig2"));

    println!("available  {}", sparkline(&r.available_mbps, 72));
    println!();
    println!("mean  : {:>8.1} Mbps", r.mean);
    println!("std   : {:>8.1} Mbps  (cv {:.1} %)", r.std, r.cv() * 100.0);
    println!("range : {:>8.1} – {:.1} Mbps", r.min, r.max);

    write_series_csv(
        "fig2_volatility",
        &["t_s", "available_mbps"],
        r.t_s
            .iter()
            .zip(&r.available_mbps)
            .map(|(t, a)| vec![*t, *a]),
    )
    .expect("csv");

    common::report_wall("fig2", wall, duration);
    let shape = if r.cv() > 0.03 && (r.max - r.min) / r.mean > 0.15 {
        Ok(())
    } else {
        Err(format!(
            "trace too flat: cv {:.3}, relative range {:.3}",
            r.cv(),
            (r.max - r.min) / r.mean
        ))
    };
    common::finish("fig2", shape);
}
