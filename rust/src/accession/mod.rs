//! Accession handling: identifiers, the simulated repository catalog,
//! and URL resolution.
//!
//! A FastBioDL transfer starts from an *accession list* (paper §4): run
//! accessions (`SRR…`/`ERR…`/`DRR…`) or whole BioProjects (`PRJNA…`).
//! The real system resolves these against the ENA Portal API or NCBI
//! E-utilities; this reproduction resolves them against a deterministic
//! in-process catalog ([`catalog`]) whose three built-in projects are
//! the paper's Table 2 datasets, regenerated file-by-file with the
//! exact published counts, total sizes, and per-file ranges
//! ([`datasets`]).
//!
//! The resolver ([`resolver`]) also models the *cost* of resolution —
//! the paper's baselines resolve metadata per file at download time
//! (serialized, seconds each: the Amplicon-Digester killer), while
//! FastBioDL batch-resolves the whole list up front.

pub mod catalog;
pub mod datasets;
pub mod id;
pub mod resolver;

pub use catalog::{Catalog, RunRecord};
pub use datasets::{DatasetPreset, TABLE2_PRESETS};
pub use id::Accession;
pub use resolver::{ResolutionCost, Resolver};
