//! URL resolution and its *cost model*.
//!
//! Real tools differ not only in download concurrency but in **when and
//! how they resolve accessions to URLs**:
//!
//! * `prefetch`/`pysradb` resolve each run at download time through the
//!   SRA name-resolution service — one serialized metadata round trip
//!   per file (observed seconds each on public endpoints). On workloads
//!   of many small files this dominates wall time and is why both
//!   baselines report nearly identical ≈29 Mbps on Amplicon-Digester
//!   (Table 3): they serialize on the same resolution path.
//! * FastBioDL reads the accession list up front and batch-resolves it
//!   with one ENA Portal API query (paper Figure 3), paying one
//!   round-trip for the whole list.
//!
//! [`ResolutionCost`] captures those two shapes; the session drivers
//! charge the cost in virtual (or real) time accordingly.

use crate::accession::catalog::{Catalog, RunRecord};
use crate::accession::id::Accession;
use crate::Result;

/// How a tool pays for metadata resolution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ResolutionCost {
    /// One API round trip for the entire list (FastBioDL).
    Batch {
        /// Total latency for the single query (s).
        latency_s: f64,
    },
    /// One serialized round trip per file at download time
    /// (prefetch / pysradb): a global metadata lock, per-file latency.
    PerFileSerialized {
        /// Latency per file (s).
        latency_s: f64,
    },
}

impl ResolutionCost {
    /// Up-front delay before any download starts.
    pub fn upfront_latency(&self, _n_files: usize) -> f64 {
        match self {
            ResolutionCost::Batch { latency_s } => *latency_s,
            ResolutionCost::PerFileSerialized { .. } => 0.0,
        }
    }

    /// Serialized per-file delay charged when a worker picks up a new
    /// file (zero for batch resolution).
    pub fn per_file_latency(&self) -> f64 {
        match self {
            ResolutionCost::Batch { .. } => 0.0,
            ResolutionCost::PerFileSerialized { latency_s } => *latency_s,
        }
    }
}

/// Resolves accession lists against a catalog, with a cost model.
pub struct Resolver<'a> {
    catalog: &'a Catalog,
    cost: ResolutionCost,
}

impl<'a> Resolver<'a> {
    pub fn new(catalog: &'a Catalog, cost: ResolutionCost) -> Self {
        Resolver { catalog, cost }
    }

    /// FastBioDL's resolver: one batch ENA Portal query.
    pub fn batch(catalog: &'a Catalog) -> Self {
        Resolver::new(catalog, ResolutionCost::Batch { latency_s: 1.5 })
    }

    /// Resolve a list to run records. The *time* cost is returned to
    /// the caller (virtual-time drivers charge it to their clock; the
    /// real driver has actually waited by then). Records come back with
    /// their full ordered mirror lists (ENA primary + NCBI fallback for
    /// the built-in presets) so the session engine can schedule across
    /// mirrors without a second resolution round trip.
    pub fn resolve(&self, accessions: &[Accession]) -> Result<(Vec<RunRecord>, f64)> {
        let records = self.catalog.expand(accessions)?;
        let upfront = self.cost.upfront_latency(records.len());
        Ok((records, upfront))
    }

    pub fn cost(&self) -> ResolutionCost {
        self.cost
    }
}

/// Largest mirror count across a resolved record list — the width of
/// the mirror health board a session allocates.
pub fn mirror_width(records: &[RunRecord]) -> usize {
    records
        .iter()
        .map(RunRecord::mirror_count)
        .max()
        .unwrap_or(1)
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_pays_once() {
        let c = ResolutionCost::Batch { latency_s: 1.5 };
        assert_eq!(c.upfront_latency(43), 1.5);
        assert_eq!(c.per_file_latency(), 0.0);
    }

    #[test]
    fn serialized_pays_per_file() {
        let c = ResolutionCost::PerFileSerialized { latency_s: 8.0 };
        assert_eq!(c.upfront_latency(43), 0.0);
        assert_eq!(c.per_file_latency(), 8.0);
    }

    #[test]
    fn resolver_expands_project() {
        let cat = Catalog::with_table2(1);
        let r = Resolver::batch(&cat);
        let accs = vec![Accession::parse("PRJNA540705").unwrap()];
        let (recs, upfront) = r.resolve(&accs).unwrap();
        assert_eq!(recs.len(), 6);
        assert!(upfront > 0.0);
        // Built-in presets resolve with both archive mirrors attached.
        assert_eq!(mirror_width(&recs), 2);
        assert_eq!(mirror_width(&[]), 1);
    }
}
