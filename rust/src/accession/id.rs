//! Accession identifier parsing and classification.
//!
//! Grammar (the subset used by SRA/ENA):
//!
//! * run accessions: `SRR`, `ERR`, `DRR` + 6–9 digits (NCBI, EBI, DDBJ)
//! * experiment: `SRX`/`ERX`/`DRX` + digits (accepted, resolved to runs)
//! * BioProjects: `PRJNA`/`PRJEB`/`PRJDB` + digits
//!
//! Case-insensitive on input, normalized to upper-case.

use std::fmt;

use crate::{Error, Result};

/// A validated accession.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Accession {
    /// A single sequencing run (`SRR1234567`).
    Run(String),
    /// An experiment grouping runs (`SRX1234567`).
    Experiment(String),
    /// A BioProject (`PRJNA762469`).
    Project(String),
}

impl Accession {
    /// Parse and validate one accession string.
    pub fn parse(raw: &str) -> Result<Accession> {
        let s = raw.trim().to_ascii_uppercase();
        if s.is_empty() {
            return Err(Error::Accession("empty accession".into()));
        }
        let (kind, digits): (fn(String) -> Accession, &str) = if let Some(rest) =
            strip_any(&s, &["PRJNA", "PRJEB", "PRJDB"])
        {
            (Accession::Project, rest)
        } else if let Some(rest) = strip_any(&s, &["SRR", "ERR", "DRR"]) {
            (Accession::Run, rest)
        } else if let Some(rest) = strip_any(&s, &["SRX", "ERX", "DRX"]) {
            (Accession::Experiment, rest)
        } else {
            return Err(Error::Accession(format!(
                "unrecognized accession '{raw}' (expected SRR/ERR/DRR, SRX/ERX/DRX or PRJNA/PRJEB/PRJDB prefix)"
            )));
        };
        if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
            return Err(Error::Accession(format!(
                "accession '{raw}' must be <prefix><digits>"
            )));
        }
        if !(4..=12).contains(&digits.len()) {
            return Err(Error::Accession(format!(
                "accession '{raw}' has implausible digit count {}",
                digits.len()
            )));
        }
        Ok(kind(s))
    }

    /// Parse a whitespace/comma/newline-separated accession list (the
    /// input format of the paper's workflow, Figure 3).
    pub fn parse_list(text: &str) -> Result<Vec<Accession>> {
        let mut out = Vec::new();
        for line in text.lines() {
            // Everything after '#' on a line is a comment.
            let line = line.split('#').next().unwrap_or("");
            for token in line.split(|c: char| c.is_whitespace() || c == ',') {
                let token = token.trim();
                if token.is_empty() {
                    continue;
                }
                out.push(Accession::parse(token)?);
            }
        }
        if out.is_empty() {
            return Err(Error::Accession("accession list is empty".into()));
        }
        Ok(out)
    }

    /// The raw normalized string.
    pub fn as_str(&self) -> &str {
        match self {
            Accession::Run(s) | Accession::Experiment(s) | Accession::Project(s) => s,
        }
    }

    pub fn is_project(&self) -> bool {
        matches!(self, Accession::Project(_))
    }
}

impl fmt::Display for Accession {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

fn strip_any<'a>(s: &'a str, prefixes: &[&str]) -> Option<&'a str> {
    prefixes.iter().find_map(|p| s.strip_prefix(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_valid_accessions() {
        assert_eq!(
            Accession::parse("SRR1554534").unwrap(),
            Accession::Run("SRR1554534".into())
        );
        assert_eq!(
            Accession::parse("prjna762469").unwrap(),
            Accession::Project("PRJNA762469".into())
        );
        assert_eq!(
            Accession::parse("ERX123456").unwrap(),
            Accession::Experiment("ERX123456".into())
        );
    }

    #[test]
    fn rejects_malformed() {
        assert!(Accession::parse("").is_err());
        assert!(Accession::parse("SRR").is_err());
        assert!(Accession::parse("SRRabc").is_err());
        assert!(Accession::parse("XYZ123456").is_err());
        assert!(Accession::parse("SRR1234567890123").is_err());
    }

    #[test]
    fn list_parsing_with_comments() {
        let list = Accession::parse_list("SRR0000001, SRR0000002\n# comment\nPRJNA540705\n")
            .unwrap();
        assert_eq!(list.len(), 3);
        assert!(list[2].is_project());
    }

    #[test]
    fn empty_list_is_error() {
        assert!(Accession::parse_list("# nothing\n").is_err());
    }
}
