//! Table 2 dataset presets.
//!
//! The paper evaluates on three public BioProjects chosen to span file
//! sizes and I/O profiles (paper §5.1, Table 2):
//!
//! | Alias             | BioProject  | Files | Total    | Range           |
//! |-------------------|-------------|-------|----------|-----------------|
//! | Breast-RNA-seq    | PRJNA762469 | 10    | 22.06 GB | 1.72–3.03 GB    |
//! | HiFi-WGS          | PRJNA540705 | 6     | 56.15 GB | 8.10–10.81 GB   |
//! | Amplicon-Digester | PRJNA400087 | 43    | 1.91 GB  | 13.43–66.47 MB  |
//!
//! We cannot fetch the real runs offline, so [`DatasetPreset::generate`]
//! synthesizes a file-size population with the *exact* published count,
//! total, and min/max — the only properties the downloader observes.
//! Sizes are drawn deterministically (seeded), then affinely rescaled
//! inside the published range so the total matches to the byte.

use crate::util::prng::Prng;

/// GB/MB in the paper's tables are decimal units.
const GB: f64 = 1e9;
const MB: f64 = 1e6;

/// One evaluation dataset (a row of Table 2).
#[derive(Clone, Copy, Debug)]
pub struct DatasetPreset {
    /// Paper alias.
    pub alias: &'static str,
    /// BioProject accession.
    pub project: &'static str,
    /// Organism / sample type (documentation only).
    pub organism: &'static str,
    /// Number of runs taken.
    pub files: usize,
    /// Total size (bytes).
    pub total_bytes: u64,
    /// Per-file size range (bytes).
    pub min_bytes: u64,
    pub max_bytes: u64,
    /// Run-accession prefix for synthesized members.
    pub run_prefix: &'static str,
}

/// The three Table 2 presets.
pub const TABLE2_PRESETS: [DatasetPreset; 3] = [
    DatasetPreset {
        alias: "Breast-RNA-seq",
        project: "PRJNA762469",
        organism: "Homo sapiens (breast transcriptome)",
        files: 10,
        total_bytes: 22_060_000_000,
        min_bytes: 1_720_000_000,
        max_bytes: 3_030_000_000,
        run_prefix: "SRR157624",
    },
    DatasetPreset {
        alias: "HiFi-WGS",
        project: "PRJNA540705",
        organism: "Homo sapiens (PacBio long-read WGS)",
        files: 6,
        total_bytes: 56_150_000_000,
        min_bytes: 8_100_000_000,
        max_bytes: 10_810_000_000,
        run_prefix: "SRR902145",
    },
    DatasetPreset {
        alias: "Amplicon-Digester",
        project: "PRJNA400087",
        organism: "anaerobic digester metagenome",
        files: 43,
        total_bytes: 1_910_000_000,
        min_bytes: 13_430_000,
        max_bytes: 66_470_000,
        run_prefix: "SRR599871",
    },
];

impl DatasetPreset {
    /// Find a preset by alias (case-insensitive) or project id.
    pub fn find(name: &str) -> Option<&'static DatasetPreset> {
        TABLE2_PRESETS.iter().find(|p| {
            p.alias.eq_ignore_ascii_case(name) || p.project.eq_ignore_ascii_case(name)
        })
    }

    /// Synthesize the per-file sizes: `files` values inside
    /// `[min_bytes, max_bytes]` summing to exactly `total_bytes`.
    ///
    /// Deterministic in `seed`. The construction draws uniform sizes,
    /// then iteratively rescales deviations-from-mean so the sum and
    /// the range constraints hold simultaneously (both always *can*
    /// hold: the paper's mean lies inside the published range).
    pub fn generate(&self, seed: u64) -> Vec<u64> {
        let n = self.files;
        let total = self.total_bytes as f64;
        let lo = self.min_bytes as f64;
        let hi = self.max_bytes as f64;
        let mean = total / n as f64;
        assert!(
            lo <= mean && mean <= hi,
            "{}: published mean {mean} outside range [{lo}, {hi}]",
            self.alias
        );

        let mut rng = Prng::new(seed ^ 0xDA7A_5E7);
        let mut sizes: Vec<f64> = (0..n).map(|_| rng.range_f64(lo, hi)).collect();
        // Rescale deviations so the sum is exact, shrinking toward the
        // mean whenever a value would escape the range.
        for _ in 0..64 {
            let sum: f64 = sizes.iter().sum();
            let err = total - sum;
            if err.abs() < 1.0 {
                break;
            }
            let adj = err / n as f64;
            for s in sizes.iter_mut() {
                *s = (*s + adj).clamp(lo, hi);
            }
        }
        // Final exact fix-up on the slack-iest element.
        let sum: f64 = sizes.iter().sum();
        let err = total - sum;
        if err.abs() >= 1.0 {
            // Put the residue on the element with the most headroom.
            let idx = if err > 0.0 {
                sizes
                    .iter()
                    .enumerate()
                    .max_by(|a, b| (hi - a.1).total_cmp(&(hi - b.1)))
                    .map(|(i, _)| i)
                    .unwrap()
            } else {
                sizes
                    .iter()
                    .enumerate()
                    .max_by(|a, b| (a.1 - lo).total_cmp(&(b.1 - lo)))
                    .map(|(i, _)| i)
                    .unwrap()
            };
            sizes[idx] = (sizes[idx] + err).clamp(lo, hi);
        }
        sizes.iter().map(|&s| s.round() as u64).collect()
    }

    /// Mean file size (bytes).
    pub fn mean_bytes(&self) -> f64 {
        self.total_bytes as f64 / self.files as f64
    }

    /// Human description line (Table 2 row).
    pub fn describe(&self) -> String {
        format!(
            "{:<18} {:<12} {:>3} files  total {:>8.2} GB  range {:.2}–{:.2} GB",
            self.alias,
            self.project,
            self.files,
            self.total_bytes as f64 / GB,
            self.min_bytes as f64 / GB,
            self.max_bytes as f64 / GB,
        )
    }
}

/// Sanity helper for tests and docs: byte counts of the paper units.
pub fn paper_units() -> (f64, f64) {
    (GB, MB)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table2() {
        assert_eq!(TABLE2_PRESETS.len(), 3);
        let breast = DatasetPreset::find("Breast-RNA-seq").unwrap();
        assert_eq!(breast.project, "PRJNA762469");
        assert_eq!(breast.files, 10);
        let hifi = DatasetPreset::find("prjna540705").unwrap();
        assert_eq!(hifi.alias, "HiFi-WGS");
        assert!(DatasetPreset::find("nope").is_none());
    }

    #[test]
    fn generated_sizes_satisfy_published_constraints() {
        for preset in &TABLE2_PRESETS {
            for seed in 0..5 {
                let sizes = preset.generate(seed);
                assert_eq!(sizes.len(), preset.files, "{}", preset.alias);
                let total: u64 = sizes.iter().sum();
                let err = (total as i64 - preset.total_bytes as i64).abs();
                assert!(
                    err <= preset.files as i64,
                    "{}: total off by {err} bytes",
                    preset.alias
                );
                for &s in &sizes {
                    assert!(
                        s >= preset.min_bytes && s <= preset.max_bytes,
                        "{}: size {s} outside [{}, {}]",
                        preset.alias,
                        preset.min_bytes,
                        preset.max_bytes
                    );
                }
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let p = &TABLE2_PRESETS[0];
        assert_ne!(p.generate(1), p.generate(2));
        assert_eq!(p.generate(3), p.generate(3));
    }
}
