//! The simulated repository catalog (stands in for ENA/NCBI metadata).
//!
//! Maps BioProjects to their member runs with sizes and download URLs.
//! The three Table 2 projects are built in; ad-hoc projects can be
//! registered for tests and the FABRIC-style synthetic workloads
//! (§5.2 used "several hundred gigabytes of randomly generated files" —
//! [`Catalog::register_synthetic`] builds exactly that).
//!
//! Every [`RunRecord`] carries an *ordered mirror list* rather than a
//! single URL: INSDC data is replicated across ENA and NCBI, and the
//! unified session engine schedules across (and fails over between)
//! those mirrors. `urls[0]` is the primary; helpers keep the common
//! single-mirror construction ergonomic.

use std::collections::BTreeMap;

use crate::accession::datasets::{DatasetPreset, TABLE2_PRESETS};
use crate::accession::id::Accession;
use crate::{Error, Result};

/// One downloadable run (a file in the repository).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunRecord {
    /// Run accession (`SRR…`).
    pub accession: String,
    /// Parent project.
    pub project: String,
    /// Payload size (bytes).
    pub bytes: u64,
    /// Ordered mirror list: `urls[0]` is the primary endpoint (simulated
    /// ENA FTP/HTTPS path, or a real `http://127.0.0.1:…` URL when
    /// serving from the local test server); later entries are fallback
    /// mirrors the session engine fails over to when the primary slows
    /// down or browns out. Never empty.
    pub urls: Vec<String>,
}

impl RunRecord {
    /// Single-mirror record (the common case).
    pub fn new(
        accession: impl Into<String>,
        project: impl Into<String>,
        bytes: u64,
        url: impl Into<String>,
    ) -> RunRecord {
        RunRecord {
            accession: accession.into(),
            project: project.into(),
            bytes,
            urls: vec![url.into()],
        }
    }

    /// Append fallback mirrors after the primary.
    pub fn with_mirrors(mut self, mirrors: Vec<String>) -> RunRecord {
        self.urls.extend(mirrors);
        self
    }

    /// The primary download URL.
    pub fn primary_url(&self) -> &str {
        &self.urls[0]
    }

    /// URL of mirror `m`, clamped to the record's list (records with
    /// fewer mirrors than the session-wide maximum serve the overflow
    /// from their last listed endpoint).
    pub fn mirror_url(&self, m: usize) -> &str {
        &self.urls[m.min(self.urls.len() - 1)]
    }

    /// Number of mirrors this record lists.
    pub fn mirror_count(&self) -> usize {
        self.urls.len()
    }
}

/// Project → members index.
#[derive(Debug, Default)]
pub struct Catalog {
    projects: BTreeMap<String, Vec<RunRecord>>,
}

impl Catalog {
    /// Empty catalog (tests).
    pub fn empty() -> Catalog {
        Catalog::default()
    }

    /// Catalog with the three Table 2 BioProjects, file sizes
    /// synthesized deterministically from `seed`.
    pub fn with_table2(seed: u64) -> Catalog {
        let mut cat = Catalog::default();
        for preset in &TABLE2_PRESETS {
            cat.register_preset(preset, seed);
        }
        cat
    }

    /// Register one preset's synthesized members. Every run lists two
    /// mirrors — the ENA FTP primary and the NCBI SRA fallback — the
    /// way real INSDC data is actually replicated, so multi-mirror
    /// scheduling is exercisable on the built-in catalog.
    pub fn register_preset(&mut self, preset: &DatasetPreset, seed: u64) {
        let sizes = preset.generate(seed);
        let runs = sizes
            .iter()
            .enumerate()
            .map(|(i, &bytes)| {
                let proj = preset.project.to_ascii_lowercase();
                RunRecord::new(
                    format!("{}{:02}", preset.run_prefix, i + 1),
                    preset.project,
                    bytes,
                    format!(
                        "https://ftp.sra.ebi.ac.uk/vol1/srr/{proj}/{}{:02}",
                        preset.run_prefix,
                        i + 1
                    ),
                )
                .with_mirrors(vec![format!(
                    "https://sra-download.ncbi.nlm.nih.gov/traces/{proj}/{}{:02}",
                    preset.run_prefix,
                    i + 1
                )])
            })
            .collect();
        self.projects.insert(preset.project.to_string(), runs);
    }

    /// Register a synthetic project of `files` equal-size files
    /// (the §5.2 FABRIC workloads: 100 GB / 512 GB random files).
    pub fn register_synthetic(&mut self, project: &str, files: usize, bytes_each: u64) {
        self.register_synthetic_mirrored(project, files, bytes_each, 1);
    }

    /// Synthetic project whose files are replicated across `mirrors`
    /// endpoints (mirror-failover workloads; `mirrors >= 1`).
    pub fn register_synthetic_mirrored(
        &mut self,
        project: &str,
        files: usize,
        bytes_each: u64,
        mirrors: usize,
    ) {
        let mirrors = mirrors.max(1);
        let runs = (0..files)
            .map(|i| {
                let mut rec = RunRecord::new(
                    format!("SYN{project}{i:03}"),
                    project,
                    bytes_each,
                    format!("ftp://testbed/{project}/file{i:03}.bin"),
                );
                rec = rec.with_mirrors(
                    (1..mirrors)
                        .map(|m| format!("ftp://mirror{m}.testbed/{project}/file{i:03}.bin"))
                        .collect(),
                );
                rec
            })
            .collect();
        self.projects.insert(project.to_string(), runs);
    }

    /// Register explicit records (real-transport tests point these at
    /// the local HTTP server).
    pub fn register_runs(&mut self, project: &str, runs: Vec<RunRecord>) {
        self.projects.insert(project.to_string(), runs);
    }

    /// Member runs of a project.
    pub fn project_runs(&self, project: &str) -> Result<&[RunRecord]> {
        self.projects
            .get(project)
            .map(Vec::as_slice)
            .ok_or_else(|| {
                Error::Accession(format!("project '{project}' not found in catalog"))
            })
    }

    /// Find a single run anywhere in the catalog.
    pub fn find_run(&self, accession: &str) -> Option<&RunRecord> {
        self.projects
            .values()
            .flatten()
            .find(|r| r.accession == accession)
    }

    /// Expand an accession list into concrete run records.
    pub fn expand(&self, accessions: &[Accession]) -> Result<Vec<RunRecord>> {
        let mut out = Vec::new();
        for acc in accessions {
            match acc {
                Accession::Project(p) => out.extend_from_slice(self.project_runs(p)?),
                Accession::Run(r) => {
                    let rec = self.find_run(r).ok_or_else(|| {
                        Error::Accession(format!("run '{r}' not found in catalog"))
                    })?;
                    out.push(rec.clone());
                }
                Accession::Experiment(x) => {
                    return Err(Error::Accession(format!(
                        "experiment accessions ('{x}') must be expanded to runs first \
                         (the simulated catalog indexes runs and projects)"
                    )));
                }
            }
        }
        Ok(out)
    }

    /// Total bytes across a record list.
    pub fn total_bytes(records: &[RunRecord]) -> u64 {
        records.iter().map(|r| r.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_projects_present() {
        let cat = Catalog::with_table2(7);
        for preset in &TABLE2_PRESETS {
            let runs = cat.project_runs(preset.project).unwrap();
            assert_eq!(runs.len(), preset.files);
            let total: u64 = runs.iter().map(|r| r.bytes).sum();
            let err = (total as i64 - preset.total_bytes as i64).abs();
            assert!(err <= preset.files as i64);
        }
    }

    #[test]
    fn expand_projects_and_runs() {
        let cat = Catalog::with_table2(7);
        let accs = vec![
            Accession::parse("PRJNA400087").unwrap(),
            cat.project_runs("PRJNA762469").unwrap()[0]
                .accession
                .parse::<String>()
                .map(|s| Accession::parse(&s).unwrap())
                .unwrap(),
        ];
        let recs = cat.expand(&accs).unwrap();
        assert_eq!(recs.len(), 43 + 1);
    }

    #[test]
    fn unknown_project_errors() {
        let cat = Catalog::with_table2(7);
        assert!(cat.project_runs("PRJNA000000").is_err());
        let accs = vec![Accession::parse("SRR9999999").unwrap()];
        assert!(cat.expand(&accs).is_err());
    }

    #[test]
    fn synthetic_projects() {
        let mut cat = Catalog::empty();
        cat.register_synthetic("FABRIC-A", 4, 100_000_000_000);
        let runs = cat.project_runs("FABRIC-A").unwrap();
        assert_eq!(runs.len(), 4);
        assert_eq!(Catalog::total_bytes(runs), 400_000_000_000);
        assert_eq!(runs[0].mirror_count(), 1);
    }

    #[test]
    fn preset_records_list_ena_and_ncbi_mirrors() {
        let cat = Catalog::with_table2(7);
        for r in cat.project_runs("PRJNA400087").unwrap() {
            assert_eq!(r.mirror_count(), 2);
            assert!(r.primary_url().contains("ebi.ac.uk"));
            assert!(r.mirror_url(1).contains("ncbi"));
            // Out-of-range mirror indices clamp to the last endpoint.
            assert_eq!(r.mirror_url(9), r.mirror_url(1));
        }
    }

    #[test]
    fn synthetic_mirrored_projects() {
        let mut cat = Catalog::empty();
        cat.register_synthetic_mirrored("FAB", 2, 1_000, 3);
        let runs = cat.project_runs("FAB").unwrap();
        assert_eq!(runs[0].mirror_count(), 3);
        assert_ne!(runs[0].mirror_url(0), runs[0].mirror_url(2));
    }
}
