//! The simulated repository catalog (stands in for ENA/NCBI metadata).
//!
//! Maps BioProjects to their member runs with sizes and download URLs.
//! The three Table 2 projects are built in; ad-hoc projects can be
//! registered for tests and the FABRIC-style synthetic workloads
//! (§5.2 used "several hundred gigabytes of randomly generated files" —
//! [`Catalog::register_synthetic`] builds exactly that).

use std::collections::BTreeMap;

use crate::accession::datasets::{DatasetPreset, TABLE2_PRESETS};
use crate::accession::id::Accession;
use crate::{Error, Result};

/// One downloadable run (a file in the repository).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunRecord {
    /// Run accession (`SRR…`).
    pub accession: String,
    /// Parent project.
    pub project: String,
    /// Payload size (bytes).
    pub bytes: u64,
    /// Download URL (simulated ENA FTP/HTTPS path, or a real
    /// `http://127.0.0.1:…` URL when serving from the local test server).
    pub url: String,
}

/// Project → members index.
#[derive(Debug, Default)]
pub struct Catalog {
    projects: BTreeMap<String, Vec<RunRecord>>,
}

impl Catalog {
    /// Empty catalog (tests).
    pub fn empty() -> Catalog {
        Catalog::default()
    }

    /// Catalog with the three Table 2 BioProjects, file sizes
    /// synthesized deterministically from `seed`.
    pub fn with_table2(seed: u64) -> Catalog {
        let mut cat = Catalog::default();
        for preset in &TABLE2_PRESETS {
            cat.register_preset(preset, seed);
        }
        cat
    }

    /// Register one preset's synthesized members.
    pub fn register_preset(&mut self, preset: &DatasetPreset, seed: u64) {
        let sizes = preset.generate(seed);
        let runs = sizes
            .iter()
            .enumerate()
            .map(|(i, &bytes)| RunRecord {
                accession: format!("{}{:02}", preset.run_prefix, i + 1),
                project: preset.project.to_string(),
                bytes,
                url: format!(
                    "https://ftp.sra.ebi.ac.uk/vol1/srr/{}/{}{:02}",
                    preset.project.to_ascii_lowercase(),
                    preset.run_prefix,
                    i + 1
                ),
            })
            .collect();
        self.projects.insert(preset.project.to_string(), runs);
    }

    /// Register a synthetic project of `files` equal-size files
    /// (the §5.2 FABRIC workloads: 100 GB / 512 GB random files).
    pub fn register_synthetic(&mut self, project: &str, files: usize, bytes_each: u64) {
        let runs = (0..files)
            .map(|i| RunRecord {
                accession: format!("SYN{project}{i:03}"),
                project: project.to_string(),
                bytes: bytes_each,
                url: format!("ftp://testbed/{project}/file{i:03}.bin"),
            })
            .collect();
        self.projects.insert(project.to_string(), runs);
    }

    /// Register explicit records (real-transport tests point these at
    /// the local HTTP server).
    pub fn register_runs(&mut self, project: &str, runs: Vec<RunRecord>) {
        self.projects.insert(project.to_string(), runs);
    }

    /// Member runs of a project.
    pub fn project_runs(&self, project: &str) -> Result<&[RunRecord]> {
        self.projects
            .get(project)
            .map(Vec::as_slice)
            .ok_or_else(|| {
                Error::Accession(format!("project '{project}' not found in catalog"))
            })
    }

    /// Find a single run anywhere in the catalog.
    pub fn find_run(&self, accession: &str) -> Option<&RunRecord> {
        self.projects
            .values()
            .flatten()
            .find(|r| r.accession == accession)
    }

    /// Expand an accession list into concrete run records.
    pub fn expand(&self, accessions: &[Accession]) -> Result<Vec<RunRecord>> {
        let mut out = Vec::new();
        for acc in accessions {
            match acc {
                Accession::Project(p) => out.extend_from_slice(self.project_runs(p)?),
                Accession::Run(r) => {
                    let rec = self.find_run(r).ok_or_else(|| {
                        Error::Accession(format!("run '{r}' not found in catalog"))
                    })?;
                    out.push(rec.clone());
                }
                Accession::Experiment(x) => {
                    return Err(Error::Accession(format!(
                        "experiment accessions ('{x}') must be expanded to runs first \
                         (the simulated catalog indexes runs and projects)"
                    )));
                }
            }
        }
        Ok(out)
    }

    /// Total bytes across a record list.
    pub fn total_bytes(records: &[RunRecord]) -> u64 {
        records.iter().map(|r| r.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_projects_present() {
        let cat = Catalog::with_table2(7);
        for preset in &TABLE2_PRESETS {
            let runs = cat.project_runs(preset.project).unwrap();
            assert_eq!(runs.len(), preset.files);
            let total: u64 = runs.iter().map(|r| r.bytes).sum();
            let err = (total as i64 - preset.total_bytes as i64).abs();
            assert!(err <= preset.files as i64);
        }
    }

    #[test]
    fn expand_projects_and_runs() {
        let cat = Catalog::with_table2(7);
        let accs = vec![
            Accession::parse("PRJNA400087").unwrap(),
            cat.project_runs("PRJNA762469").unwrap()[0]
                .accession
                .parse::<String>()
                .map(|s| Accession::parse(&s).unwrap())
                .unwrap(),
        ];
        let recs = cat.expand(&accs).unwrap();
        assert_eq!(recs.len(), 43 + 1);
    }

    #[test]
    fn unknown_project_errors() {
        let cat = Catalog::with_table2(7);
        assert!(cat.project_runs("PRJNA000000").is_err());
        let accs = vec![Accession::parse("SRR9999999").unwrap()];
        assert!(cat.expand(&accs).is_err());
    }

    #[test]
    fn synthetic_projects() {
        let mut cat = Catalog::empty();
        cat.register_synthetic("FABRIC-A", 4, 100_000_000_000);
        let runs = cat.project_runs("FABRIC-A").unwrap();
        assert_eq!(runs.len(), 4);
        assert_eq!(Catalog::total_bytes(runs), 400_000_000_000);
    }
}
