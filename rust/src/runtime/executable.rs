//! One compiled artifact: HLO text → PJRT executable → typed execute.

use std::path::Path;

use crate::{Error, Result};

use super::artifacts::ArtifactSpec;

/// A single compiled HLO artifact plus its manifest spec (for shape
/// checking at the call boundary).
pub struct CompiledArtifact {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

impl CompiledArtifact {
    /// Parse `<dir>/<spec.file>` as HLO text and compile it on `client`.
    pub fn compile(
        client: &xla::PjRtClient,
        dir: &Path,
        spec: &ArtifactSpec,
    ) -> Result<CompiledArtifact> {
        let path = dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-UTF-8 path {}", path.display())))?,
        )
        .map_err(|e| {
            Error::Artifact(format!("failed to parse {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Xla(format!("compiling {}: {e}", spec.name)))?;
        Ok(CompiledArtifact {
            exe,
            spec: spec.clone(),
        })
    }

    /// Artifact name (diagnostics).
    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Execute with f32 slices as inputs; returns the flattened f32
    /// contents of the (single) output tensor.
    ///
    /// Input lengths are checked against the manifest spec before the
    /// PJRT call so a drifted caller fails with a precise message rather
    /// than an opaque XLA shape error.
    pub fn execute(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Xla(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, tspec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if data.len() != tspec.elements() {
                return Err(Error::Xla(format!(
                    "{}: input {i} has {} elements, artifact expects {} (shape {:?})",
                    self.spec.name,
                    data.len(),
                    tspec.elements(),
                    tspec.shape
                )));
            }
            let lit = xla::Literal::vec1(data);
            // Reshape 1-D host data to the artifact's logical shape when
            // it is not rank-1 (e.g. the f32[G,G] utility surface output
            // has rank-2 *inputs* only in future artifacts; today only
            // rank-1 inputs exist, but keep this general).
            let lit = if tspec.shape.len() == 1 {
                lit
            } else {
                let dims: Vec<i64> = tspec.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)?
            };
            literals.push(lit);
        }

        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Xla(format!("{}: empty result", self.spec.name)))?
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let inner = out.to_tuple1()?;
        let values = inner.to_vec::<f32>()?;
        let expected: usize = self.spec.outputs.iter().map(|o| o.elements()).sum();
        if values.len() != expected {
            return Err(Error::Xla(format!(
                "{}: output has {} elements, manifest says {}",
                self.spec.name,
                values.len(),
                expected
            )));
        }
        Ok(values)
    }
}
