//! Artifact manifest parsing and validation.
//!
//! `python/compile/aot.py` writes `manifest.json` next to the HLO text
//! files; this module reads it and checks that (a) every artifact this
//! crate needs is present, (b) the model constants match the sizes the
//! Rust controllers were written against, and (c) each file's SHA-256
//! matches the manifest, so a half-regenerated artifact directory fails
//! at startup instead of silently mis-executing.

use std::path::Path;

use crate::util::json::Json;
use crate::{Error, Result};

use super::REQUIRED_ARTIFACTS;

/// Window/grid sizes the Rust side is compiled against. Must equal the
/// constants in `python/compile/model.py`.
pub const EXPECTED_WINDOW: usize = 16;
pub const EXPECTED_GRID: usize = 64;
pub const EXPECTED_SAMPLES: usize = 256;

/// Constants recorded by the AOT step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConstants {
    /// Probe-history ring length.
    pub window: usize,
    /// Candidate concurrency grid length (Bayesian step).
    pub grid: usize,
    /// Raw monitor samples per probe window.
    pub samples: usize,
}

/// Shape+dtype of one artifact input or output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .require("shape")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("tensor shape is not an array".into()))?
            .iter()
            .map(|d| {
                d.as_u64()
                    .map(|v| v as usize)
                    .ok_or_else(|| Error::Artifact("non-integer dim".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .require("dtype")?
            .as_str()
            .ok_or_else(|| Error::Artifact("dtype is not a string".into()))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

/// The parsed `manifest.json`.
#[derive(Debug)]
pub struct ArtifactManifest {
    pub constants: ModelConstants,
    pub artifacts: Vec<ArtifactSpec>,
    dir: std::path::PathBuf,
}

impl ArtifactManifest {
    /// Read and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!("cannot read {}: {e}", path.display()))
        })?;
        let j = Json::parse(&text)?;

        let format = j.require("format")?.as_str().unwrap_or_default();
        if format != "hlo-text-v1" {
            return Err(Error::Artifact(format!(
                "unsupported artifact format '{format}' (expected hlo-text-v1)"
            )));
        }

        let consts = j.require("constants")?;
        let get_const = |k: &str| -> Result<usize> {
            consts
                .require(k)?
                .as_u64()
                .map(|v| v as usize)
                .ok_or_else(|| Error::Artifact(format!("constant '{k}' is not an integer")))
        };
        let constants = ModelConstants {
            window: get_const("window")?,
            grid: get_const("grid")?,
            samples: get_const("samples")?,
        };

        let arts = j
            .require("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("'artifacts' is not an object".into()))?;
        let mut artifacts = Vec::new();
        for (name, entry) in arts {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .require(key)?
                    .as_arr()
                    .ok_or_else(|| Error::Artifact(format!("'{key}' is not an array")))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: name.clone(),
                file: entry
                    .require("file")?
                    .as_str()
                    .ok_or_else(|| Error::Artifact("'file' is not a string".into()))?
                    .to_string(),
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
                sha256: entry
                    .require("sha256")?
                    .as_str()
                    .unwrap_or_default()
                    .to_string(),
            });
        }

        Ok(ArtifactManifest {
            constants,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Check completeness, constant agreement, and file hashes.
    pub fn validate(&self) -> Result<()> {
        let c = &self.constants;
        if c.window != EXPECTED_WINDOW || c.grid != EXPECTED_GRID || c.samples != EXPECTED_SAMPLES
        {
            return Err(Error::Artifact(format!(
                "artifact constants {c:?} do not match this build \
                 (window={EXPECTED_WINDOW}, grid={EXPECTED_GRID}, samples={EXPECTED_SAMPLES}); \
                 re-run `make artifacts`"
            )));
        }
        for required in REQUIRED_ARTIFACTS {
            let spec = self.spec(required)?;
            let path = self.dir.join(&spec.file);
            let text = std::fs::read_to_string(&path).map_err(|e| {
                Error::Artifact(format!("cannot read {}: {e}", path.display()))
            })?;
            let digest = sha256_hex(text.as_bytes());
            if !spec.sha256.is_empty() && digest != spec.sha256 {
                return Err(Error::Artifact(format!(
                    "{} content hash mismatch (manifest {}, file {}); artifact dir is stale — \
                     re-run `make artifacts`",
                    spec.file,
                    &spec.sha256[..12.min(spec.sha256.len())],
                    &digest[..12],
                )));
            }
        }
        Ok(())
    }

    /// Look up one artifact's spec by name.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "artifact '{name}' missing from manifest — re-run `make artifacts`"
                ))
            })
    }
}

/// Pure-Rust SHA-256 (FIPS 180-4). Only used at startup for artifact
/// integrity; ~1 MB of HLO text hashes in well under a millisecond.
pub fn sha256_hex(data: &[u8]) -> String {
    const K: [u32; 64] = [
        0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
        0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
        0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
        0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
        0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
        0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
        0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
        0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
        0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
        0xc67178f2,
    ];
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let bitlen = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_be_bytes());

    let mut w = [0u32; 64];
    for chunk in msg.chunks_exact(64) {
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes(chunk[i * 4..i * 4 + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let (mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh) =
            (h[0], h[1], h[2], h[3], h[4], h[5], h[6], h[7]);
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    h.iter().map(|x| format!("{x:08x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // Multi-block message (>64 bytes).
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec {
            shape: vec![64, 64],
            dtype: "float32".into(),
        };
        assert_eq!(t.elements(), 4096);
        let scalar = TensorSpec {
            shape: vec![],
            dtype: "float32".into(),
        };
        assert_eq!(scalar.elements(), 1);
    }
}
