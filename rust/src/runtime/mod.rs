//! XLA/PJRT runtime bridge.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`
//! (`artifacts/*.hlo.txt` + `manifest.json`), compiles each once on the
//! PJRT CPU client at startup, and exposes typed entry points the
//! optimizer loop calls every probing interval. Python never runs here —
//! the artifacts are plain HLO text and the `xla` crate executes them
//! natively (see `/opt/xla-example/load_hlo/` for the reference wiring).
//!
//! Compilation happens exactly once per artifact; execution from the hot
//! path is lock-free reads of the compiled executable plus one
//! host-literal round trip (microseconds against a 3–5 s probing
//! interval — see EXPERIMENTS.md §Perf for measurements).

mod artifacts;
mod executable;

pub use artifacts::{
    ArtifactManifest, ArtifactSpec, ModelConstants, EXPECTED_GRID, EXPECTED_SAMPLES,
    EXPECTED_WINDOW,
};
pub use executable::CompiledArtifact;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::{Error, Result};

/// Names of the artifacts the runtime requires (must match
/// `compile.model.artifact_specs()` on the Python side).
pub const REQUIRED_ARTIFACTS: [&str; 4] = [
    "gd_step",
    "bayes_step",
    "throughput_window",
    "utility_surface",
];

/// The loaded runtime: one PJRT client plus every compiled artifact.
///
/// `XlaRuntime` is cheap to share (`Arc` internally) and thread-safe for
/// execution: PJRT CPU executions are internally synchronized, and each
/// call builds its own input literals.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    gd_step: CompiledArtifact,
    bayes_step: CompiledArtifact,
    throughput_window: CompiledArtifact,
    utility_surface: CompiledArtifact,
}

/// Shared handle used across coordinator threads.
pub type SharedRuntime = Arc<XlaRuntime>;

impl XlaRuntime {
    /// Load and compile every artifact from `dir` (e.g. `artifacts/`).
    ///
    /// Fails fast if the manifest is missing, its constants disagree with
    /// this crate's compiled-in expectations, any artifact file is
    /// missing, or its content hash differs from the manifest entry.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = ArtifactManifest::load(dir)?;
        manifest.validate()?;

        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> Result<CompiledArtifact> {
            let spec = manifest.spec(name)?;
            CompiledArtifact::compile(&client, dir, spec)
        };
        Ok(XlaRuntime {
            gd_step: compile("gd_step")?,
            bayes_step: compile("bayes_step")?,
            throughput_window: compile("throughput_window")?,
            utility_surface: compile("utility_surface")?,
            manifest,
            client,
        })
    }

    /// Locate the artifact directory: `$FASTBIODL_ARTIFACTS`, else
    /// `./artifacts`, else `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("FASTBIODL_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("manifest.json").exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load from [`XlaRuntime::default_dir`].
    pub fn load_default() -> Result<XlaRuntime> {
        let dir = Self::default_dir();
        if !dir.join("manifest.json").exists() {
            return Err(Error::Artifact(format!(
                "artifact manifest not found at {} — run `make artifacts` first",
                dir.display()
            )));
        }
        Self::load(&dir)
    }

    /// Model constants the artifacts were lowered with.
    pub fn constants(&self) -> &ModelConstants {
        &self.manifest.constants
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// One gradient-descent step. See `compile.model.gd_step` for the
    /// slot layout; returns `[next_c, grad, step, u_mean]`.
    pub fn gd_step(
        &self,
        c_hist: &[f32],
        t_hist: &[f32],
        weights: &[f32],
        params: &[f32; 8],
    ) -> Result<Vec<f32>> {
        self.gd_step.execute(&[c_hist, t_hist, weights, params])
    }

    /// One Bayesian-optimization step. Returns
    /// `[mu(G) | std(G) | ei(G) | best_idx | next_c]`.
    pub fn bayes_step(
        &self,
        c_obs: &[f32],
        t_obs: &[f32],
        valid: &[f32],
        grid: &[f32],
        params: &[f32; 8],
    ) -> Result<Vec<f32>> {
        self.bayes_step
            .execute(&[c_obs, t_obs, valid, grid, params])
    }

    /// Aggregate one probe window of raw throughput samples. Returns
    /// `[count, mean, std, min, max, wmean]`.
    pub fn throughput_window(
        &self,
        samples: &[f32],
        valid: &[f32],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        self.throughput_window.execute(&[samples, valid, weights])
    }

    /// Full utility surface `U[i,j] = t[i] / k^c[j]`, row-major `G*G`.
    pub fn utility_surface(&self, t_grid: &[f32], c_grid: &[f32], k: f32) -> Result<Vec<f32>> {
        self.utility_surface.execute(&[t_grid, c_grid, &[k]])
    }
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime")
            .field("platform", &self.client.platform_name())
            .field("constants", &self.manifest.constants)
            .finish()
    }
}
