//! `fastbiodl` — the leader binary.
//!
//! Subcommands:
//!
//! * `download <accession...>` — simulated adaptive download of one or
//!   more accessions/BioProjects on a named scenario profile.
//! * `campaign <manifest|accession...>` — many-file campaign run:
//!   small files coalesced into pipelined request trains, large files
//!   chunk-striped, one global chunk pool.
//! * `fetch <url...>` — real-socket adaptive download of HTTP URLs
//!   (pair with `serve`).
//! * `serve` — run the throttled local HTTP server with synthetic
//!   files (the loopback "archive mirror").
//! * `datasets` — print the Table 2 dataset inventory.
//! * `experiment <id|all>` — regenerate a paper table/figure
//!   (`table1`, `table3`, `fig1`, `fig2`, `fig4`, `fig5`, `fig6`).
//! * `utility-surface` — dump the §4.1 utility surface for a given k
//!   through the XLA artifact.
//! * `info` — runtime/platform/artifact diagnostics.
//!
//! Run `fastbiodl help` for flags.

use std::sync::Arc;

use fastbiodl::accession::{Accession, Catalog, Resolver};
use fastbiodl::config::cli::Args;
use fastbiodl::config::{DownloadConfig, OptimizerKind, TraceConfig, TraceFormat};
use fastbiodl::experiments::runner::{run_tool_once_with_stats, Tool};
use fastbiodl::experiments::{fig1, fig2, fig4, fig5, fig6, scenario, table1, table3};
use fastbiodl::optimizer::build_controller_with;
use fastbiodl::report::{sparkline, Table};
use fastbiodl::runtime::{SharedRuntime, XlaRuntime};
use fastbiodl::session::real::{run_real_session_with_stats, RealSessionParams, Sink};
use fastbiodl::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use fastbiodl::session::{session_report_json, EngineStats, SessionReport};
use fastbiodl::trace::Tracer;
use fastbiodl::transport::{ServedFile, ThrottleConfig, ThrottledHttpServer};
use fastbiodl::util::logger;
use fastbiodl::{out, vlog, Error, Result};

const HELP: &str = r#"fastbiodl — adaptive parallel downloader for large genomic datasets

USAGE:
    fastbiodl <command> [args] [--flags]

GLOBAL FLAGS (any command):
    -q, --quiet               errors and warnings only; stdout stays clean
    -v, --verbose             extra diagnostics on stderr

COMMANDS:
    download <accession...>   simulated adaptive download (Table 2 catalog)
        --scenario <alias>    colab dataset alias or fabric-a|b|c (default: auto)
        --optimizer <gd|bayes|fixed>   controller (default gd)
        --k <float>           utility penalty coefficient (default 1.02)
        --probe <secs>        probing interval (default 5)
        --fixed-level <n>     level for --optimizer fixed
        --seed <n>            simulation seed (default 1)
        --faults <profile>    hostile network variant: none|flaky|stalls|
                              errors|collapse|flashcrowd|brownout|
                              slowmirror|burstloss|dnsoutage|bitflip|
                              chaos (seeded schedule; see netsim::fault)
        --mirror-strategy <s> stripe (score-weighted striping, default)
                              or failover (winner-take-all binding)
        --mirror-conns <n>    per-mirror connection cap (default 0 = off)
        --fault-penalty <w>   weight of the retry/reject fault penalty
                              in the adaptive utility (default 0 = off)
        --adaptive-chunks     striping-aware chunk sizing: shrink chunks
                              under fault pressure / on degraded mirrors
        --verify              per-chunk SHA-256 verification: corrupt
                              chunks (e.g. --faults bitflip) are caught
                              and re-fetched instead of shipped
        --reconcile <m>       engine slot reconciliation: batched
                              (default) or full-scan (naive reference)
        --report-json <path>  write the machine-readable session record
                              (schema fastbiodl-report-v1)
        --trace-out <path>    flight recorder: export the session's
                              event trace here (default off; tracing
                              never alters a session's behaviour)
        --trace-format <f>    ndjson (default; schema fastbiodl-trace-v1)
                              or chrome (trace_event JSON for Perfetto)
        --pipeline-depth <n>  in-flight requests per keep-alive
                              connection (default 1 = no pipelining)
        --trace-capacity <n>  trace ring-buffer capacity in events
                              (default 65536; oldest overwritten)
    campaign <manifest|accession...>
                              many-file campaign through one engine run:
                              files below the coalesce threshold become
                              pipelined whole-file request trains, large
                              files keep chunked striping. A positional
                              that names an existing file is read as a
                              manifest (one accession per line, # = comment).
                              Takes the download flags, plus:
        --pipeline-depth <n>  in-flight requests per connection
                              (campaign default 4)
        --coalesce-files-kb <n>  files smaller than this join request
                              trains (default 4096; larger = chunked)
    fetch <url...>            real-socket adaptive download over HTTP
        --out <dir>           write payloads here (default: discard)
        --chunk-mb <n>        range-request size (default 32)
        --probe <secs>        probing interval (default 5)
        --c-max <n>           worker-pool capacity (default 16)
        --size <bytes>        total size per URL if the server lacks HEAD
        --mirror-strategy <s> stripe (default) or failover
        --mirror-conns <n>    per-mirror connection cap (default 0 = off)
        --fault-penalty <w>   utility fault penalty (default 0 = off)
        --adaptive-chunks     striping-aware chunk sizing
        --progress-window <s> progress deadline: cut a connection that
                              moves < --progress-min-bytes per window
                              (default 30; 0 disables)
        --progress-min-bytes <n>  minimum bytes per progress window
                              (default 65536)
        --sink-threads <n>    dedicated disk-writer threads (default 2;
                              0 = write inline on the reactor threads)
        --sink-queue-mb <n>   pooled write-buffer budget in MiB
                              (default 64; full pool = backpressure)
        --coalesce-kb <n>     max bytes merged into one positional
                              write (default 1024)
        --verify              per-chunk SHA-256 verification against the
                              .fastbiodl-manifest kept next to --out
                              files (trust-on-first-use for unknown
                              chunks; mismatches are re-fetched)
        --pipeline-depth <n>  in-flight requests per keep-alive
                              connection (default 1 = no pipelining)
        --reuse-local         delta resume: rehash partial files on disk
                              at cold start and re-download only the
                              chunks that fail verification (requires
                              --verify)
        --report-json <path>  machine-readable session record
        --trace-out <path>    flight-recorder trace (see download)
        --trace-format <f>    ndjson (default) or chrome
        --trace-capacity <n>  trace ring capacity (default 65536)
    trace-validate <path>     check an NDJSON trace against the
                              fastbiodl-trace-v1 schema (exit non-zero
                              on any malformed line)
    serve                     run the throttled loopback archive server
        --files <n>           number of synthetic files (default 4)
        --size-mb <n>         size of each file (default 64)
        --conn-mbps <n>       per-connection cap (default 0 = off)
        --global-mbps <n>     global cap (default 0 = off)
        --ttfb <secs>         first-byte latency (default 0)
        --faults <profile>    replay a fault profile server-side (5xx
                              windows + added latency; pair with fetch)
        --seed <n>            fault schedule seed (default 1)
        --horizon <secs>      fault schedule horizon (default 600)
    datasets                  print the Table 2 inventory
    bench                     deterministic macro-benchmark harness:
                              Table-2 presets x fault profiles x
                              {gd,bayes,fixed} x c_max {16,64,256} over
                              the virtual-clock netsim, measuring real
                              control-loop cost (ns/tick, allocs/tick,
                              reconcile scan) alongside simulated goodput
        --suite <s>           smoke (7 cases, default), full (108), or
                              campaign (3 many-file presets: many-small
                              / mixed / many-large in campaign mode,
                              files/sec per cell)
        --out <path>          output JSON (default BENCH_engine.json)
        --baseline <path>     diff against a stored BENCH_engine.json
                              and print regressions
        --tolerance <frac>    ns/tick increase tolerated vs baseline
                              (default 0.35)
        --reconcile <m>       batched (default) or full-scan engine
                              reconciliation (the measured baseline)
        --sweep               instead of a suite: deterministic GD
                              hyperparameter sweep (k x lr x probe
                              interval) under the hostile profiles
                              {slowmirror, brownout, flashcrowd},
                              reporting the best cell per profile
        --seed <n>            simulation seed (default 1)
    experiment <id|all>       regenerate paper artifacts
        --runs <n>            runs per configuration (default 5)
        --seed <n>            base seed (default 1000)
    utility-surface           print U(T,C)=T/k^C via the XLA artifact
        --k <float>           coefficient (default 1.02)
    info                      runtime/platform/artifact diagnostics
    help                      this text

ENVIRONMENT:
    FASTBIODL_ARTIFACTS       artifact directory (default ./artifacts)
    FASTBIODL_K, FASTBIODL_PROBE_INTERVAL, FASTBIODL_LR, FASTBIODL_OPTIMIZER,
    FASTBIODL_MIRROR_STRATEGY, FASTBIODL_FAULT_PENALTY, FASTBIODL_PROGRESS_WINDOW,
    FASTBIODL_SINK_THREADS, FASTBIODL_SINK_QUEUE_MB, FASTBIODL_COALESCE_KB,
    FASTBIODL_PIPELINE_DEPTH, FASTBIODL_VERIFY, FASTBIODL_REUSE_LOCAL,
    FASTBIODL_TRACE_OUT, FASTBIODL_TRACE_FORMAT, FASTBIODL_TRACE_CAPACITY
                              config overrides (see config module docs)
"#;

fn main() {
    match run() {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run() -> Result<()> {
    // Strip the global verbosity flags before command parsing so they
    // work in any argv position; the last one wins.
    let mut level = logger::Level::Normal;
    let argv: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| match a.as_str() {
            "-q" | "--quiet" => {
                level = logger::Level::Quiet;
                false
            }
            "-v" | "--verbose" => {
                level = logger::Level::Verbose;
                false
            }
            _ => true,
        })
        .collect();
    logger::init(level);
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "" | "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "datasets" => cmd_datasets(),
        "info" => cmd_info(),
        "bench" => cmd_bench(&args),
        "download" => cmd_download(&args),
        "campaign" => cmd_campaign(&args),
        "fetch" => cmd_fetch(&args),
        "trace-validate" => cmd_trace_validate(&args),
        "serve" => cmd_serve(&args),
        "experiment" => cmd_experiment(&args),
        "utility-surface" => cmd_utility_surface(&args),
        other => Err(Error::Config(format!(
            "unknown command '{other}' (try `fastbiodl help`)"
        ))),
    }
}

fn load_runtime() -> Result<SharedRuntime> {
    Ok(Arc::new(XlaRuntime::load_default()?))
}

fn cmd_datasets() -> Result<()> {
    out!("Table 2 — evaluation datasets:");
    for p in &fastbiodl::accession::TABLE2_PRESETS {
        out!("  {}", p.describe());
        out!("    organism: {}", p.organism);
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = XlaRuntime::default_dir();
    out!("artifact dir : {}", dir.display());
    let rt = load_runtime()?;
    out!("platform     : {}", rt.platform());
    out!("constants    : {:?}", rt.constants());
    for name in fastbiodl::runtime::REQUIRED_ARTIFACTS {
        out!("artifact     : {name} (compiled)");
    }
    Ok(())
}

fn apply_optimizer_flags(cfg: &mut DownloadConfig, args: &Args) -> Result<()> {
    if let Some(k) = args.flag_f64("k")? {
        cfg.optimizer.k = k;
    }
    if let Some(strategy) = args.flag("mirror-strategy") {
        cfg.mirror.strategy = fastbiodl::config::MirrorStrategy::parse(strategy)?;
    }
    if let Some(mode) = args.flag("reconcile") {
        cfg.reconcile = fastbiodl::config::ReconcileMode::parse(mode)?;
    }
    if let Some(conns) = args.flag_usize("mirror-conns")? {
        cfg.mirror.per_mirror_conns = conns;
    }
    if let Some(w) = args.flag_f64("fault-penalty")? {
        cfg.control.fault_penalty = w;
    }
    if args.flag_bool_strict("adaptive-chunks")? {
        cfg.control.adaptive_chunks = true;
    }
    if args.flag_bool_strict("verify")? {
        cfg.integrity.verify = true;
    }
    if args.flag_bool_strict("reuse-local")? {
        cfg.integrity.reuse_local = true;
    }
    if let Some(p) = args.flag_f64("probe")? {
        cfg.optimizer.probe_interval_s = p;
    }
    if let Some(kind) = args.flag("optimizer") {
        cfg.optimizer.kind = OptimizerKind::parse(kind)?;
    }
    if let Some(level) = args.flag_usize("fixed-level")? {
        cfg.optimizer.fixed_level = level;
        cfg.optimizer.c_init = level;
    }
    if let Some(c) = args.flag_usize("c-max")? {
        cfg.optimizer.c_max = c;
    }
    if let Some(mb) = args.flag_usize("chunk-mb")? {
        cfg.chunk_bytes = (mb as u64) * 1024 * 1024;
    }
    if let Some(d) = args.flag_usize("pipeline-depth")? {
        cfg.pipeline_depth = d;
    }
    if let Some(kb) = args.flag_u64("coalesce-files-kb")? {
        cfg.coalesce_files_kb = kb;
    }
    if let Some(path) = args.flag("trace-out") {
        cfg.trace.out = Some(path.to_string());
    }
    if let Some(f) = args.flag("trace-format") {
        cfg.trace.format = TraceFormat::parse(f)?;
    }
    if let Some(n) = args.flag_usize("trace-capacity")? {
        cfg.trace.capacity = n;
    }
    cfg.apply_env()?;
    Ok(())
}

/// Build the flight recorder when `--trace-out` (or the matching env
/// var) asked for one; `None` keeps every hot path untraced.
fn build_tracer(cfg: &TraceConfig) -> Result<Option<Arc<Tracer>>> {
    let Some(out) = cfg.out.as_ref() else {
        return Ok(None);
    };
    cfg.validate()?;
    let tracer = Tracer::with_capacity(cfg.capacity).with_blackbox(format!("{out}.blackbox"));
    Ok(Some(Arc::new(tracer)))
}

/// Export the recorded trace in the configured format. Called even
/// when the session itself failed: a post-mortem trace is the point.
fn write_trace(tracer: &Tracer, cfg: &TraceConfig) -> Result<()> {
    let Some(out) = cfg.out.as_ref() else {
        return Ok(());
    };
    let snap = tracer.snapshot();
    let text = match cfg.format {
        TraceFormat::Ndjson => snap.to_ndjson(),
        TraceFormat::Chrome => snap.to_chrome_json(),
    };
    std::fs::write(out, text)?;
    out!(
        "wrote {out} ({} events, {} dropped, format {})",
        snap.records.len(),
        snap.dropped,
        cfg.format.name()
    );
    Ok(())
}

/// Write the versioned machine-readable session record
/// (`--report-json`).
fn write_report_json(
    path: &str,
    report: &SessionReport,
    stats: Option<&EngineStats>,
) -> Result<()> {
    let mut text = session_report_json(report, stats).to_string_compact();
    text.push('\n');
    std::fs::write(path, &text)?;
    out!("wrote {path} (schema {})", fastbiodl::session::REPORT_SCHEMA);
    Ok(())
}

fn cmd_trace_validate(args: &Args) -> Result<()> {
    args.expect_flags(&[])?;
    let path = args
        .positional
        .first()
        .ok_or_else(|| Error::Config("trace-validate needs a trace file path".into()))?;
    let text = std::fs::read_to_string(path)?;
    let stats = fastbiodl::trace::validate_ndjson(&text)?;
    out!(
        "{path}: valid {} ({} events, ring capacity {}, {} dropped)",
        fastbiodl::trace::TRACE_SCHEMA,
        stats.events,
        stats.capacity,
        stats.dropped
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    use fastbiodl::bench;
    args.expect_flags(&[
        "suite", "out", "baseline", "seed", "reconcile", "tolerance", "sweep",
    ])?;
    let suite = bench::Suite::parse(args.flag("suite").unwrap_or("smoke"))?;
    let seed = args.flag_u64("seed")?.unwrap_or(1);
    if seed > (1u64 << 53) {
        // Seeds round-trip through JSON f64 numbers; beyond 2^53 the
        // baseline diff would silently skip its determinism checks.
        return Err(Error::Config(format!(
            "bench seed {seed} exceeds 2^53 (not representable in the JSON report)"
        )));
    }
    let reconcile = match args.flag("reconcile") {
        Some(s) => fastbiodl::config::ReconcileMode::parse(s)?,
        None => fastbiodl::config::ReconcileMode::default(),
    };
    let tolerance = args
        .flag_f64("tolerance")?
        .unwrap_or(bench::DEFAULT_TIMING_TOLERANCE);
    // Hyperparameter sweep mode: deterministic GD k × lr × probe grid
    // under the hostile profiles, best cell per profile (exclusive
    // with the suite grid).
    if args.flag_bool_strict("sweep")? {
        // The suite/baseline machinery does not run in sweep mode;
        // refuse the combination instead of silently skipping the
        // regression gate the caller asked for.
        if args.flag("suite").is_some()
            || args.flag("baseline").is_some()
            || args.flag("tolerance").is_some()
        {
            return Err(Error::Config(
                "--sweep is exclusive with --suite/--baseline/--tolerance \
                 (the sweep runs its own fixed grid)"
                    .into(),
            ));
        }
        let out_path = args.flag("out").unwrap_or("BENCH_sweep.json");
        let grid = bench::sweep_grid();
        out!(
            "bench sweep: {} cells over {} hostile profiles (seed {seed}, dataset {})",
            grid.len(),
            bench::SWEEP_PROFILES.len(),
            bench::SWEEP_DATASET,
        );
        let mut cells = Vec::with_capacity(grid.len());
        for (profile, tune) in grid {
            let cell = bench::run_sweep_cell(profile, tune, seed, reconcile)?;
            out!(
                "  {:<34} {:>8.1} Mbps  {:>7.1}s  {:>4} retries{}",
                cell.id(),
                cell.result.goodput_mbps,
                cell.result.duration_s,
                cell.result.chunk_retries,
                if cell.result.completed { "" } else { "  [capped]" },
            );
            cells.push(cell);
        }
        out!("best cell per profile:");
        for best in bench::best_per_profile(&cells) {
            out!(
                "  {:<12} k={:<5} lr={:<4} probe={:<4} -> {:.1} Mbps",
                best.profile.name(),
                best.tune.k,
                best.tune.lr,
                best.tune.probe_interval_s,
                best.result.goodput_mbps,
            );
        }
        let mut text = bench::sweep_to_json(&cells, seed, reconcile).to_string_compact();
        text.push('\n');
        std::fs::write(out_path, &text)?;
        out!("wrote {out_path} ({} cells)", cells.len());
        return Ok(());
    }

    let out_path = args.flag("out").unwrap_or("BENCH_engine.json");

    let specs = bench::suite_cases(suite);
    out!(
        "bench suite '{}' ({} cases, seed {seed}, reconcile {})",
        suite.name(),
        specs.len(),
        reconcile.name()
    );
    let mut cases = Vec::with_capacity(specs.len());
    for spec in &specs {
        let case = bench::run_case(spec, seed, reconcile)?;
        out!(
            "  {:<42} {:>8.1} Mbps  {:>7.2} f/s  {:>7} ticks  {:>9.0} ns/tick  {:>6.2} alloc/tick  scan {:>6.1}/tick{}",
            case.id,
            case.goodput_mbps,
            case.files_per_sec,
            case.ticks,
            case.ns_per_tick,
            case.allocs_per_tick,
            case.slots_scanned_per_tick,
            if case.completed { "" } else { "  [capped]" },
        );
        cases.push(case);
    }
    let report = bench::BenchReport {
        suite: suite.name().to_string(),
        seed,
        reconcile: reconcile.name().to_string(),
        cases,
    };
    let mut text = report.to_json().to_string_compact();
    text.push('\n');
    std::fs::write(out_path, &text)?;
    out!(
        "wrote {out_path} ({} cases, schema {})",
        report.cases.len(),
        bench::SCHEMA_VERSION
    );

    if let Some(baseline_path) = args.flag("baseline") {
        let baseline = bench::BenchReport::from_json(&std::fs::read_to_string(baseline_path)?)?;
        if baseline.cases.is_empty() {
            // A committed bootstrap baseline: the gate is wired but no
            // values are frozen yet. Freeze them by replacing the file
            // with a real report from the same suite+seed (e.g. the
            // one this run just wrote).
            out!(
                "baseline {baseline_path} is a bootstrap (no cases): nothing to diff. \
                 Freeze it by committing {out_path} as the new baseline."
            );
            return Ok(());
        }
        let regressions = bench::diff(&report, &baseline, tolerance);
        if regressions.is_empty() {
            out!(
                "baseline {baseline_path}: no regressions (ns/tick tolerance {:.0}%)",
                tolerance * 100.0
            );
        } else {
            // Regression details go through the warn channel so they
            // survive --quiet (CI runs want the findings, not just the
            // non-zero exit).
            log::warn!(
                "baseline {baseline_path}: {} regression(s):",
                regressions.len()
            );
            for r in &regressions {
                log::warn!("  [{}] {}: {}", r.kind.name(), r.case_id, r.detail);
            }
            // Baseline mode is an explicit gate: scripts and CI must
            // see a non-zero exit, not have to scrape stdout.
            return Err(Error::Session(format!(
                "bench regressed against {baseline_path} ({} finding(s))",
                regressions.len()
            )));
        }
    }
    Ok(())
}

fn cmd_download(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "scenario", "optimizer", "k", "probe", "fixed-level", "seed", "c-max", "chunk-mb",
        "faults", "mirror-strategy", "mirror-conns", "reconcile", "fault-penalty",
        "adaptive-chunks", "verify", "pipeline-depth", "report-json", "trace-out",
        "trace-format", "trace-capacity",
    ])?;
    if args.positional.is_empty() {
        return Err(Error::Config(
            "download needs at least one accession (e.g. PRJNA762469)".into(),
        ));
    }
    let seed = args.flag_u64("seed")?.unwrap_or(1);
    let accessions: Vec<Accession> = args
        .positional
        .iter()
        .map(|s| Accession::parse(s))
        .collect::<Result<_>>()?;

    // Scenario: explicit flag, else inferred from the first project.
    let mut sc = match args.flag("scenario") {
        Some(name) if name.starts_with("fabric-") => {
            scenario::fabric(name.chars().last().unwrap(), seed)?
        }
        Some(name) => scenario::colab_dataset(name, seed)?,
        None => scenario::colab_dataset(
            accessions
                .iter()
                .find(|a| a.is_project())
                .map(|a| a.as_str())
                .unwrap_or("Breast-RNA-seq"),
            seed,
        )?,
    };
    apply_optimizer_flags(&mut sc.download, args)?;

    // Hostile variant: overlay a seeded fault schedule.
    if let Some(profile) = args.flag("faults") {
        let profile = fastbiodl::netsim::FaultProfile::parse(profile).map_err(Error::Config)?;
        let horizon = if sc.download.timeout_s > 0.0 {
            sc.download.timeout_s
        } else {
            1_800.0
        };
        sc = sc.with_fault_profile(profile, seed, horizon);
        if !sc.netsim.faults.is_empty() {
            out!(
                "fault profile '{}': {} scheduled events",
                profile.name(),
                sc.netsim.faults.len()
            );
        }
    }

    // Resolve against the catalog (simulated ENA portal).
    let catalog = Catalog::with_table2(seed);
    let resolver = Resolver::batch(&catalog);
    let (records, _) = resolver.resolve(&accessions)?;
    sc.records = records;

    out!(
        "downloading {} files ({}) on scenario '{}' with {} optimizer",
        sc.records.len(),
        fastbiodl::util::fmt_bytes(Catalog::total_bytes(&sc.records)),
        sc.name,
        sc.download.optimizer.kind.name(),
    );
    let tracer = build_tracer(&sc.download.trace)?;
    // Prefer the compiled XLA artifacts; fall back to the pure-Rust
    // mirror controllers when they are unavailable so the simulated
    // path (including --faults) works on a bare checkout.
    let outcome = match load_runtime() {
        Ok(rt) => run_tool_once_with_stats(&sc, &Tool::fastbiodl(&sc), &rt, seed, tracer.clone()),
        Err(e) => {
            log::warn!("XLA runtime unavailable ({e}); using pure-Rust mirror controllers");
            let controller =
                build_controller_with(&sc.download.optimizer, &sc.download.control, None)?;
            let mut session = SimSession::new(SimSessionParams {
                download: sc.download.clone(),
                behavior: ToolBehavior::fastbiodl(&sc.download),
                netsim: sc.netsim.clone(),
                records: sc.records.clone(),
                controller,
                runtime: None,
                seed,
            });
            if let Some(tr) = &tracer {
                session = session.with_tracer(tr.clone());
            }
            session.run_with_stats()
        }
    };
    // Export the trace before propagating a session error: the
    // post-mortem record matters most on the failing runs.
    if let Some(tr) = &tracer {
        write_trace(tr, &sc.download.trace)?;
    }
    let (report, stats) = outcome?;
    if let Some(path) = args.flag("report-json") {
        write_report_json(path, &report, Some(&stats))?;
    }
    print_report(&report, Some(&stats));
    Ok(())
}

/// Campaign mode: many accessions scheduled through one engine run,
/// with small files coalesced into pipelined request trains
/// (`SchedulerMode::Campaign`) while large files keep chunked striping.
fn cmd_campaign(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "scenario", "optimizer", "k", "probe", "fixed-level", "seed", "c-max", "chunk-mb",
        "faults", "mirror-strategy", "mirror-conns", "reconcile", "fault-penalty",
        "adaptive-chunks", "verify", "pipeline-depth", "coalesce-files-kb", "report-json",
        "trace-out", "trace-format", "trace-capacity",
    ])?;
    if args.positional.is_empty() {
        return Err(Error::Config(
            "campaign needs a manifest file or accession list \
             (e.g. `fastbiodl campaign runs.txt` or `fastbiodl campaign PRJNA762469`)"
                .into(),
        ));
    }
    let seed = args.flag_u64("seed")?.unwrap_or(1);

    // Manifest: each positional is either a file of accessions (one
    // per line, '#' comments) or an accession literal — so a
    // thousand-run campaign is a text file, not a shell line.
    let mut names: Vec<String> = Vec::new();
    for arg in &args.positional {
        if std::path::Path::new(arg).is_file() {
            for line in std::fs::read_to_string(arg)?.lines() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                names.push(line.to_string());
            }
        } else {
            names.push(arg.clone());
        }
    }
    if names.is_empty() {
        return Err(Error::Config("campaign manifest resolved to zero accessions".into()));
    }
    let accessions: Vec<Accession> = names
        .iter()
        .map(|s| Accession::parse(s))
        .collect::<Result<_>>()?;

    let mut sc = match args.flag("scenario") {
        Some(name) if name.starts_with("fabric-") => {
            scenario::fabric(name.chars().last().unwrap(), seed)?
        }
        Some(name) => scenario::colab_dataset(name, seed)?,
        None => scenario::colab_dataset(
            accessions
                .iter()
                .find(|a| a.is_project())
                .map(|a| a.as_str())
                .unwrap_or("Breast-RNA-seq"),
            seed,
        )?,
    };
    // Campaign defaults: trains on, pipelining deep enough to amortize
    // staging latency. `--pipeline-depth`/env still override.
    sc.download.campaign = true;
    sc.download.pipeline_depth = 4;
    apply_optimizer_flags(&mut sc.download, args)?;
    sc.download.validate()?;

    if let Some(profile) = args.flag("faults") {
        let profile = fastbiodl::netsim::FaultProfile::parse(profile).map_err(Error::Config)?;
        let horizon = if sc.download.timeout_s > 0.0 {
            sc.download.timeout_s
        } else {
            1_800.0
        };
        sc = sc.with_fault_profile(profile, seed, horizon);
        if !sc.netsim.faults.is_empty() {
            out!(
                "fault profile '{}': {} scheduled events",
                profile.name(),
                sc.netsim.faults.len()
            );
        }
    }

    let catalog = Catalog::with_table2(seed);
    let resolver = Resolver::batch(&catalog);
    let (records, _) = resolver.resolve(&accessions)?;
    sc.records = records;

    out!(
        "campaign: {} files ({}) on scenario '{}', coalesce < {} KiB, pipeline depth {}",
        sc.records.len(),
        fastbiodl::util::fmt_bytes(Catalog::total_bytes(&sc.records)),
        sc.name,
        sc.download.coalesce_files_kb,
        sc.download.pipeline_depth,
    );
    let tracer = build_tracer(&sc.download.trace)?;
    let outcome = match load_runtime() {
        Ok(rt) => run_tool_once_with_stats(&sc, &Tool::fastbiodl(&sc), &rt, seed, tracer.clone()),
        Err(e) => {
            log::warn!("XLA runtime unavailable ({e}); using pure-Rust mirror controllers");
            let controller =
                build_controller_with(&sc.download.optimizer, &sc.download.control, None)?;
            let mut session = SimSession::new(SimSessionParams {
                download: sc.download.clone(),
                behavior: ToolBehavior::fastbiodl(&sc.download),
                netsim: sc.netsim.clone(),
                records: sc.records.clone(),
                controller,
                runtime: None,
                seed,
            });
            if let Some(tr) = &tracer {
                session = session.with_tracer(tr.clone());
            }
            session.run_with_stats()
        }
    };
    if let Some(tr) = &tracer {
        write_trace(tr, &sc.download.trace)?;
    }
    let (report, stats) = outcome?;
    if let Some(path) = args.flag("report-json") {
        write_report_json(path, &report, Some(&stats))?;
    }
    print_report(&report, Some(&stats));
    if report.duration_s > 0.0 {
        out!(
            "files/sec       : {:.3}",
            report.files_completed as f64 / report.duration_s
        );
    }
    Ok(())
}

fn cmd_fetch(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "out", "chunk-mb", "probe", "c-max", "size", "optimizer", "k", "mirror-strategy",
        "mirror-conns", "reconcile", "fault-penalty", "adaptive-chunks", "progress-window",
        "progress-min-bytes", "sink-threads", "sink-queue-mb", "coalesce-kb", "verify",
        "reuse-local", "pipeline-depth", "report-json", "trace-out", "trace-format",
        "trace-capacity",
    ])?;
    if args.positional.is_empty() {
        return Err(Error::Config("fetch needs at least one http:// URL".into()));
    }
    let mut cfg = DownloadConfig::default();
    cfg.optimizer.c_max = 16;
    apply_optimizer_flags(&mut cfg, args)?;
    if let Some(w) = args.flag_f64("progress-window")? {
        cfg.progress_window_s = w;
    }
    if let Some(b) = args.flag_u64("progress-min-bytes")? {
        cfg.progress_min_bytes = b;
    }
    if let Some(n) = args.flag_usize("sink-threads")? {
        cfg.sink_threads = n;
    }
    if let Some(n) = args.flag_usize("sink-queue-mb")? {
        cfg.sink_queue_mb = n;
    }
    if let Some(n) = args.flag_usize("coalesce-kb")? {
        cfg.coalesce_kb = n;
    }
    cfg.validate()?;

    // Resolve sizes: --size override or a HEAD request.
    let mut records = Vec::new();
    for (i, url) in args.positional.iter().enumerate() {
        let bytes = match args.flag_u64("size")? {
            Some(b) => b,
            None => head_content_length(url)?,
        };
        vlog!("resolved {url}: {bytes} bytes");
        records.push(fastbiodl::accession::RunRecord::new(
            format!("URL{i:03}"),
            "fetch",
            bytes,
            url.clone(),
        ));
    }
    let rt = match load_runtime() {
        Ok(rt) => Some(rt),
        Err(e) => {
            log::warn!("XLA runtime unavailable ({e}); using pure-Rust mirror controllers");
            None
        }
    };
    let controller = build_controller_with(&cfg.optimizer, &cfg.control, rt.clone())?;
    let sink = match args.flag("out") {
        Some(dir) => Sink::Directory(dir.to_string()),
        None => Sink::Discard,
    };
    let trace_cfg = cfg.trace.clone();
    let tracer = build_tracer(&trace_cfg)?;
    let outcome = run_real_session_with_stats(RealSessionParams {
        download: cfg,
        records,
        controller,
        runtime: rt.as_deref(),
        sink,
        name: "fastbiodl".into(),
        tracer: tracer.clone(),
    });
    // Export the trace before propagating a session error: the
    // post-mortem record matters most on the failing runs.
    if let Some(tr) = &tracer {
        write_trace(tr, &trace_cfg)?;
    }
    let (report, stats) = outcome?;
    if let Some(path) = args.flag("report-json") {
        write_report_json(path, &report, Some(&stats))?;
    }
    print_report(&report, Some(&stats));
    Ok(())
}

/// Minimal HEAD request to discover Content-Length.
fn head_content_length(url: &str) -> Result<u64> {
    use std::io::{BufRead, BufReader, Write};
    let (host, port, path) = fastbiodl::transport::HttpConnection::split_url(url)?;
    let mut stream = std::net::TcpStream::connect((host.as_str(), port))
        .map_err(|e| Error::Transport(format!("connect {host}:{port}: {e}")))?;
    write!(
        stream,
        "HEAD {path} HTTP/1.1\r\nHost: {host}:{port}\r\nConnection: close\r\n\r\n"
    )
    .map_err(|e| Error::Transport(e.to_string()))?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line.map_err(|e| Error::Transport(e.to_string()))?;
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            return v
                .trim()
                .parse()
                .map_err(|_| Error::Transport("bad Content-Length".into()));
        }
        if line.is_empty() {
            break;
        }
    }
    Err(Error::Transport(format!(
        "{url}: no Content-Length in HEAD response (pass --size)"
    )))
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "files", "size-mb", "conn-mbps", "global-mbps", "ttfb", "faults", "seed", "horizon",
    ])?;
    let files = args.flag_usize("files")?.unwrap_or(4);
    let size_mb = args.flag_usize("size-mb")?.unwrap_or(64);
    let mut throttle = ThrottleConfig {
        per_conn_bytes_per_s: args.flag_f64("conn-mbps")?.unwrap_or(0.0) * 1e6 / 8.0,
        global_bytes_per_s: args.flag_f64("global-mbps")?.unwrap_or(0.0) * 1e6 / 8.0,
        first_byte_latency_s: args.flag_f64("ttfb")?.unwrap_or(0.0),
        ..ThrottleConfig::default()
    };
    // Replay a simulator fault profile on the loopback mirror: 5xx
    // windows and added latency, so `fetch` exercises the same
    // recovery machinery `download --faults` does in simulation.
    if let Some(profile) = args.flag("faults") {
        let profile = fastbiodl::netsim::FaultProfile::parse(profile).map_err(Error::Config)?;
        let seed = args.flag_u64("seed")?.unwrap_or(1);
        let horizon = args.flag_f64("horizon")?.unwrap_or(600.0);
        throttle = throttle.with_fault_profile(profile, seed, horizon);
        out!(
            "fault profile '{}': {} server-side windows over {horizon}s",
            profile.name(),
            throttle.fault_windows.len()
        );
    }
    let served: Vec<ServedFile> = (0..files)
        .map(|i| ServedFile {
            path: format!("/vol1/FILE{i:03}"),
            bytes: (size_mb as u64) * 1024 * 1024,
            seed: 7000 + i as u64,
        })
        .collect();
    let server = ThrottledHttpServer::start(served.clone(), throttle)?;
    out!(
        "serving {} files of {} MiB at {}",
        files,
        size_mb,
        server.base_url()
    );
    for f in &served {
        out!("  {}{}", server.base_url(), f.path);
    }
    out!("press Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    args.expect_flags(&["runs", "seed"])?;
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let runs = args.flag_usize("runs")?.unwrap_or(5);
    let seed = args.flag_u64("seed")?.unwrap_or(1000);
    let rt = load_runtime()?;

    let run_one = |id: &str| -> Result<()> {
        out!("\n=== {id} ===");
        match id {
            "fig1" => {
                let r = fig1::run(120.0, seed)?;
                out!("available  {}", sparkline(&r.available_mbps, 64));
                out!("single     {}", sparkline(&r.single_stream_mbps, 64));
                out!(
                    "single stream {:.0} / available {:.0} Mbps ({:.0}% used)",
                    r.mean_single,
                    r.mean_available,
                    r.utilization() * 100.0
                );
            }
            "fig2" => {
                let r = fig2::run(120.0, seed)?;
                out!("available  {}", sparkline(&r.available_mbps, 64));
                out!(
                    "mean {:.0} ± {:.0} Mbps, range {:.0}–{:.0}",
                    r.mean, r.std, r.min, r.max
                );
            }
            "table1" => {
                let rows = table1::run(&rt, runs, seed)?;
                let mut t = Table::new(vec!["K", "Speed (Mbps)", "Concurrency"]);
                for r in &rows {
                    t.row(vec![
                        format!("{:.2}", r.k),
                        r.summary.speed_mbps.to_string(),
                        r.summary.concurrency.to_string(),
                    ]);
                }
                out!("{}", t.render());
                table1::check_shape(&rows).map_err(Error::Session)?;
            }
            "table3" => {
                let rows = table3::run(&rt, runs, seed)?;
                let mut t = Table::new(vec!["Dataset", "Tool", "Concurrency", "Speed (Mbps)"]);
                for r in &rows {
                    for s in [&r.prefetch, &r.pysradb, &r.fastbiodl] {
                        t.row(vec![
                            r.dataset.to_string(),
                            s.tool.clone(),
                            s.concurrency.to_string(),
                            s.speed_mbps.to_string(),
                        ]);
                    }
                }
                out!("{}", t.render());
                table3::check_shape(&rows).map_err(Error::Session)?;
            }
            "fig4" => {
                let r = fig4::run(&rt, runs, seed)?;
                out!(
                    "gd {:.1}s vs bayes {:.1}s -> bayes {:.0}% slower",
                    r.gd.duration_s.mean,
                    r.bayes.duration_s.mean,
                    (r.bayes_slowdown() - 1.0) * 100.0
                );
                fig4::check_shape(&r).map_err(Error::Session)?;
            }
            "fig5" => {
                let r = fig5::run(&rt, runs, seed)?;
                for band in [&r.fastbiodl, &r.prefetch, &r.pysradb] {
                    out!(
                        "{:<10} peak {:>6.0} Mbps  done {:>6.1}s  {}",
                        band.tool,
                        band.peak(),
                        band.completion_s(),
                        sparkline(&band.mean, 48)
                    );
                }
                fig5::check_shape(&r).map_err(Error::Session)?;
            }
            "fig6" => {
                let rows = fig6::run(&rt, runs, seed)?;
                for r in &rows {
                    out!(
                        "{:<9} C*={:>5.1}  adaptive {:.0} Mbps  vs fixed-5 {:.2}x  vs fixed-3 {:.2}x",
                        r.scenario,
                        r.c_star,
                        r.adaptive.speed_mbps.mean,
                        r.speedup_vs_fixed5(),
                        r.speedup_vs_fixed3()
                    );
                }
                fig6::check_shape(&rows).map_err(Error::Session)?;
            }
            other => {
                return Err(Error::Config(format!(
                    "unknown experiment '{other}' (table1|table3|fig1|fig2|fig4|fig5|fig6|all)"
                )));
            }
        }
        Ok(())
    };

    if which == "all" {
        for id in ["fig1", "fig2", "table1", "fig4", "table3", "fig5", "fig6"] {
            run_one(id)?;
        }
    } else {
        run_one(which)?;
    }
    Ok(())
}

fn cmd_utility_surface(args: &Args) -> Result<()> {
    args.expect_flags(&["k"])?;
    let k = args.flag_f64("k")?.unwrap_or(1.02);
    if k <= 1.0 {
        return Err(Error::Config("k must be > 1".into()));
    }
    let rt = load_runtime()?;
    let g = rt.constants().grid;
    let t_grid: Vec<f32> = (0..g).map(|i| 100.0 * (i + 1) as f32).collect();
    let c_grid: Vec<f32> = (1..=g).map(|i| i as f32).collect();
    let surf = rt.utility_surface(&t_grid, &c_grid, k as f32)?;
    out!(
        "U(T, C) = T / {k}^C    (C* = 1/ln k = {:.1})",
        1.0 / k.ln()
    );
    for &row in &[7usize, 15, 31, 63] {
        let vals: Vec<f64> = (0..g).map(|j| surf[row * g + j] as f64).collect();
        out!("T={:<6} {}", t_grid[row], sparkline(&vals, 64));
    }
    Ok(())
}

fn print_report(r: &SessionReport, stats: Option<&EngineStats>) {
    out!();
    out!("tool            : {}", r.tool);
    out!("duration        : {}", fastbiodl::util::fmt_secs(r.duration_s));
    out!("bytes           : {}", fastbiodl::util::fmt_bytes(r.total_bytes));
    out!("mean throughput : {:.1} Mbps", r.mean_throughput_mbps);
    out!("peak throughput : {:.1} Mbps", r.peak_mbps);
    out!(
        "mean concurrency: {:.2} (in-flight {:.2})",
        r.mean_concurrency, r.mean_inflight
    );
    out!("files completed : {}", r.files_completed);
    if r.chunk_retries > 0 {
        out!(
            "recovery        : {} chunk retries ({} connection resets, {} server errors)",
            r.chunk_retries, r.connection_resets, r.server_rejects
        );
    }
    if r.hash_mismatches > 0 {
        out!(
            "integrity       : {} corrupt chunks discarded and re-fetched",
            r.hash_mismatches
        );
    }
    if r.mirror_bytes.len() > 1 {
        let shares: Vec<String> = r
            .mirror_bytes
            .iter()
            .enumerate()
            .map(|(m, b)| format!("m{m}={}", fastbiodl::util::fmt_bytes(*b)))
            .collect();
        out!(
            "mirrors         : {} ({} failovers)",
            shares.join(", "),
            r.mirror_switches
        );
    }
    if let Some(st) = stats {
        out!(
            "disk path       : {} write syscalls, sink queue peak {}, reactor stalls {:.1} ms",
            st.write_syscalls,
            fastbiodl::util::fmt_bytes(st.sink_queue_peak),
            st.reactor_stall_ns as f64 / 1e6
        );
    }
    out!("optimizer probes: {}", r.probes);
    out!("throughput      : {}", sparkline(&r.timeline.values, 64));
    if r.concurrency_trace.len() > 1 {
        let cs: Vec<f64> = r.concurrency_trace.iter().map(|&(_, c)| c as f64).collect();
        out!("concurrency     : {}", sparkline(&cs, 64));
    }
}
