//! TOML-subset config-file loader.
//!
//! Offline stand-in for the `toml` crate. Supports the subset real
//! deployments need: `[section]` headers, `key = value` with string /
//! float / integer / bool scalars, `#` comments, and flat arrays of
//! scalars. No nested tables-in-arrays, no multi-line strings — config
//! files here are knobs, not documents.
//!
//! ```toml
//! # fastbiodl.toml
//! [optimizer]
//! kind = "gd"
//! k = 1.02
//! probe_interval_s = 5.0
//!
//! [download]
//! chunk_bytes = 33554432
//! max_open_files = 4
//! sink_threads = 2          # 0 = inline writes on the reactor
//! sink_queue_mb = 64        # pooled write-buffer budget
//! coalesce_kb = 1024        # max bytes merged per positional write
//!
//! [mirror]
//! strategy = "stripe"       # or "failover" (winner-take-all)
//! per_mirror_conns = 4      # 0 = unlimited
//! stripe_floor = 0.05
//!
//! [control]
//! fault_penalty = 0.0       # weight of the utility fault penalty
//! adaptive_chunks = false   # striping-aware chunk sizing
//! chunk_scale_min = 0.25    # floor of the adaptive chunk scale
//!
//! [integrity]
//! verify = false            # per-chunk SHA-256 verification
//! reuse_local = false       # delta resume: rehash + reuse disk chunks
//!
//! [trace]
//! out = "run.jsonl"         # flight-recorder export path (unset = off)
//! format = "ndjson"         # or "chrome" (Perfetto / chrome://tracing)
//! capacity = 65536          # ring-buffer capacity, in records
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::{DownloadConfig, MirrorStrategy, OptimizerKind, TraceFormat};
use crate::{Error, Result};

/// A scalar config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parsed file: `section.key → value`. Keys before any `[section]`
/// live in the "" section.
#[derive(Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, Value>,
}

impl TomlDoc {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| bad(lineno, "unterminated [section]"))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(bad(lineno, "empty section name"));
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| bad(lineno, "expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(bad(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.values.insert(full, value);
        }
        Ok(doc)
    }

    /// Read + parse a file.
    pub fn load(path: &Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    pub fn get(&self, dotted_key: &str) -> Option<&Value> {
        self.values.get(dotted_key)
    }

    /// All keys (for unknown-key warnings).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn bad(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("config line {}: {msg}", lineno + 1))
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s.is_empty() {
        return Err(bad(lineno, "empty value"));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| bad(lineno, "unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| bad(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, lineno)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| bad(lineno, &format!("cannot parse value '{s}'")))
}

fn split_array_items(s: &str) -> Vec<String> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                items.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        items.push(cur);
    }
    items
}

/// Overlay a parsed file onto a [`DownloadConfig`].
pub fn apply_to_config(doc: &TomlDoc, cfg: &mut DownloadConfig) -> Result<()> {
    let known_prefixes = [
        "optimizer.",
        "download.",
        "mirror.",
        "control.",
        "integrity.",
        "trace.",
    ];
    for key in doc.keys() {
        if !known_prefixes.iter().any(|p| key.starts_with(p)) {
            return Err(Error::Config(format!(
                "unknown config key '{key}' \
                 (sections: [optimizer], [download], [mirror], [control], [integrity], [trace])"
            )));
        }
    }

    macro_rules! f64_opt {
        ($key:expr, $slot:expr) => {
            if let Some(v) = doc.get($key) {
                $slot = v
                    .as_f64()
                    .ok_or_else(|| Error::Config(format!("'{}' must be a number", $key)))?;
            }
        };
    }
    macro_rules! usize_opt {
        ($key:expr, $slot:expr) => {
            if let Some(v) = doc.get($key) {
                $slot = v.as_usize().ok_or_else(|| {
                    Error::Config(format!("'{}' must be a non-negative integer", $key))
                })?;
            }
        };
    }

    if let Some(v) = doc.get("optimizer.kind") {
        let s = v
            .as_str()
            .ok_or_else(|| Error::Config("'optimizer.kind' must be a string".into()))?;
        cfg.optimizer.kind = OptimizerKind::parse(s)?;
    }
    f64_opt!("optimizer.k", cfg.optimizer.k);
    f64_opt!("optimizer.probe_interval_s", cfg.optimizer.probe_interval_s);
    f64_opt!("optimizer.lr", cfg.optimizer.lr);
    f64_opt!("optimizer.step_clip", cfg.optimizer.step_clip);
    usize_opt!("optimizer.c_min", cfg.optimizer.c_min);
    usize_opt!("optimizer.c_max", cfg.optimizer.c_max);
    usize_opt!("optimizer.c_init", cfg.optimizer.c_init);
    usize_opt!("optimizer.fixed_level", cfg.optimizer.fixed_level);
    f64_opt!("optimizer.bayes_lengthscale", cfg.optimizer.bayes_lengthscale);
    f64_opt!("optimizer.bayes_noise", cfg.optimizer.bayes_noise);
    f64_opt!("optimizer.bayes_xi", cfg.optimizer.bayes_xi);
    f64_opt!("optimizer.history_half_life", cfg.optimizer.history_half_life);

    if let Some(v) = doc.get("download.chunk_bytes") {
        cfg.chunk_bytes = v
            .as_u64()
            .ok_or_else(|| Error::Config("'download.chunk_bytes' must be an integer".into()))?;
    }
    f64_opt!("download.monitor_hz", cfg.monitor_hz);
    usize_opt!("download.max_open_files", cfg.max_open_files);
    f64_opt!("download.timeout_s", cfg.timeout_s);
    f64_opt!("download.progress_window_s", cfg.progress_window_s);
    if let Some(v) = doc.get("download.progress_min_bytes") {
        cfg.progress_min_bytes = v.as_u64().ok_or_else(|| {
            Error::Config("'download.progress_min_bytes' must be an integer".into())
        })?;
    }
    usize_opt!("download.sink_threads", cfg.sink_threads);
    usize_opt!("download.sink_queue_mb", cfg.sink_queue_mb);
    usize_opt!("download.coalesce_kb", cfg.coalesce_kb);
    if let Some(v) = doc.get("download.output_dir") {
        cfg.output_dir = v
            .as_str()
            .ok_or_else(|| Error::Config("'download.output_dir' must be a string".into()))?
            .to_string();
    }

    if let Some(v) = doc.get("mirror.strategy") {
        let s = v
            .as_str()
            .ok_or_else(|| Error::Config("'mirror.strategy' must be a string".into()))?;
        cfg.mirror.strategy = MirrorStrategy::parse(s)?;
    }
    usize_opt!("mirror.per_mirror_conns", cfg.mirror.per_mirror_conns);
    f64_opt!("mirror.stripe_floor", cfg.mirror.stripe_floor);

    f64_opt!("control.fault_penalty", cfg.control.fault_penalty);
    f64_opt!("control.chunk_scale_min", cfg.control.chunk_scale_min);
    if let Some(v) = doc.get("control.adaptive_chunks") {
        cfg.control.adaptive_chunks = match v {
            Value::Bool(b) => *b,
            _ => {
                return Err(Error::Config(
                    "'control.adaptive_chunks' must be a boolean".into(),
                ))
            }
        };
    }

    let mut bool_opt = |key: &str, slot: &mut bool| -> Result<()> {
        if let Some(v) = doc.get(key) {
            *slot = match v {
                Value::Bool(b) => *b,
                _ => return Err(Error::Config(format!("'{key}' must be a boolean"))),
            };
        }
        Ok(())
    };
    bool_opt("integrity.verify", &mut cfg.integrity.verify)?;
    bool_opt("integrity.reuse_local", &mut cfg.integrity.reuse_local)?;

    if let Some(v) = doc.get("trace.out") {
        cfg.trace.out = Some(
            v.as_str()
                .ok_or_else(|| Error::Config("'trace.out' must be a string".into()))?
                .to_string(),
        );
    }
    if let Some(v) = doc.get("trace.format") {
        let s = v
            .as_str()
            .ok_or_else(|| Error::Config("'trace.format' must be a string".into()))?;
        cfg.trace.format = TraceFormat::parse(s)?;
    }
    usize_opt!("trace.capacity", cfg.trace.capacity);
    Ok(())
}

/// Load a config file and overlay it onto defaults.
pub fn load_config(path: &Path) -> Result<DownloadConfig> {
    let doc = TomlDoc::load(path)?;
    let mut cfg = DownloadConfig::default();
    apply_to_config(&doc, &mut cfg)?;
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            r#"
            # top comment
            [optimizer]
            kind = "bayes"   # inline comment
            k = 1.05
            c_max = 32

            [download]
            output_dir = "/tmp/x"
            chunk_bytes = 1_048_576
            flag = true
            arr = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("optimizer.kind").unwrap().as_str(), Some("bayes"));
        assert_eq!(doc.get("optimizer.k").unwrap().as_f64(), Some(1.05));
        assert_eq!(doc.get("download.chunk_bytes").unwrap().as_u64(), Some(1_048_576));
        assert_eq!(doc.get("download.flag"), Some(&Value::Bool(true)));
        assert_eq!(
            doc.get("download.arr"),
            Some(&Value::Arr(vec![
                Value::Num(1.0),
                Value::Num(2.0),
                Value::Num(3.0)
            ]))
        );
    }

    #[test]
    fn hash_in_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"key = "a#b""##).unwrap();
        assert_eq!(doc.get("key").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn overlay_and_validate() {
        let doc = TomlDoc::parse(
            r#"
            [optimizer]
            kind = "gd"
            k = 1.01
            probe_interval_s = 3.0
            [download]
            max_open_files = 2
            sink_threads = 4
            sink_queue_mb = 16
            coalesce_kb = 512
            "#,
        )
        .unwrap();
        let mut cfg = DownloadConfig::default();
        apply_to_config(&doc, &mut cfg).unwrap();
        assert_eq!(cfg.optimizer.k, 1.01);
        assert_eq!(cfg.optimizer.probe_interval_s, 3.0);
        assert_eq!(cfg.max_open_files, 2);
        assert_eq!(cfg.sink_threads, 4);
        assert_eq!(cfg.sink_queue_mb, 16);
        assert_eq!(cfg.coalesce_kb, 512);
        cfg.validate().unwrap();
    }

    #[test]
    fn control_section_overlays() {
        let doc = TomlDoc::parse(
            r#"
            [control]
            fault_penalty = 1.5
            adaptive_chunks = true
            chunk_scale_min = 0.5
            "#,
        )
        .unwrap();
        let mut cfg = DownloadConfig::default();
        apply_to_config(&doc, &mut cfg).unwrap();
        assert_eq!(cfg.control.fault_penalty, 1.5);
        assert!(cfg.control.adaptive_chunks);
        assert_eq!(cfg.control.chunk_scale_min, 0.5);
        cfg.validate().unwrap();
        // Type error: adaptive_chunks must be a boolean.
        let doc = TomlDoc::parse("[control]\nadaptive_chunks = 1.0").unwrap();
        let mut cfg = DownloadConfig::default();
        assert!(apply_to_config(&doc, &mut cfg).is_err());
    }

    #[test]
    fn integrity_section_overlays() {
        let doc = TomlDoc::parse("[integrity]\nverify = true\nreuse_local = true").unwrap();
        let mut cfg = DownloadConfig::default();
        apply_to_config(&doc, &mut cfg).unwrap();
        assert!(cfg.integrity.verify);
        assert!(cfg.integrity.reuse_local);
        cfg.validate().unwrap();
        // Type error: the knobs are booleans.
        let doc = TomlDoc::parse("[integrity]\nverify = 1.0").unwrap();
        let mut cfg = DownloadConfig::default();
        assert!(apply_to_config(&doc, &mut cfg).is_err());
    }

    #[test]
    fn trace_section_overlays() {
        let doc = TomlDoc::parse(
            r#"
            [trace]
            out = "run.jsonl"
            format = "chrome"
            capacity = 1024
            "#,
        )
        .unwrap();
        let mut cfg = DownloadConfig::default();
        apply_to_config(&doc, &mut cfg).unwrap();
        assert_eq!(cfg.trace.out.as_deref(), Some("run.jsonl"));
        assert_eq!(cfg.trace.format, TraceFormat::Chrome);
        assert_eq!(cfg.trace.capacity, 1024);
        cfg.validate().unwrap();
        // Type errors: out/format are strings, capacity an integer.
        let doc = TomlDoc::parse("[trace]\nout = true").unwrap();
        let mut cfg = DownloadConfig::default();
        assert!(apply_to_config(&doc, &mut cfg).is_err());
        let doc = TomlDoc::parse("[trace]\nformat = \"svg\"").unwrap();
        let mut cfg = DownloadConfig::default();
        assert!(apply_to_config(&doc, &mut cfg).is_err());
        let doc = TomlDoc::parse("[trace]\ncapacity = \"big\"").unwrap();
        let mut cfg = DownloadConfig::default();
        assert!(apply_to_config(&doc, &mut cfg).is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = TomlDoc::parse("[optimzer]\nk = 1.02").unwrap();
        let mut cfg = DownloadConfig::default();
        let err = apply_to_config(&doc, &mut cfg).unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn type_errors_reported() {
        let doc = TomlDoc::parse("[optimizer]\nk = \"high\"").unwrap();
        let mut cfg = DownloadConfig::default();
        assert!(apply_to_config(&doc, &mut cfg).is_err());
    }

    #[test]
    fn parse_errors_have_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nnot a kv line").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }
}
