//! Configuration system: typed knobs, a TOML-subset file format, and
//! environment overrides.
//!
//! Precedence (lowest → highest): built-in defaults → config file
//! (`--config path.toml`) → `FASTBIODL_*` environment variables → CLI
//! flags. Everything validates before a transfer starts; invalid
//! combinations fail with precise messages rather than mid-download.

pub mod cli;
pub mod file;

use crate::{Error, Result};

/// Which concurrency controller drives the transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Paper's chosen controller: online gradient descent on `-U`.
    GradientDescent,
    /// In-paper baseline: GP surrogate + expected improvement.
    Bayesian,
    /// Static concurrency (the baseline tools' behaviour).
    Fixed,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gd" | "gradient" | "gradient-descent" => Ok(OptimizerKind::GradientDescent),
            "bayes" | "bayesian" | "bo" => Ok(OptimizerKind::Bayesian),
            "fixed" | "static" => Ok(OptimizerKind::Fixed),
            other => Err(Error::Config(format!(
                "unknown optimizer '{other}' (expected gd | bayes | fixed)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::GradientDescent => "gradient-descent",
            OptimizerKind::Bayesian => "bayesian",
            OptimizerKind::Fixed => "fixed",
        }
    }
}

/// Controller hyper-parameters (paper §4.1–4.2).
#[derive(Clone, Debug)]
pub struct OptimizerConfig {
    /// Which controller to run.
    pub kind: OptimizerKind,
    /// Utility penalty coefficient `k` (> 1). Paper default 1.02
    /// (Table 1 selects it over 1.01 / 1.05).
    pub k: f64,
    /// Probing interval (s): how long each concurrency level is
    /// measured before the optimizer updates. Paper: 3 s default,
    /// 5 s in the evaluation.
    pub probe_interval_s: f64,
    /// Gradient-descent learning rate (unitless — the step is
    /// normalized by the window's mean utility; see `compile.model`).
    pub lr: f64,
    /// Max |Δconcurrency| per probe.
    pub step_clip: f64,
    /// Concurrency bounds.
    pub c_min: usize,
    pub c_max: usize,
    /// Initial concurrency (paper: starts at 1).
    pub c_init: usize,
    /// Fixed level (only for `OptimizerKind::Fixed`).
    pub fixed_level: usize,
    /// GP lengthscale / noise / EI ξ (Bayesian controller only).
    pub bayes_lengthscale: f64,
    pub bayes_noise: f64,
    pub bayes_xi: f64,
    /// Probe-history recency half-life, in probes (weights the GD
    /// window; older probes decay by 2^(-age/half_life)).
    pub history_half_life: f64,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        OptimizerConfig {
            kind: OptimizerKind::GradientDescent,
            k: 1.02,
            probe_interval_s: 5.0,
            lr: 3.0,
            step_clip: 4.0,
            c_min: 1,
            c_max: 64,
            c_init: 1,
            fixed_level: 3,
            bayes_lengthscale: 4.0,
            bayes_noise: 1e-3,
            bayes_xi: 0.01,
            history_half_life: 4.0,
        }
    }
}

impl OptimizerConfig {
    pub fn validate(&self) -> Result<()> {
        if self.k <= 1.0 {
            return Err(Error::Config(format!(
                "k must be > 1 (got {}); k^C must penalize concurrency",
                self.k
            )));
        }
        if self.probe_interval_s <= 0.0 {
            return Err(Error::Config("probe_interval_s must be > 0".into()));
        }
        if self.c_min < 1 || self.c_min > self.c_max {
            return Err(Error::Config(format!(
                "bad concurrency bounds [{}, {}]",
                self.c_min, self.c_max
            )));
        }
        if self.c_max > 65536 {
            // The engine's slot table is sparse and the real driver is
            // event-driven, so large pools are cheap — but a c_max past
            // every sane fd limit is a config typo, not a workload.
            // (The Bayesian controller's *proposals* are additionally
            // capped by the artifact's 64-point candidate grid
            // regardless of c_max; GD and Fixed scale to the full
            // pool.)
            return Err(Error::Config(format!(
                "c_max {} unreasonably large (max 65536)",
                self.c_max
            )));
        }
        if !(self.c_min..=self.c_max).contains(&self.c_init) {
            return Err(Error::Config(format!(
                "c_init {} outside [{}, {}]",
                self.c_init, self.c_min, self.c_max
            )));
        }
        if self.lr <= 0.0 || self.step_clip <= 0.0 {
            return Err(Error::Config("lr and step_clip must be > 0".into()));
        }
        if self.bayes_lengthscale <= 0.0 || self.bayes_noise <= 0.0 {
            return Err(Error::Config("bayes lengthscale/noise must be > 0".into()));
        }
        if self.history_half_life <= 0.0 {
            return Err(Error::Config("history_half_life must be > 0".into()));
        }
        Ok(())
    }

    /// Theoretical concurrency ceiling `C* = 1 / ln k` (paper §4.1).
    pub fn c_star(&self) -> f64 {
        1.0 / self.k.ln()
    }
}

/// Fault-aware control-plane knobs (see [`crate::control`]): how much
/// the adaptive controllers penalize fault telemetry, and whether the
/// engine scales chunk sizes down under fault pressure. Both default
/// to **off**, which keeps every benign, single-mirror, and
/// paper-figure run bit-identical to the fault-blind controllers.
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// Weight of the fault-penalty term in the adaptive utilities: the
    /// probe-window goodput is discounted by
    /// `1 + fault_penalty × weighted_fault_rate` before it enters the
    /// §4.1 utility (see [`crate::control::discounted_goodput`]).
    /// `0.0` (the default) disables the term entirely — the goodput
    /// passes through bit-identically.
    pub fault_penalty: f64,
    /// Striping-aware chunk sizing: controllers emit a chunk scale from
    /// fault pressure ([`crate::control::chunk_scale`]) and the engine
    /// shrinks chunks cut for slots bound to degraded mirrors, so a
    /// probe chunk on a crawling mirror stops tying a slot up for many
    /// seconds. Off by default.
    pub adaptive_chunks: bool,
    /// Floor of every chunk scale, in `(0, 1]`: chunks never shrink
    /// below `chunk_scale_min × chunk_bytes` (and never below the
    /// scheduler's 64 KiB absolute minimum).
    pub chunk_scale_min: f64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            fault_penalty: 0.0,
            adaptive_chunks: false,
            chunk_scale_min: 0.25,
        }
    }
}

impl ControlConfig {
    /// Parameter sanity.
    pub fn validate(&self) -> Result<()> {
        if !(self.fault_penalty >= 0.0 && self.fault_penalty.is_finite()) {
            return Err(Error::Config(format!(
                "fault_penalty {} must be finite and >= 0",
                self.fault_penalty
            )));
        }
        if !(self.chunk_scale_min > 0.0 && self.chunk_scale_min <= 1.0) {
            return Err(Error::Config(format!(
                "chunk_scale_min {} outside (0, 1]",
                self.chunk_scale_min
            )));
        }
        Ok(())
    }
}

/// Chunk-integrity knobs (see [`crate::coordinator::manifest`]): per-chunk
/// SHA-256 verification with a persisted manifest, and delta resume that
/// harvests verified chunks from local partial files. Both default to
/// **off**, which keeps every existing run bit-identical to the
/// hash-free engine (pinned by `engine_parity` and the bench baseline).
#[derive(Clone, Debug, Default)]
pub struct IntegrityConfig {
    /// Hash every completed chunk (sink writer threads on the real
    /// path, the byte-stream model in the sim), verify against the
    /// manifest, and re-fetch on mismatch. Persists
    /// `.fastbiodl-manifest` next to the journal.
    pub verify: bool,
    /// At cold start, rehash candidate chunks of existing output files
    /// against the manifest and reuse every verified chunk instead of
    /// trusting the journal frontier (or discarding a foreign partial
    /// file). Requires `verify`.
    pub reuse_local: bool,
}

impl IntegrityConfig {
    /// Parameter sanity.
    pub fn validate(&self) -> Result<()> {
        if self.reuse_local && !self.verify {
            return Err(Error::Config(
                "reuse_local requires verify (chunk reuse is meaningless without hashes)".into(),
            ));
        }
        Ok(())
    }
}

/// Trace export format (see [`crate::trace`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceFormat {
    /// Versioned NDJSON (`fastbiodl-trace-v1`): one header line, one
    /// compact JSON object per event. The default.
    #[default]
    Ndjson,
    /// Chrome `trace_event` JSON, viewable in Perfetto or
    /// `chrome://tracing`.
    Chrome,
}

impl TraceFormat {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ndjson" | "jsonl" => Ok(TraceFormat::Ndjson),
            "chrome" | "trace-event" | "perfetto" => Ok(TraceFormat::Chrome),
            other => Err(Error::Config(format!(
                "unknown trace format '{other}' (expected ndjson | chrome)"
            ))),
        }
    }

    /// Canonical name (the `--trace-format` spelling).
    pub fn name(&self) -> &'static str {
        match self {
            TraceFormat::Ndjson => "ndjson",
            TraceFormat::Chrome => "chrome",
        }
    }
}

/// Flight-recorder knobs (see [`crate::trace`]). Default is **off**
/// (`out: None`): no recorder is constructed and every session is
/// bit-identical to the untraced engine (pinned by
/// `rust/tests/trace_events.rs`).
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Trace output path (`--trace-out`). `None` disables tracing.
    pub out: Option<String>,
    /// Export format for the file at [`Self::out`].
    pub format: TraceFormat,
    /// Ring-buffer capacity in records; the oldest records are
    /// overwritten (and counted) once the session exceeds it.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            out: None,
            format: TraceFormat::Ndjson,
            capacity: crate::trace::DEFAULT_CAPACITY,
        }
    }
}

impl TraceConfig {
    /// Whether a recorder should be constructed.
    pub fn enabled(&self) -> bool {
        self.out.is_some()
    }

    /// Parameter sanity.
    pub fn validate(&self) -> Result<()> {
        if !(16..=16_777_216).contains(&self.capacity) {
            return Err(Error::Config(format!(
                "trace capacity {} outside [16, 16777216]",
                self.capacity
            )));
        }
        if let Some(out) = &self.out {
            if out.is_empty() {
                return Err(Error::Config("trace out path must not be empty".into()));
            }
        }
        Ok(())
    }
}

/// How the session engine reconciles its worker-slot pool against the
/// shared [`crate::coordinator::pool::StatusArray`] each control tick.
///
/// The engine is the status array's only writer during a session (one
/// batched `set_target` per probe), so the RUNNING set is always the
/// prefix `0..target` — which the engine knows without touching the
/// atomics. [`ReconcileMode::Batched`] exploits that: the per-tick
/// reconcile/rebalance/assign passes walk only the live prefix plus a
/// drain watermark of slots still winding down after a target shrink,
/// instead of scanning all `c_max` slots through atomic loads. At
/// `c_max = 256` with a typical target of a few dozen this removes the
/// bulk of the control-loop cost (measured by `fastbiodl bench`; see
/// `docs/ARCHITECTURE.md` §Benchmarking).
///
/// [`ReconcileMode::FullScan`] keeps the naive full-pool scan as a
/// reference implementation: `rust/tests/engine_tick.rs` proves both
/// modes produce identical slot assignments and byte-for-byte identical
/// [`crate::session::SessionReport`]s across random fault schedules.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReconcileMode {
    /// Naive reference: scan every slot `0..c_max` each tick, reading
    /// the status array per slot.
    FullScan,
    /// Watermark reconciliation against the engine's prefix view of the
    /// status array (the default).
    #[default]
    Batched,
}

impl ReconcileMode {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "full-scan" | "fullscan" | "full" | "naive" => Ok(ReconcileMode::FullScan),
            "batched" | "batch" | "incremental" => Ok(ReconcileMode::Batched),
            other => Err(Error::Config(format!(
                "unknown reconcile mode '{other}' (expected batched | full-scan)"
            ))),
        }
    }

    /// Canonical name (the `--reconcile` spelling).
    pub fn name(&self) -> &'static str {
        match self {
            ReconcileMode::FullScan => "full-scan",
            ReconcileMode::Batched => "batched",
        }
    }
}

/// How the session engine schedules work across a record's mirror list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MirrorStrategy {
    /// Winner-take-all binding (the PR 2 behaviour, kept as a baseline):
    /// every (re)connecting slot binds to the best-scoring mirror and
    /// only abandons it when its score collapses relative to the best.
    Failover,
    /// Score-weighted striping (the default): connections are spread
    /// across healthy mirrors in proportion to their
    /// [`crate::session::mirrors::MirrorBoard`] goodput scores, capped
    /// per mirror, with periodic re-probes of idle/degraded mirrors so
    /// a healed endpoint is re-admitted.
    WeightedStripe,
}

impl MirrorStrategy {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "failover" | "winner-take-all" | "wta" => Ok(MirrorStrategy::Failover),
            "stripe" | "striping" | "weighted" | "weighted-stripe" => {
                Ok(MirrorStrategy::WeightedStripe)
            }
            other => Err(Error::Config(format!(
                "unknown mirror strategy '{other}' (expected stripe | failover)"
            ))),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            MirrorStrategy::Failover => "failover",
            MirrorStrategy::WeightedStripe => "stripe",
        }
    }
}

/// Multi-mirror scheduling knobs (see [`crate::session::mirrors`]).
#[derive(Clone, Debug)]
pub struct MirrorPolicy {
    /// Scheduling strategy across a record's mirror list.
    pub strategy: MirrorStrategy,
    /// Max simultaneous connections a session holds to one mirror
    /// (0 = unlimited). Enforced centrally by the engine's picker and
    /// again by both transports (netsim flow table, real worker
    /// bindings) as defense in depth.
    pub per_mirror_conns: usize,
    /// Weight floor, as a fraction of the best mirror's score, applied
    /// when striping: a degraded (but previously working) mirror's
    /// weight never falls below `floor × best`, so it keeps receiving
    /// occasional chunks and its goodput estimate can recover after it
    /// heals. Mirrors that have only ever failed sit below the floor
    /// and are re-admitted via the periodic re-probe instead.
    pub stripe_floor: f64,
}

impl Default for MirrorPolicy {
    fn default() -> Self {
        MirrorPolicy {
            strategy: MirrorStrategy::WeightedStripe,
            per_mirror_conns: 0,
            stripe_floor: 0.05,
        }
    }
}

impl MirrorPolicy {
    /// Parameter sanity.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=0.5).contains(&self.stripe_floor) {
            return Err(Error::Config(format!(
                "stripe_floor {} outside [0, 0.5]",
                self.stripe_floor
            )));
        }
        Ok(())
    }
}

/// Whole-transfer configuration.
#[derive(Clone, Debug)]
pub struct DownloadConfig {
    pub optimizer: OptimizerConfig,
    /// Multi-mirror scheduling policy.
    pub mirror: MirrorPolicy,
    /// Fault-aware control-plane knobs (fault penalty, adaptive chunk
    /// sizing); defaults keep the fault-blind behaviour.
    pub control: ControlConfig,
    /// Chunk-integrity knobs (per-chunk SHA-256 verification, delta
    /// resume with local chunk reuse); defaults keep the hash-free
    /// behaviour.
    pub integrity: IntegrityConfig,
    /// Flight-recorder knobs (event tracing); default off keeps every
    /// session bit-identical to the untraced engine.
    pub trace: TraceConfig,
    /// Worker-slot pool reconciliation strategy (see [`ReconcileMode`];
    /// `FullScan` exists as the measured baseline for `fastbiodl bench`
    /// and the equivalence tests).
    pub reconcile: ReconcileMode,
    /// Range-request chunk size (bytes). Files smaller than one chunk
    /// download in a single request.
    pub chunk_bytes: u64,
    /// Monitor sampling rate (Hz) — instantaneous throughput samples
    /// per second feeding the probe window.
    pub monitor_hz: f64,
    /// Max distinct files in flight at once (chunked scheduling keeps
    /// this small to bound sink-side interleaving; see netsim::client).
    pub max_open_files: usize,
    /// Output directory for downloaded payloads (real transport only).
    pub output_dir: String,
    /// Abort the whole transfer after this much time (s); 0 = no limit.
    pub timeout_s: f64,
    /// Whole-chunk progress deadline window (s), real transport only: a
    /// connection that moves fewer than [`Self::progress_min_bytes`]
    /// in one window is failed as a retryable transport error (the
    /// defense against servers dribbling a byte every few seconds,
    /// which per-read socket timeouts never catch). 0 disables.
    pub progress_window_s: f64,
    /// Minimum bytes a connection must move per progress window.
    pub progress_min_bytes: u64,
    /// Dedicated sink writer threads landing payload bytes with
    /// coalesced positional writes (real transport only). 0 keeps
    /// writes inline on the reactor threads (the pre-sink legacy
    /// behaviour, also the measured baseline in perf tests).
    pub sink_threads: usize,
    /// Total pooled payload-buffer budget (MiB) — the bound on sink
    /// memory; a dry pool parks connections (backpressure) instead of
    /// queuing unbounded.
    pub sink_queue_mb: usize,
    /// Maximum bytes merged into one positional write (KiB).
    pub coalesce_kb: usize,
    /// Campaign mode: schedule the record set through
    /// [`crate::coordinator::scheduler::SchedulerMode::Campaign`] —
    /// files at or below [`Self::coalesce_files_kb`] coalesce into
    /// pipelined whole-file request trains while larger files keep
    /// chunked striping. Off by default (byte-identical to the
    /// pre-campaign engine).
    pub campaign: bool,
    /// Max HTTP/1.1 requests on the wire per connection
    /// (`--pipeline-depth`). 1 = no pipelining, today's behaviour;
    /// higher depths amortize request round-trips and cold-staging
    /// latency across a train of small files.
    pub pipeline_depth: usize,
    /// Campaign coalescing threshold (KiB): files at or below this size
    /// become whole-file train requests (`--coalesce-files-kb`).
    pub coalesce_files_kb: u64,
}

impl Default for DownloadConfig {
    fn default() -> Self {
        DownloadConfig {
            optimizer: OptimizerConfig::default(),
            mirror: MirrorPolicy::default(),
            control: ControlConfig::default(),
            integrity: IntegrityConfig::default(),
            trace: TraceConfig::default(),
            reconcile: ReconcileMode::default(),
            chunk_bytes: 32 * 1024 * 1024,
            monitor_hz: 4.0,
            max_open_files: 4,
            output_dir: "downloads".into(),
            timeout_s: 0.0,
            progress_window_s: 30.0,
            progress_min_bytes: 64 * 1024,
            sink_threads: 2,
            sink_queue_mb: 64,
            coalesce_kb: 1024,
            campaign: false,
            pipeline_depth: 1,
            coalesce_files_kb: 4096,
        }
    }
}

impl DownloadConfig {
    pub fn validate(&self) -> Result<()> {
        self.optimizer.validate()?;
        self.mirror.validate()?;
        self.control.validate()?;
        self.integrity.validate()?;
        self.trace.validate()?;
        if self.integrity.verify && self.control.adaptive_chunks {
            // Verification hashes the fixed chunk grid; adaptive chunk
            // scaling cuts off-grid chunks that cannot be checked
            // against (or reused from) a manifest.
            return Err(Error::Config(
                "verify is incompatible with adaptive_chunks (hashing needs a fixed chunk grid)"
                    .into(),
            ));
        }
        if self.chunk_bytes < 64 * 1024 {
            return Err(Error::Config(format!(
                "chunk_bytes {} too small (min 64 KiB)",
                self.chunk_bytes
            )));
        }
        if self.monitor_hz <= 0.0 || self.monitor_hz > 1000.0 {
            return Err(Error::Config("monitor_hz must be in (0, 1000]".into()));
        }
        if self.max_open_files == 0 {
            return Err(Error::Config("max_open_files must be >= 1".into()));
        }
        if self.timeout_s < 0.0 {
            return Err(Error::Config("timeout_s must be >= 0".into()));
        }
        if self.progress_window_s < 0.0 {
            return Err(Error::Config("progress_window_s must be >= 0".into()));
        }
        if self.sink_threads > 64 {
            return Err(Error::Config(format!(
                "sink_threads {} too large (max 64)",
                self.sink_threads
            )));
        }
        if self.sink_queue_mb == 0 {
            return Err(Error::Config("sink_queue_mb must be >= 1".into()));
        }
        if !(256..=16384).contains(&self.coalesce_kb) {
            return Err(Error::Config(format!(
                "coalesce_kb {} outside [256, 16384]",
                self.coalesce_kb
            )));
        }
        if !(1..=64).contains(&self.pipeline_depth) {
            return Err(Error::Config(format!(
                "pipeline_depth {} outside [1, 64]",
                self.pipeline_depth
            )));
        }
        if self.campaign && self.coalesce_files_kb == 0 {
            return Err(Error::Config(
                "coalesce_files_kb must be >= 1 in campaign mode".into(),
            ));
        }
        Ok(())
    }

    /// Apply `FASTBIODL_*` environment overrides (documented in README).
    pub fn apply_env(&mut self) -> Result<()> {
        fn env_f64(name: &str) -> Result<Option<f64>> {
            match std::env::var(name) {
                Ok(v) => v
                    .parse()
                    .map(Some)
                    .map_err(|_| Error::Config(format!("{name}='{v}' is not a number"))),
                Err(_) => Ok(None),
            }
        }
        if let Some(k) = env_f64("FASTBIODL_K")? {
            self.optimizer.k = k;
        }
        if let Some(p) = env_f64("FASTBIODL_PROBE_INTERVAL")? {
            self.optimizer.probe_interval_s = p;
        }
        if let Some(lr) = env_f64("FASTBIODL_LR")? {
            self.optimizer.lr = lr;
        }
        if let Ok(kind) = std::env::var("FASTBIODL_OPTIMIZER") {
            self.optimizer.kind = OptimizerKind::parse(&kind)?;
        }
        if let Ok(strategy) = std::env::var("FASTBIODL_MIRROR_STRATEGY") {
            self.mirror.strategy = MirrorStrategy::parse(&strategy)?;
        }
        if let Some(w) = env_f64("FASTBIODL_FAULT_PENALTY")? {
            self.control.fault_penalty = w;
        }
        if let Some(w) = env_f64("FASTBIODL_PROGRESS_WINDOW")? {
            self.progress_window_s = w;
        }
        fn env_usize(name: &str) -> Result<Option<usize>> {
            match std::env::var(name) {
                Ok(v) => v
                    .parse()
                    .map(Some)
                    .map_err(|_| Error::Config(format!("{name}='{v}' is not an integer"))),
                Err(_) => Ok(None),
            }
        }
        if let Some(n) = env_usize("FASTBIODL_SINK_THREADS")? {
            self.sink_threads = n;
        }
        if let Some(n) = env_usize("FASTBIODL_SINK_QUEUE_MB")? {
            self.sink_queue_mb = n;
        }
        if let Some(n) = env_usize("FASTBIODL_COALESCE_KB")? {
            self.coalesce_kb = n;
        }
        if let Some(n) = env_usize("FASTBIODL_PIPELINE_DEPTH")? {
            self.pipeline_depth = n;
        }
        fn env_bool(name: &str) -> Result<Option<bool>> {
            match std::env::var(name) {
                Ok(v) => match v.to_ascii_lowercase().as_str() {
                    "1" | "true" | "yes" | "on" => Ok(Some(true)),
                    "0" | "false" | "no" | "off" => Ok(Some(false)),
                    _ => Err(Error::Config(format!("{name}='{v}' is not a boolean"))),
                },
                Err(_) => Ok(None),
            }
        }
        if let Some(b) = env_bool("FASTBIODL_VERIFY")? {
            self.integrity.verify = b;
        }
        if let Some(b) = env_bool("FASTBIODL_REUSE_LOCAL")? {
            self.integrity.reuse_local = b;
        }
        if let Ok(out) = std::env::var("FASTBIODL_TRACE_OUT") {
            self.trace.out = Some(out);
        }
        if let Ok(format) = std::env::var("FASTBIODL_TRACE_FORMAT") {
            self.trace.format = TraceFormat::parse(&format)?;
        }
        if let Some(n) = env_usize("FASTBIODL_TRACE_CAPACITY")? {
            self.trace.capacity = n;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        DownloadConfig::default().validate().unwrap();
    }

    #[test]
    fn k_must_exceed_one() {
        let mut c = OptimizerConfig::default();
        c.k = 1.0;
        assert!(c.validate().is_err());
        c.k = 0.9;
        assert!(c.validate().is_err());
        c.k = 1.001;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn c_star_matches_paper() {
        // Paper §4.1: C* = 1/ln k. For k=1.02, C* ≈ 50.5.
        let c = OptimizerConfig {
            k: 1.02,
            ..Default::default()
        };
        assert!((c.c_star() - 50.497).abs() < 0.01);
        // k=1.05 is much more conservative: C* ≈ 20.5.
        let c = OptimizerConfig {
            k: 1.05,
            ..Default::default()
        };
        assert!((c.c_star() - 20.498).abs() < 0.01);
    }

    #[test]
    fn bounds_checked() {
        let mut c = OptimizerConfig::default();
        c.c_min = 0;
        assert!(c.validate().is_err());
        c = OptimizerConfig::default();
        c.c_max = 100_000;
        assert!(c.validate().is_err());
        c = OptimizerConfig::default();
        c.c_init = 70;
        assert!(c.validate().is_err());
    }

    #[test]
    fn c_max_scales_past_the_artifact_grid() {
        // The engine scale-out target: pools of 256+ slots validate
        // (Bayesian proposals stay grid-capped internally).
        let mut c = OptimizerConfig::default();
        c.c_max = 256;
        assert!(c.validate().is_ok());
        c.c_max = 1024;
        assert!(c.validate().is_ok());
        // The event-driven real driver scales with the sim path now:
        // thousands of slots are a workload, not a typo.
        c.c_max = 4096;
        assert!(c.validate().is_ok());
        c.c_max = 65536;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn progress_deadline_validates() {
        let mut dl = DownloadConfig::default();
        assert!(dl.progress_window_s > 0.0);
        dl.progress_window_s = 0.0; // disabled is fine
        assert!(dl.validate().is_ok());
        dl.progress_window_s = -1.0;
        assert!(dl.validate().is_err());
    }

    #[test]
    fn sink_knobs_validate() {
        let dl = DownloadConfig::default();
        assert_eq!(dl.sink_threads, 2);
        assert_eq!(dl.sink_queue_mb, 64);
        assert_eq!(dl.coalesce_kb, 1024);
        assert!(dl.validate().is_ok());
        let mut dl = DownloadConfig::default();
        dl.sink_threads = 0; // inline legacy mode is a valid setting
        assert!(dl.validate().is_ok());
        dl.sink_threads = 65;
        assert!(dl.validate().is_err());
        dl = DownloadConfig::default();
        dl.sink_queue_mb = 0;
        assert!(dl.validate().is_err());
        dl = DownloadConfig::default();
        dl.coalesce_kb = 128;
        assert!(dl.validate().is_err());
        dl.coalesce_kb = 32768;
        assert!(dl.validate().is_err());
        dl.coalesce_kb = 256;
        assert!(dl.validate().is_ok());
    }

    #[test]
    fn reconcile_mode_parses_and_defaults_to_batched() {
        assert_eq!(ReconcileMode::default(), ReconcileMode::Batched);
        assert_eq!(ReconcileMode::parse("full-scan").unwrap(), ReconcileMode::FullScan);
        assert_eq!(ReconcileMode::parse("BATCHED").unwrap(), ReconcileMode::Batched);
        assert!(ReconcileMode::parse("lazy").is_err());
        assert_eq!(ReconcileMode::FullScan.name(), "full-scan");
        assert_eq!(DownloadConfig::default().reconcile, ReconcileMode::Batched);
    }

    #[test]
    fn mirror_policy_validates_and_parses() {
        let mut p = MirrorPolicy::default();
        assert!(p.validate().is_ok());
        p.stripe_floor = 0.9;
        assert!(p.validate().is_err());
        assert_eq!(
            MirrorStrategy::parse("stripe").unwrap(),
            MirrorStrategy::WeightedStripe
        );
        assert_eq!(
            MirrorStrategy::parse("FAILOVER").unwrap(),
            MirrorStrategy::Failover
        );
        assert!(MirrorStrategy::parse("roulette").is_err());
    }

    #[test]
    fn control_config_defaults_are_fault_blind_and_validate() {
        let c = ControlConfig::default();
        assert_eq!(c.fault_penalty, 0.0);
        assert!(!c.adaptive_chunks);
        c.validate().unwrap();
        let mut bad = ControlConfig::default();
        bad.fault_penalty = -1.0;
        assert!(bad.validate().is_err());
        bad = ControlConfig::default();
        bad.fault_penalty = f64::NAN;
        assert!(bad.validate().is_err());
        bad = ControlConfig::default();
        bad.chunk_scale_min = 0.0;
        assert!(bad.validate().is_err());
        bad.chunk_scale_min = 1.5;
        assert!(bad.validate().is_err());
        let ok = ControlConfig {
            fault_penalty: 2.5,
            adaptive_chunks: true,
            chunk_scale_min: 0.125,
        };
        ok.validate().unwrap();
        // The whole-transfer validate chain covers the control section.
        let mut dl = DownloadConfig::default();
        dl.control.chunk_scale_min = -0.1;
        assert!(dl.validate().is_err());
    }

    #[test]
    fn integrity_defaults_off_and_validates() {
        let c = IntegrityConfig::default();
        assert!(!c.verify && !c.reuse_local);
        c.validate().unwrap();
        // reuse_local without verify is meaningless.
        let bad = IntegrityConfig {
            verify: false,
            reuse_local: true,
        };
        assert!(bad.validate().is_err());
        // verify conflicts with adaptive chunk scaling (off-grid cuts).
        let mut dl = DownloadConfig::default();
        dl.integrity.verify = true;
        assert!(dl.validate().is_ok());
        dl.control.adaptive_chunks = true;
        assert!(dl.validate().is_err());
    }

    #[test]
    fn trace_defaults_off_and_validates() {
        let c = TraceConfig::default();
        assert!(!c.enabled());
        assert_eq!(c.format, TraceFormat::Ndjson);
        assert_eq!(c.capacity, crate::trace::DEFAULT_CAPACITY);
        c.validate().unwrap();
        let mut bad = TraceConfig::default();
        bad.capacity = 4;
        assert!(bad.validate().is_err());
        bad = TraceConfig::default();
        bad.out = Some(String::new());
        assert!(bad.validate().is_err());
        assert_eq!(TraceFormat::parse("chrome").unwrap(), TraceFormat::Chrome);
        assert_eq!(TraceFormat::parse("JSONL").unwrap(), TraceFormat::Ndjson);
        assert!(TraceFormat::parse("svg").is_err());
        assert_eq!(TraceFormat::Chrome.name(), "chrome");
        // The whole-transfer validate chain covers the trace section.
        let mut dl = DownloadConfig::default();
        dl.trace.capacity = 0;
        assert!(dl.validate().is_err());
    }

    #[test]
    fn campaign_knobs_default_off_and_validate() {
        let dl = DownloadConfig::default();
        assert!(!dl.campaign);
        assert_eq!(dl.pipeline_depth, 1);
        assert_eq!(dl.coalesce_files_kb, 4096);
        assert!(dl.validate().is_ok());
        let mut dl = DownloadConfig::default();
        dl.pipeline_depth = 0;
        assert!(dl.validate().is_err());
        dl.pipeline_depth = 65;
        assert!(dl.validate().is_err());
        dl.pipeline_depth = 8;
        assert!(dl.validate().is_ok());
        dl.campaign = true;
        assert!(dl.validate().is_ok());
        dl.coalesce_files_kb = 0;
        assert!(dl.validate().is_err());
    }

    #[test]
    fn optimizer_kind_parses() {
        assert_eq!(
            OptimizerKind::parse("gd").unwrap(),
            OptimizerKind::GradientDescent
        );
        assert_eq!(OptimizerKind::parse("BAYES").unwrap(), OptimizerKind::Bayesian);
        assert_eq!(OptimizerKind::parse("fixed").unwrap(), OptimizerKind::Fixed);
        assert!(OptimizerKind::parse("sgd").is_err());
    }
}
