//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `fastbiodl <command> [positional...] [--flag value]...`.
//! Flags may appear anywhere after the command; `--flag=value` and
//! `--flag value` are both accepted; bare `--flag` is boolean `true`.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        let Some(cmd) = it.next() else {
            return Ok(out);
        };
        if cmd.starts_with('-') {
            return Err(Error::Config(format!(
                "expected a command first, got flag '{cmd}' (try `fastbiodl help`)"
            )));
        }
        out.command = cmd;
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if flag.is_empty() {
                    return Err(Error::Config("bare '--' not supported".into()));
                }
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(flag.to_string(), v);
                } else {
                    out.flags.insert(flag.to_string(), "true".into());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                Error::Config(format!("--{name}='{v}' is not a number"))
            }),
        }
    }

    pub fn flag_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                Error::Config(format!("--{name}='{v}' is not an integer"))
            }),
        }
    }

    pub fn flag_u64(&self, name: &str) -> Result<Option<u64>> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                Error::Config(format!("--{name}='{v}' is not an integer"))
            }),
        }
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Boolean flag that refuses to swallow a positional argument.
    ///
    /// The grammar's greedy `--flag value` form means a bare boolean
    /// flag placed *before* a positional (`--sweep smoke`) captures the
    /// positional as its value; [`Args::flag_bool`] would then quietly
    /// report `false` and the positional would vanish. This variant
    /// turns that into a loud error: bare `--flag` and explicit
    /// true/false spellings are accepted, anything else is rejected.
    pub fn flag_bool_strict(&self, name: &str) -> Result<bool> {
        match self.flag(name) {
            None => Ok(false),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(other) => Err(Error::Config(format!(
                "--{name} is a boolean flag but captured '{other}' — put --{name} after \
                 positional arguments or write --{name}=true"
            ))),
        }
    }

    /// Error on unknown flags (catches typos early).
    pub fn expect_flags(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(Error::Config(format!(
                    "unknown flag --{k} for '{}' (known: {})",
                    self.command,
                    known.join(", ")
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic_forms() {
        let a = parse("download PRJNA762469 --k 1.05 --real --seed=42");
        assert_eq!(a.command, "download");
        assert_eq!(a.positional, vec!["PRJNA762469"]);
        assert_eq!(a.flag_f64("k").unwrap(), Some(1.05));
        assert!(a.flag_bool("real"));
        assert_eq!(a.flag_u64("seed").unwrap(), Some(42));
        assert_eq!(a.flag("missing"), None);
    }

    #[test]
    fn flag_then_positional() {
        let a = parse("experiment --runs 3 table3");
        assert_eq!(a.positional, vec!["table3"]);
        assert_eq!(a.flag_usize("runs").unwrap(), Some(3));
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("download --chnk 5");
        assert!(a.expect_flags(&["chunk"]).is_err());
        assert!(a.expect_flags(&["chnk"]).is_ok());
    }

    #[test]
    fn type_errors() {
        let a = parse("x --n abc");
        assert!(a.flag_usize("n").is_err());
    }

    #[test]
    fn leading_flag_is_error() {
        assert!(Args::parse(vec!["--help".to_string()]).is_err());
    }

    #[test]
    fn strict_bool_flags_reject_swallowed_positionals() {
        let a = parse("download --adaptive-chunks PRJNA762469");
        // The greedy grammar captured the accession as the flag value:
        // the strict accessor must refuse instead of reporting false.
        assert!(a.flag_bool_strict("adaptive-chunks").is_err());
        let a = parse("download PRJNA762469 --adaptive-chunks");
        assert!(a.flag_bool_strict("adaptive-chunks").unwrap());
        let a = parse("download --adaptive-chunks=true PRJNA762469");
        assert!(a.flag_bool_strict("adaptive-chunks").unwrap());
        assert_eq!(a.positional, vec!["PRJNA762469"]);
        let a = parse("download --adaptive-chunks=false PRJNA762469");
        assert!(!a.flag_bool_strict("adaptive-chunks").unwrap());
        let a = parse("download PRJNA762469");
        assert!(!a.flag_bool_strict("adaptive-chunks").unwrap());
    }
}
