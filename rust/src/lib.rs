//! # FastBioDL — adaptive parallel downloader for large genomic datasets
//!
//! Reproduction of *"Adaptive Parallel Downloader for Large Genomic
//! Datasets"* (CS.DC 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: accession resolution, chunk
//!   scheduling, a dynamically sized worker pool driven by status arrays
//!   (paper Algorithm 1), a throughput monitor, and the probing loop that
//!   invokes the adaptive concurrency controller every few seconds.
//! * **L2/L1 (build-time Python, `python/compile/`)** — the controller
//!   compute graphs (gradient-descent step, Bayesian GP step, throughput
//!   window aggregation, utility surfaces) with Pallas kernels at the hot
//!   spots, AOT-lowered once to HLO text under `artifacts/`.
//! * **Runtime bridge** — [`runtime`] loads those artifacts through the
//!   PJRT CPU client (`xla` crate) at startup and executes them from the
//!   optimizer loop. Python never runs on the request path.
//!
//! The crate also contains every substrate the paper's evaluation needs
//! but this environment does not have: a virtual-time network simulator
//! ([`netsim`]) standing in for the Colab↔NCBI WAN and the FABRIC
//! testbed, a real HTTP/1.1 transport + throttled localhost server
//! ([`transport`]) proving the stack composes over actual sockets,
//! behavioural models of the baseline tools ([`baselines`]), and the
//! experiment harness regenerating every table and figure
//! ([`experiments`]). See `DESIGN.md` for the substitution map.

pub mod accession;
pub mod baselines;
pub mod bench;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod experiments;
pub mod metrics;
pub mod netsim;
pub mod optimizer;
pub mod report;
pub mod runtime;
pub mod session;
pub mod trace;
pub mod transport;
pub mod util;

mod error;

pub use error::{Error, Result};
