//! Figure 6: adaptive vs fixed concurrency on high-speed networks.
//!
//! The paper's three FABRIC scenarios (throttled so the theoretical
//! optimum is known exactly):
//!
//! * (a) 10 Gbps link, 500 Mbps/thread → C* = 20; adaptive finishes
//!   44 % faster than fixed-5 and 67 % faster than fixed-3, reaching
//!   ≈7.5 Gbps.
//! * (b) 10 Gbps, 1400 Mbps/thread → C* ≈ 7.1; adaptive ≈9.3 Gbps vs
//!   ≈7.3 for fixed-5 (which trails by only seconds but leaves
//!   bandwidth idle).
//! * (c) 20 Gbps, 1400 Mbps/thread → C* ≈ 14.3; adaptive averages ≈14
//!   threads and wins 1.3× / 2.1× over fixed-5 / fixed-3.
//!
//! Shapes under test are in [`check_shape`].

use crate::baselines::BaselineTool;
use crate::experiments::runner::{run_tool, Tool, ToolSummary};
use crate::experiments::scenario::{self, Scenario};
use crate::runtime::SharedRuntime;
use crate::Result;

/// One scenario's three arms.
#[derive(Clone, Debug)]
pub struct ScenarioComparison {
    pub scenario: &'static str,
    pub c_star: f64,
    pub adaptive: ToolSummary,
    pub fixed5: ToolSummary,
    pub fixed3: ToolSummary,
}

impl ScenarioComparison {
    pub fn speedup_vs_fixed5(&self) -> f64 {
        self.fixed5.duration_s.mean / self.adaptive.duration_s.mean.max(1e-9)
    }

    pub fn speedup_vs_fixed3(&self) -> f64 {
        self.fixed3.duration_s.mean / self.adaptive.duration_s.mean.max(1e-9)
    }
}

fn run_scenario(
    s: &Scenario,
    runtime: &SharedRuntime,
    runs: usize,
    seed_base: u64,
) -> Result<ScenarioComparison> {
    let adaptive = run_tool(s, &Tool::fastbiodl(s), runtime, runs, seed_base)?;
    let fixed5 = run_tool(
        s,
        &Tool::Baseline(BaselineTool::fixed_fastbiodl(5, &s.download)),
        runtime,
        runs,
        seed_base,
    )?;
    let fixed3 = run_tool(
        s,
        &Tool::Baseline(BaselineTool::fixed_fastbiodl(3, &s.download)),
        runtime,
        runs,
        seed_base,
    )?;
    Ok(ScenarioComparison {
        scenario: s.name,
        c_star: s.c_star_theoretical.unwrap_or(f64::NAN),
        adaptive,
        fixed5,
        fixed3,
    })
}

/// Run all three scenarios.
pub fn run(
    runtime: &SharedRuntime,
    runs: usize,
    seed_base: u64,
) -> Result<Vec<ScenarioComparison>> {
    ['a', 'b', 'c']
        .iter()
        .map(|&which| {
            let s = scenario::fabric(which, seed_base)?;
            run_scenario(&s, runtime, runs, seed_base)
        })
        .collect()
}

/// The paper's qualitative claims.
pub fn check_shape(rows: &[ScenarioComparison]) -> std::result::Result<(), String> {
    if rows.len() != 3 {
        return Err(format!("expected 3 scenarios, got {}", rows.len()));
    }
    for r in rows {
        // Adaptive beats both fixed arms everywhere.
        if r.speedup_vs_fixed5() < 1.02 {
            return Err(format!(
                "{}: adaptive should beat fixed-5 (got {:.2}x)",
                r.scenario,
                r.speedup_vs_fixed5()
            ));
        }
        if r.speedup_vs_fixed3() <= r.speedup_vs_fixed5() {
            return Err(format!(
                "{}: fixed-3 should lose by more than fixed-5",
                r.scenario
            ));
        }
    }
    let (a, b, c) = (&rows[0], &rows[1], &rows[2]);
    // (a) has the largest headroom (C*=20): the biggest fixed-3 gap.
    if a.speedup_vs_fixed3() < 1.4 {
        return Err(format!(
            "fabric-a: expected ≥1.4x over fixed-3, got {:.2}",
            a.speedup_vs_fixed3()
        ));
    }
    // (b): fixed-5 is nearly competitive (C*≈7): gap well under (a)'s.
    if b.speedup_vs_fixed5() >= a.speedup_vs_fixed5() {
        return Err(format!(
            "fabric-b fixed-5 gap ({:.2}) should be smaller than fabric-a's ({:.2})",
            b.speedup_vs_fixed5(),
            a.speedup_vs_fixed5()
        ));
    }
    // (c): adaptive converges near C* ≈ 14.3 and clearly beats fixed-3.
    let late_c: f64 = c
        .adaptive
        .reports
        .iter()
        .filter_map(|r| r.concurrency_trace.last().map(|&(_, c)| c as f64))
        .sum::<f64>()
        / c.adaptive.reports.len().max(1) as f64;
    if !(10.0..=20.0).contains(&late_c) {
        return Err(format!(
            "fabric-c: late concurrency {late_c:.1} far from C*≈14.3"
        ));
    }
    if c.speedup_vs_fixed3() < 1.5 {
        return Err(format!(
            "fabric-c: expected ≥1.5x over fixed-3 (paper 2.1x), got {:.2}",
            c.speedup_vs_fixed3()
        ));
    }
    Ok(())
}
