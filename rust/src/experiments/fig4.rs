//! Figure 4: gradient descent beats Bayesian optimization for this
//! control problem.
//!
//! The paper runs both optimizers on the same transfer five times and
//! reports Bayesian optimization ≈20 % slower in total copy time: the
//! GP surrogate, seeded during momentary spikes, sends the acquisition
//! to far-away thread counts; every jump costs socket resets and feeds
//! more noise back into the model.
//!
//! Shape under test: `mean(duration_bayes) > mean(duration_gd)`, with
//! the gap in a broad band around the paper's 20 % (we accept 5–60 %),
//! and the Bayesian concurrency trace showing strictly more movement
//! (sum of |ΔC|) than GD's.

use crate::config::OptimizerKind;
use crate::experiments::runner::{run_tool, Tool, ToolSummary};
use crate::experiments::scenario;
use crate::runtime::SharedRuntime;
use crate::Result;

/// Comparison outcome.
#[derive(Clone, Debug)]
pub struct Fig4Result {
    pub gd: ToolSummary,
    pub bayes: ToolSummary,
}

impl Fig4Result {
    /// Bayesian slowdown factor (>1 means GD wins).
    pub fn bayes_slowdown(&self) -> f64 {
        self.bayes.duration_s.mean / self.gd.duration_s.mean.max(1e-9)
    }

    /// Mean total concurrency movement per run for a tool.
    pub fn movement(summary: &ToolSummary) -> f64 {
        let total: f64 = summary
            .reports
            .iter()
            .map(|r| {
                r.concurrency_trace
                    .windows(2)
                    .map(|w| (w[1].1 as f64 - w[0].1 as f64).abs())
                    .sum::<f64>()
            })
            .sum();
        total / summary.reports.len().max(1) as f64
    }
}

/// Run both controllers on the Breast-RNA-seq workload.
pub fn run(runtime: &SharedRuntime, runs: usize, seed_base: u64) -> Result<Fig4Result> {
    let scenario = scenario::colab_dataset("Breast-RNA-seq", seed_base)?;

    let mut gd_download = scenario.download.clone();
    gd_download.optimizer.kind = OptimizerKind::GradientDescent;
    let gd = run_tool(
        &scenario,
        &Tool::FastBioDl {
            download: gd_download,
        },
        runtime,
        runs,
        seed_base,
    )?;

    let mut bo_download = scenario.download.clone();
    bo_download.optimizer.kind = OptimizerKind::Bayesian;
    let bayes = run_tool(
        &scenario,
        &Tool::FastBioDl {
            download: bo_download,
        },
        runtime,
        runs,
        seed_base,
    )?;

    Ok(Fig4Result { gd, bayes })
}

/// The paper's qualitative claims.
pub fn check_shape(r: &Fig4Result) -> std::result::Result<(), String> {
    let slow = r.bayes_slowdown();
    if slow < 1.05 {
        return Err(format!(
            "Bayesian should be ≥5% slower than GD (paper ~20%), got {:.1}%",
            (slow - 1.0) * 100.0
        ));
    }
    if slow > 1.6 {
        return Err(format!(
            "Bayesian {:.1}% slower — far beyond the paper's regime",
            (slow - 1.0) * 100.0
        ));
    }
    let gd_move = Fig4Result::movement(&r.gd);
    let bo_move = Fig4Result::movement(&r.bayes);
    if bo_move <= gd_move {
        return Err(format!(
            "Bayesian should jump more than GD (movement {bo_move:.1} vs {gd_move:.1})"
        ));
    }
    Ok(())
}
