//! Shared experiment orchestration: build tool → run N seeds → summarize.
//!
//! The paper runs every comparison "five times using a round-robin
//! approach" (§5.1); [`run_tool`] reproduces that: seeds
//! `base..base+runs`, one full session each, summaries across runs.

use crate::baselines::BaselineTool;
use crate::config::DownloadConfig;
use crate::experiments::scenario::Scenario;
use crate::metrics::summary::{mean_std, MeanStd};
use crate::optimizer::build_controller_with;
use crate::runtime::SharedRuntime;
use crate::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use crate::session::{EngineStats, SessionReport};
use crate::trace::Tracer;
use crate::Result;
use std::sync::Arc;

/// Which tool to run in a scenario.
#[derive(Clone, Debug)]
pub enum Tool {
    /// FastBioDL with the adaptive controller from the scenario config
    /// (optionally overriding the optimizer kind / k).
    FastBioDl { download: DownloadConfig },
    /// A baseline model.
    Baseline(BaselineTool),
}

impl Tool {
    /// FastBioDL with the scenario's own download config.
    pub fn fastbiodl(s: &Scenario) -> Tool {
        Tool::FastBioDl {
            download: s.download.clone(),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Tool::FastBioDl { .. } => "fastbiodl".into(),
            Tool::Baseline(b) => b.behavior.name.clone(),
        }
    }
}

/// Cross-run summary for one tool in one scenario.
#[derive(Clone, Debug)]
pub struct ToolSummary {
    pub tool: String,
    pub speed_mbps: MeanStd,
    pub concurrency: MeanStd,
    pub duration_s: MeanStd,
    pub reports: Vec<SessionReport>,
}

/// Run one tool `runs` times (seeds `seed_base..seed_base+runs`).
pub fn run_tool(
    scenario: &Scenario,
    tool: &Tool,
    runtime: &SharedRuntime,
    runs: usize,
    seed_base: u64,
) -> Result<ToolSummary> {
    let mut reports = Vec::with_capacity(runs);
    for run in 0..runs {
        let seed = seed_base + run as u64;
        let report = run_tool_once(scenario, tool, runtime, seed)?;
        reports.push(report);
    }
    Ok(summarize(tool.name(), reports))
}

/// One seed, one full session.
pub fn run_tool_once(
    scenario: &Scenario,
    tool: &Tool,
    runtime: &SharedRuntime,
    seed: u64,
) -> Result<SessionReport> {
    run_tool_once_with_stats(scenario, tool, runtime, seed, None).map(|(report, _)| report)
}

/// [`run_tool_once`] keeping the engine-internal counters, optionally
/// with a flight recorder attached (`--trace-out` on the sim command).
pub fn run_tool_once_with_stats(
    scenario: &Scenario,
    tool: &Tool,
    runtime: &SharedRuntime,
    seed: u64,
    tracer: Option<Arc<Tracer>>,
) -> Result<(SessionReport, EngineStats)> {
    let (download, behavior, controller) = match tool {
        Tool::FastBioDl { download } => {
            // The download config carries the control-plane knobs
            // (fault penalty, adaptive chunks); experiment presets
            // leave them at the fault-blind defaults, so every paper
            // artifact replays bit-identically.
            let controller = build_controller_with(
                &download.optimizer,
                &download.control,
                Some(runtime.clone()),
            )?;
            (
                download.clone(),
                ToolBehavior::fastbiodl(download),
                controller,
            )
        }
        Tool::Baseline(b) => {
            let mut download = scenario.download.clone();
            download.optimizer = b.optimizer.clone();
            let controller = build_controller_with(
                &download.optimizer,
                &download.control,
                Some(runtime.clone()),
            )?;
            (download, b.behavior.clone(), controller)
        }
    };
    let params = SimSessionParams {
        download,
        behavior,
        netsim: scenario.netsim.clone(),
        records: scenario.records.clone(),
        controller,
        runtime: Some(runtime),
        seed,
    };
    let mut session = SimSession::new(params);
    if let Some(tr) = tracer {
        session = session.with_tracer(tr);
    }
    session.run_with_stats()
}

/// Summarize a report list into the paper's mean ± std columns.
pub fn summarize(tool: String, reports: Vec<SessionReport>) -> ToolSummary {
    let speeds: Vec<f64> = reports.iter().map(|r| r.mean_throughput_mbps).collect();
    let concs: Vec<f64> = reports.iter().map(|r| r.mean_concurrency).collect();
    let durs: Vec<f64> = reports.iter().map(|r| r.duration_s).collect();
    ToolSummary {
        tool,
        speed_mbps: mean_std(&speeds),
        concurrency: mean_std(&concs),
        duration_s: mean_std(&durs),
        reports,
    }
}
