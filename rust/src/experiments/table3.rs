//! Table 3 (and the §5.1 comparison): FastBioDL vs prefetch vs pysradb
//! on the three Table 2 datasets.
//!
//! Paper values (mean ± std over 5 round-robin runs):
//!
//! | Dataset           | Tool      | Concurrency | Speed (Mbps)    |
//! |-------------------|-----------|-------------|-----------------|
//! | Breast-RNA-seq    | prefetch  | 3.00        | 517.70 ± 40.12  |
//! |                   | pysradb   | 8.00        | 749.32 ± 141.82 |
//! |                   | FastBioDL | 3.42 ± 0.62 | 989.12 ± 92.35  |
//! | HiFi-WGS          | prefetch  | 3.00        | 246.82 ± 18.97  |
//! |                   | pysradb   | 8.00        | 220.56 ± 82.67  |
//! |                   | FastBioDL | 4.92 ± 0.21 | 594.75 ± 50.52  |
//! | Amplicon-Digester | prefetch  | 3.00        | 29.15 ± 3.53    |
//! |                   | pysradb   | 8.00        | 29.10 ± 2.17    |
//! |                   | FastBioDL | 4.14 ± 0.42 | 117.47 ± 2.03   |
//!
//! Shapes under test (see [`check_shape`]): FastBioDL wins everywhere;
//! pysradb > prefetch on Breast but ≤ prefetch on HiFi (client
//! pressure); the two baselines are nearly identical on Amplicon
//! (serialized resolution); the FastBioDL speedup on Amplicon is the
//! largest (≈4×).

use crate::baselines::BaselineTool;
use crate::experiments::runner::{run_tool, Tool, ToolSummary};
use crate::experiments::scenario;
use crate::runtime::SharedRuntime;
use crate::Result;

pub const DATASETS: [&str; 3] = ["Breast-RNA-seq", "HiFi-WGS", "Amplicon-Digester"];

/// All summaries for one dataset.
#[derive(Clone, Debug)]
pub struct DatasetComparison {
    pub dataset: &'static str,
    pub prefetch: ToolSummary,
    pub pysradb: ToolSummary,
    pub fastbiodl: ToolSummary,
}

impl DatasetComparison {
    /// FastBioDL speedup over a baseline summary.
    pub fn speedup_vs(&self, baseline: &ToolSummary) -> f64 {
        self.fastbiodl.speed_mbps.mean / baseline.speed_mbps.mean.max(1e-9)
    }
}

/// Run the full comparison (`runs` seeds per tool per dataset).
pub fn run(
    runtime: &SharedRuntime,
    runs: usize,
    seed_base: u64,
) -> Result<Vec<DatasetComparison>> {
    let mut out = Vec::new();
    for dataset in DATASETS {
        let scenario = scenario::colab_dataset(dataset, seed_base)?;
        let prefetch = run_tool(
            &scenario,
            &Tool::Baseline(BaselineTool::prefetch()),
            runtime,
            runs,
            seed_base,
        )?;
        let pysradb = run_tool(
            &scenario,
            &Tool::Baseline(BaselineTool::pysradb()),
            runtime,
            runs,
            seed_base,
        )?;
        let fastbiodl = run_tool(&scenario, &Tool::fastbiodl(&scenario), runtime, runs, seed_base)?;
        out.push(DatasetComparison {
            dataset,
            prefetch,
            pysradb,
            fastbiodl,
        });
    }
    Ok(out)
}

/// The paper's qualitative claims, as assertions.
pub fn check_shape(rows: &[DatasetComparison]) -> std::result::Result<(), String> {
    let by_name = |name: &str| rows.iter().find(|r| r.dataset == name);
    let breast = by_name("Breast-RNA-seq").ok_or("missing Breast")?;
    let hifi = by_name("HiFi-WGS").ok_or("missing HiFi")?;
    let amplicon = by_name("Amplicon-Digester").ok_or("missing Amplicon")?;

    // FastBioDL wins on every dataset.
    for r in rows {
        if r.speedup_vs(&r.prefetch) <= 1.0 {
            return Err(format!("{}: FastBioDL does not beat prefetch", r.dataset));
        }
        if r.speedup_vs(&r.pysradb) <= 1.0 {
            return Err(format!("{}: FastBioDL does not beat pysradb", r.dataset));
        }
    }
    // Breast: pysradb (8 threads) beats prefetch (3) — mild client cost.
    if breast.pysradb.speed_mbps.mean <= breast.prefetch.speed_mbps.mean {
        return Err("Breast: pysradb should beat prefetch".into());
    }
    // HiFi: the 8-thread tool loses its edge (client write pressure).
    if hifi.pysradb.speed_mbps.mean > hifi.prefetch.speed_mbps.mean * 1.15 {
        return Err(format!(
            "HiFi: pysradb ({:.0}) should NOT clearly beat prefetch ({:.0})",
            hifi.pysradb.speed_mbps.mean, hifi.prefetch.speed_mbps.mean
        ));
    }
    // Amplicon: baselines within ~25% of each other (shared serialized
    // resolution path), FastBioDL ≥ 2.5× both.
    let a_p = amplicon.prefetch.speed_mbps.mean;
    let a_y = amplicon.pysradb.speed_mbps.mean;
    if (a_p - a_y).abs() / a_p.max(a_y) > 0.25 {
        return Err(format!(
            "Amplicon: baselines should be nearly identical ({a_p:.1} vs {a_y:.1})"
        ));
    }
    if amplicon.speedup_vs(&amplicon.prefetch) < 2.5 {
        return Err(format!(
            "Amplicon: expected ≥2.5x over prefetch, got {:.2}",
            amplicon.speedup_vs(&amplicon.prefetch)
        ));
    }
    // The largest FastBioDL advantage is on the small-file dataset.
    let s_breast = breast.speedup_vs(&breast.prefetch);
    let s_amp = amplicon.speedup_vs(&amplicon.prefetch);
    if s_amp <= s_breast {
        return Err(format!(
            "Amplicon speedup ({s_amp:.2}) should exceed Breast ({s_breast:.2})"
        ));
    }
    // Adaptive concurrency stays moderate (paper: 3.4–4.9), far below
    // pysradb's fixed 8.
    for r in rows {
        let c = r.fastbiodl.concurrency.mean;
        if !(1.5..=8.0).contains(&c) {
            return Err(format!("{}: FastBioDL concurrency {:.2} implausible", r.dataset, c));
        }
    }
    Ok(())
}
