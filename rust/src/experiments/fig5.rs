//! Figure 5: per-second throughput timelines with 68 % confidence
//! bands — FastBioDL vs prefetch vs pysradb on Breast-RNA-seq.
//!
//! Paper observations in those trials: FastBioDL peaks ≈1800 Mbps vs
//! ≈1400 for the baselines, and completes at ≈160 s — 38 % / 43 %
//! faster than pysradb / prefetch.
//!
//! Shapes under test: FastBioDL's peak exceeds both baselines'; its
//! completion time beats both by ≥20 %; the bands are meaningful
//! (positive width where runs overlap).

use crate::baselines::BaselineTool;
use crate::experiments::runner::{run_tool, Tool, ToolSummary};
use crate::experiments::scenario;
use crate::metrics::timeline::{ci68_band, Timeline};
use crate::runtime::SharedRuntime;
use crate::Result;

/// A tool's aggregated timeline band.
#[derive(Clone, Debug)]
pub struct ToolBand {
    pub tool: String,
    pub mean: Vec<f64>,
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
    pub summary: ToolSummary,
}

impl ToolBand {
    fn from_summary(summary: ToolSummary) -> ToolBand {
        let runs: Vec<Timeline> = summary.reports.iter().map(|r| r.timeline.clone()).collect();
        let (mean, lo, hi) = ci68_band(&runs);
        ToolBand {
            tool: summary.tool.clone(),
            mean,
            lo,
            hi,
            summary,
        }
    }

    pub fn peak(&self) -> f64 {
        self.mean.iter().copied().fold(0.0, f64::max)
    }

    pub fn completion_s(&self) -> f64 {
        self.summary.duration_s.mean
    }
}

/// The three bands.
#[derive(Clone, Debug)]
pub struct Fig5Result {
    pub fastbiodl: ToolBand,
    pub prefetch: ToolBand,
    pub pysradb: ToolBand,
}

/// Run the timeline comparison on Breast-RNA-seq.
pub fn run(runtime: &SharedRuntime, runs: usize, seed_base: u64) -> Result<Fig5Result> {
    let scenario = scenario::colab_dataset("Breast-RNA-seq", seed_base)?;
    let fastbiodl = run_tool(&scenario, &Tool::fastbiodl(&scenario), runtime, runs, seed_base)?;
    let prefetch = run_tool(
        &scenario,
        &Tool::Baseline(BaselineTool::prefetch()),
        runtime,
        runs,
        seed_base,
    )?;
    let pysradb = run_tool(
        &scenario,
        &Tool::Baseline(BaselineTool::pysradb()),
        runtime,
        runs,
        seed_base,
    )?;
    Ok(Fig5Result {
        fastbiodl: ToolBand::from_summary(fastbiodl),
        prefetch: ToolBand::from_summary(prefetch),
        pysradb: ToolBand::from_summary(pysradb),
    })
}

/// The paper's qualitative claims.
pub fn check_shape(r: &Fig5Result) -> std::result::Result<(), String> {
    if !(r.fastbiodl.peak() > r.prefetch.peak() && r.fastbiodl.peak() > r.pysradb.peak()) {
        return Err(format!(
            "FastBioDL peak {:.0} should exceed prefetch {:.0} and pysradb {:.0}",
            r.fastbiodl.peak(),
            r.prefetch.peak(),
            r.pysradb.peak()
        ));
    }
    let f = r.fastbiodl.completion_s();
    let faster_than_prefetch = 1.0 - f / r.prefetch.completion_s();
    let faster_than_pysradb = 1.0 - f / r.pysradb.completion_s();
    if faster_than_prefetch < 0.20 {
        return Err(format!(
            "completion vs prefetch only {:.0}% faster (paper 43%)",
            faster_than_prefetch * 100.0
        ));
    }
    if faster_than_pysradb < 0.15 {
        return Err(format!(
            "completion vs pysradb only {:.0}% faster (paper 38%)",
            faster_than_pysradb * 100.0
        ));
    }
    // Bands have width where runs vary.
    let width: f64 = r
        .fastbiodl
        .hi
        .iter()
        .zip(&r.fastbiodl.lo)
        .map(|(h, l)| h - l)
        .sum();
    if width <= 0.0 {
        return Err("confidence band has zero width".into());
    }
    Ok(())
}
