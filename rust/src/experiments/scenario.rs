//! Calibrated simulation profiles (DESIGN.md §6).
//!
//! Two testbeds appear in the paper:
//!
//! * **colab** (§5.1) — Google Colab (12 GB RAM) pulling from NCBI/ENA
//!   production endpoints. Bottleneck ≈2 Gbps with heavy OU cross
//!   traffic, per-connection ceiling ≈350 Mbps, cold-object staging on
//!   first byte, long-request decay, and *dataset-dependent client
//!   pressure*: the HiFi-WGS 9.5 GB files blow through the VM's page
//!   cache (aggregate write ceiling + strong interleaved-write
//!   penalty), the 2.2 GB Breast files mostly fit (mild penalty), the
//!   40 MB Amplicon files are free. These are the phenomena behind the
//!   Table 3 orderings; parameters were calibrated against the
//!   published numbers (see EXPERIMENTS.md §Calibration).
//! * **fabric-a/b/c** (§5.2) — the FABRIC testbed with explicit
//!   throttles; client effects removed by construction (NVMe,
//!   ConnectX-6). `C* = link ÷ per-thread cap` = 20 / ≈7.1 / ≈14.3.

use crate::accession::catalog::{Catalog, RunRecord};
use crate::accession::datasets::DatasetPreset;
use crate::config::DownloadConfig;
use crate::netsim::engine::BackgroundConfig;
use crate::netsim::fault::{FaultProfile, FaultSchedule};
use crate::netsim::{ClientProfile, NetSimConfig, ServerProfile};
use crate::{Error, Result};

/// A named, fully specified simulation scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub netsim: NetSimConfig,
    /// Download config tuned for the scenario (probe interval etc.).
    pub download: DownloadConfig,
    /// The workload (resolved records).
    pub records: Vec<RunRecord>,
    /// Theoretical optimal concurrency where defined (Figure 6).
    pub c_star_theoretical: Option<f64>,
}

impl Scenario {
    /// Hostile variant: overlay a named fault profile onto the
    /// scenario's network. The schedule is fully determined by
    /// `(profile, seed, link capacity)`, so paired runs across tools
    /// see identical fault sequences. `horizon_s` bounds the scheduled
    /// window; transfers running longer see a fault-free tail.
    ///
    /// The `slowmirror` profile degrades only flows bound to mirror 0;
    /// on the built-in catalog (whose records list ENA + NCBI mirrors)
    /// the unified engine fails over to the healthy replica, while
    /// single-mirror workloads ride out the slowdown.
    pub fn with_fault_profile(
        mut self,
        profile: FaultProfile,
        seed: u64,
        horizon_s: f64,
    ) -> Scenario {
        self.netsim.faults =
            profile.schedule(seed, horizon_s, self.netsim.link_capacity_mbps);
        self
    }
}

/// §5.1 Colab-like network shared by the three Table 2 datasets.
fn colab_netsim() -> NetSimConfig {
    NetSimConfig {
        link_capacity_mbps: 2_000.0,
        background: BackgroundConfig {
            mean_mbps: 400.0,
            theta: 0.25,
            sigma: 130.0,
            max_mbps: 1_500.0,
        },
        server: ServerProfile {
            setup_latency_s: 0.25,
            first_byte_latency_s: 4.0,
            per_conn_cap_mbps: 350.0,
            long_request_decay_per_min: 0.25,
            decay_floor: 0.45,
            max_connections: 64,
        },
        client: ClientProfile {
            stream_overhead_n0: 4.0,
            stream_overhead_alpha: 0.06,
            write_cap_mbps: 1_300.0,
            file_overhead_n0: 3.0,
            file_overhead_beta: 0.01,
            efficiency_floor: 0.15,
        },
        flow_jitter_frac: 0.05,
        flow_failure_rate_per_min: 0.0,
        faults: FaultSchedule::none(),
        dt_s: 0.05,
    }
}

/// Colab scenario for one Table 2 dataset (per-dataset client pressure).
pub fn colab_dataset(alias: &str, seed: u64) -> Result<Scenario> {
    let preset = DatasetPreset::find(alias)
        .ok_or_else(|| Error::Config(format!("unknown dataset '{alias}'")))?;
    let mut netsim = colab_netsim();
    match preset.alias {
        // 9.5 GB files vs 12 GB RAM: page-cache thrash. Long-read
        // archives also stream cold objects at a lower per-connection
        // rate (≈150 Mbps observed), which sets C*≈4.7 with the
        // write ceiling — the paper's FastBioDL equilibrium of 4.92.
        "HiFi-WGS" => {
            netsim.server.per_conn_cap_mbps = 150.0;
            netsim.client.write_cap_mbps = 700.0;
            netsim.client.file_overhead_beta = 0.115;
        }
        // 2.2 GB files mostly fit the page cache: mild interleaving
        // cost only; sink ceiling from the shared default.
        "Breast-RNA-seq" => {
            netsim.client.write_cap_mbps = 1_300.0;
            netsim.client.file_overhead_beta = 0.01;
        }
        // 40 MB files: client-side effects negligible; the workload is
        // dominated by resolution + cold staging (deep-archive objects:
        // ≈8 s to first byte).
        "Amplicon-Digester" => {
            netsim.server.first_byte_latency_s = 8.0;
            netsim.client.write_cap_mbps = 0.0;
            netsim.client.file_overhead_beta = 0.0;
        }
        _ => unreachable!("presets are exhaustive"),
    }
    let mut catalog = Catalog::empty();
    catalog.register_preset(preset, seed);
    let records = catalog.project_runs(preset.project)?.to_vec();
    let download = DownloadConfig {
        optimizer: crate::config::OptimizerConfig {
            probe_interval_s: 5.0, // §5.1: "probing duration of 5 seconds"
            ..Default::default()
        },
        ..Default::default()
    };
    Ok(Scenario {
        name: preset.alias,
        netsim,
        download,
        records,
        c_star_theoretical: None,
    })
}

/// Many-file campaign presets (the `bench --suite campaign` cells and
/// the directional campaign tests): the Amplicon-style cold-staging
/// network — ≈8 s to first byte on deep-archive objects, client
/// pressure negligible — carrying a synthetic file set at one of three
/// size mixes. This is the regime where per-request latency, not
/// bandwidth, dominates wall time, so request trains and pipelining
/// are what the preset measures.
///
/// * `many-small` — 96 × 2 MiB: every file sits below the default
///   coalesce threshold and rides a request train.
/// * `mixed` — 32 × 2 MiB + 4 × 256 MiB: trains and chunked striping
///   share one connection pool and one global chunk queue.
/// * `many-large` — 6 × 512 MiB: nothing coalesces; guards that
///   campaign mode does not regress pure large-file workloads.
pub fn campaign(preset: &str, seed: u64) -> Result<Scenario> {
    let (name, small, large): (&'static str, usize, usize) = match preset {
        "many-small" => ("many-small", 96, 0),
        "mixed" => ("mixed", 32, 4),
        "many-large" => ("many-large", 0, 6),
        other => {
            return Err(Error::Config(format!(
                "unknown campaign preset '{other}' (many-small | mixed | many-large)"
            )))
        }
    };
    const SMALL_BYTES: u64 = 2 * 1024 * 1024;
    let large_bytes: u64 = if preset == "many-large" {
        512 * 1024 * 1024
    } else {
        256 * 1024 * 1024
    };
    let mut netsim = colab_netsim();
    netsim.server.first_byte_latency_s = 8.0;
    netsim.client.write_cap_mbps = 0.0;
    netsim.client.file_overhead_beta = 0.0;
    let mut catalog = Catalog::empty();
    let mut records = Vec::new();
    if small > 0 {
        catalog.register_synthetic("CAMP-S", small, SMALL_BYTES);
        records.extend_from_slice(catalog.project_runs("CAMP-S")?);
    }
    if large > 0 {
        catalog.register_synthetic("CAMP-L", large, large_bytes);
        records.extend_from_slice(catalog.project_runs("CAMP-L")?);
    }
    let _ = seed;
    let download = DownloadConfig {
        campaign: true,
        pipeline_depth: 4,
        optimizer: crate::config::OptimizerConfig {
            probe_interval_s: 5.0,
            ..Default::default()
        },
        ..Default::default()
    };
    Ok(Scenario {
        name,
        netsim,
        download,
        records,
        c_star_theoretical: None,
    })
}

/// §5.2 FABRIC-style throttled high-speed profiles.
///
/// * `a`: 10 Gbps link, 500 Mbps per thread  → C* = 20
/// * `b`: 10 Gbps link, 1400 Mbps per thread → C* ≈ 7.1
/// * `c`: 20 Gbps link, 1400 Mbps per thread → C* ≈ 14.3
pub fn fabric(which: char, seed: u64) -> Result<Scenario> {
    let (name, link, cap, files, bytes_each): (&'static str, f64, f64, usize, u64) = match which
    {
        'a' => ("fabric-a", 10_000.0, 500.0, 4, 100_000_000_000),
        'b' => ("fabric-b", 10_000.0, 1_400.0, 4, 100_000_000_000),
        'c' => ("fabric-c", 20_000.0, 1_400.0, 2, 512_000_000_000),
        other => {
            return Err(Error::Config(format!(
                "unknown fabric scenario '{other}' (a|b|c)"
            )))
        }
    };
    let netsim = NetSimConfig {
        link_capacity_mbps: link,
        background: BackgroundConfig {
            // Testbed link: tiny residual fluctuation only.
            mean_mbps: link * 0.02,
            theta: 0.4,
            sigma: link * 0.01,
            max_mbps: link * 0.08,
        },
        server: ServerProfile {
            setup_latency_s: 0.12,
            first_byte_latency_s: 0.05,
            per_conn_cap_mbps: cap,
            long_request_decay_per_min: 0.0,
            decay_floor: 1.0,
            max_connections: 64,
        },
        client: ClientProfile::ideal(),
        flow_jitter_frac: 0.03,
        flow_failure_rate_per_min: 0.0,
        faults: FaultSchedule::none(),
        dt_s: 0.05,
    };
    let mut catalog = Catalog::empty();
    catalog.register_synthetic(name, files, bytes_each);
    let records = catalog.project_runs(name)?.to_vec();
    let _ = seed;
    let download = DownloadConfig {
        optimizer: crate::config::OptimizerConfig {
            probe_interval_s: 5.0,
            // High-speed scenarios need headroom above C*=20.
            c_max: 40,
            ..Default::default()
        },
        // Bigger chunks keep request overhead negligible at 20 Gbps.
        chunk_bytes: 256 * 1024 * 1024,
        ..Default::default()
    };
    Ok(Scenario {
        name,
        netsim,
        download,
        records,
        c_star_theoretical: Some(link / cap),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn colab_scenarios_build_and_validate() {
        for alias in ["Breast-RNA-seq", "HiFi-WGS", "Amplicon-Digester"] {
            let s = colab_dataset(alias, 1).unwrap();
            s.netsim.validate().unwrap();
            s.download.validate().unwrap();
            assert!(!s.records.is_empty());
        }
        assert!(colab_dataset("nope", 1).is_err());
    }

    #[test]
    fn campaign_presets_build_with_advertised_mixes() {
        let small = campaign("many-small", 1).unwrap();
        small.netsim.validate().unwrap();
        small.download.validate().unwrap();
        assert!(small.download.campaign);
        assert!(small.download.pipeline_depth > 1);
        assert_eq!(small.records.len(), 96);
        let threshold = small.download.coalesce_files_kb * 1024;
        assert!(small.records.iter().all(|r| r.bytes < threshold));

        let mixed = campaign("mixed", 1).unwrap();
        assert!(mixed.records.iter().any(|r| r.bytes < threshold));
        assert!(mixed.records.iter().any(|r| r.bytes >= threshold));

        let large = campaign("many-large", 1).unwrap();
        assert!(large.records.iter().all(|r| r.bytes >= threshold));
        assert!(campaign("tiny", 1).is_err());
    }

    #[test]
    fn fabric_c_star_values() {
        assert_eq!(fabric('a', 1).unwrap().c_star_theoretical, Some(20.0));
        let b = fabric('b', 1).unwrap().c_star_theoretical.unwrap();
        assert!((b - 7.14).abs() < 0.01);
        let c = fabric('c', 1).unwrap().c_star_theoretical.unwrap();
        assert!((c - 14.29).abs() < 0.01);
        assert!(fabric('x', 1).is_err());
    }

    #[test]
    fn fault_profiles_overlay_deterministically() {
        let a = colab_dataset("Breast-RNA-seq", 1)
            .unwrap()
            .with_fault_profile(FaultProfile::Chaos, 9, 600.0);
        let b = colab_dataset("Breast-RNA-seq", 1)
            .unwrap()
            .with_fault_profile(FaultProfile::Chaos, 9, 600.0);
        assert_eq!(a.netsim.faults, b.netsim.faults);
        assert!(!a.netsim.faults.is_empty());
        a.netsim.validate().unwrap();
        let c = colab_dataset("Breast-RNA-seq", 1)
            .unwrap()
            .with_fault_profile(FaultProfile::Chaos, 10, 600.0);
        assert_ne!(a.netsim.faults, c.netsim.faults);
    }

    #[test]
    fn hifi_has_stronger_client_pressure_than_breast() {
        let hifi = colab_dataset("HiFi-WGS", 1).unwrap();
        let breast = colab_dataset("Breast-RNA-seq", 1).unwrap();
        assert!(hifi.netsim.client.write_cap_mbps < breast.netsim.client.write_cap_mbps);
        assert!(
            hifi.netsim.client.file_overhead_beta > breast.netsim.client.file_overhead_beta
        );
        assert!(
            hifi.netsim.server.per_conn_cap_mbps < breast.netsim.server.per_conn_cap_mbps
        );
    }
}
