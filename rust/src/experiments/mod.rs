//! Experiment harness: one module per paper table/figure.
//!
//! Every module regenerates its artifact from scratch — workload
//! construction, the 5-run round-robin, metric extraction, and the
//! printed rows/series matching the paper's layout — and returns a
//! structured result the benches print and the integration tests
//! assert *shape* properties on (who wins, by roughly what factor).
//!
//! | Module   | Reproduces | Paper claim (shape)                                  |
//! |----------|------------|------------------------------------------------------|
//! | [`fig1`] | Figure 1   | single stream ≪ available bandwidth                  |
//! | [`fig2`] | Figure 2   | available bandwidth fluctuates on probe timescales   |
//! | [`table1`] | Table 1  | k=1.02 fastest; 1.01 over-aggressive; 1.05 conservative |
//! | [`fig4`] | Figure 4   | GD beats Bayesian by ≈20 % copy time                 |
//! | [`table3`] | Table 3  | FastBioDL beats prefetch/pysradb on all 3 datasets   |
//! | [`fig5`] | Figure 5   | higher peak, ≈38–43 % faster completion              |
//! | [`fig6`] | Figure 6   | adaptive ≈ C*, 1.3–2.1× over fixed 3/5               |
//!
//! [`scenario`] holds the calibrated simulation profiles (DESIGN.md §6)
//! and [`runner`] the shared multi-run orchestration.

pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod runner;
pub mod scenario;
pub mod table1;
pub mod table3;
