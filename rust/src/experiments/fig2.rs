//! Figure 2: real-world available bandwidth is inherently dynamic.
//!
//! The paper shows a two-minute iperf3 trace whose level moves
//! substantially within seconds — the core motivation for adaptive
//! (over static) concurrency. We regenerate the trace from the same
//! Ornstein–Uhlenbeck background process the scenarios use, sampled at
//! 1 Hz for the same two-minute horizon.
//!
//! Shape under test: the trace is *volatile* (coefficient of variation
//! above a few percent, range a large fraction of the mean) yet
//! *stationary* (no trend) — the regime where a static setting must be
//! wrong much of the time.

use crate::experiments::scenario;
use crate::netsim::NetSim;
use crate::Result;

/// The regenerated volatility trace.
#[derive(Clone, Debug)]
pub struct Fig2Result {
    pub t_s: Vec<f64>,
    /// Available bandwidth per second (Mbps).
    pub available_mbps: Vec<f64>,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Fig2Result {
    /// Coefficient of variation (std/mean).
    pub fn cv(&self) -> f64 {
        if self.mean > 0.0 {
            self.std / self.mean
        } else {
            0.0
        }
    }
}

/// Sample the available-bandwidth process for `duration_s` (paper: 120 s).
pub fn run(duration_s: f64, seed: u64) -> Result<Fig2Result> {
    let cfg = scenario::colab_dataset("Breast-RNA-seq", seed)?.netsim;
    let mut sim = NetSim::new(cfg.clone(), seed)?;
    let steps_per_s = (1.0 / cfg.dt_s).round() as usize;
    let mut t_s = Vec::new();
    let mut series = Vec::new();
    let mut acc = 0.0;
    let mut steps = 0usize;
    while sim.now() < duration_s {
        let rep = sim.step(None);
        acc += (cfg.link_capacity_mbps - rep.background_mbps).max(0.0);
        steps += 1;
        if steps == steps_per_s {
            t_s.push(sim.now().round());
            series.push(acc / steps as f64);
            acc = 0.0;
            steps = 0;
        }
    }
    let n = series.len().max(1) as f64;
    let mean = series.iter().sum::<f64>() / n;
    let var = series.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Ok(Fig2Result {
        mean,
        std: var.sqrt(),
        min: series.iter().copied().fold(f64::INFINITY, f64::min),
        max: series.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        t_s,
        available_mbps: series,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_is_volatile_but_stationary() {
        let r = run(120.0, 5).unwrap();
        assert_eq!(r.available_mbps.len(), 120);
        assert!(r.cv() > 0.03, "trace too flat: cv={}", r.cv());
        assert!(
            (r.max - r.min) / r.mean > 0.15,
            "range too small: {}..{} around {}",
            r.min,
            r.max,
            r.mean
        );
        // Stationary: first-half and second-half means within 15%.
        let half = r.available_mbps.len() / 2;
        let m1: f64 = r.available_mbps[..half].iter().sum::<f64>() / half as f64;
        let m2: f64 = r.available_mbps[half..].iter().sum::<f64>() / half as f64;
        assert!((m1 - m2).abs() / r.mean < 0.15, "trend detected: {m1} vs {m2}");
    }
}
