//! Table 1: the penalty coefficient `k` trades convergence aggressiveness
//! against concurrency overhead.
//!
//! Paper rows (Colab-like setting):
//!
//! | k    | Avg Download Speed (Mbps) | Avg Concurrency |
//! |------|---------------------------|-----------------|
//! | 1.01 | 701.2                     | 6.77            |
//! | 1.02 | 815.8                     | 6.23            |
//! | 1.05 | 743.9                     | 4.64            |
//!
//! Shape under test: k = 1.02 yields the best speed; 1.01 runs *more*
//! concurrency for less speed (overhead regime); 1.05 runs visibly
//! fewer threads (conservative regime). Absolute numbers differ — the
//! substrate is the simulator.

use crate::experiments::runner::{run_tool, Tool, ToolSummary};
use crate::experiments::scenario;
use crate::runtime::SharedRuntime;
use crate::Result;

/// One row of the sweep.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub k: f64,
    pub summary: ToolSummary,
}

/// The swept values, as published.
pub const K_VALUES: [f64; 3] = [1.01, 1.02, 1.05];

/// Run the sweep: `runs` seeds per k on the Breast-RNA-seq workload.
pub fn run(runtime: &SharedRuntime, runs: usize, seed_base: u64) -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for &k in &K_VALUES {
        let scenario = scenario::colab_dataset("Breast-RNA-seq", seed_base)?;
        let mut download = scenario.download.clone();
        download.optimizer.k = k;
        let tool = Tool::FastBioDl { download };
        let summary = run_tool(&scenario, &tool, runtime, runs, seed_base)?;
        rows.push(Table1Row { k, summary });
    }
    Ok(rows)
}

/// Shape assertions shared by the bench and the integration test.
///
/// What reproduces robustly on this substrate (see EXPERIMENTS.md
/// §Table 1 for the divergence discussion): concurrency is monotone in
/// the penalty — a smaller k always runs at least as many threads, and
/// k = 1.05 is strictly the most conservative — and the selected
/// k = 1.02 is never materially beaten on speed (within 3 % of the best
/// row). The paper's sharper 14 % speed hump depends on its testbed's
/// harsher thread-overhead curvature, which our calibrated Colab
/// profile reproduces only mildly.
pub fn check_shape(rows: &[Table1Row]) -> std::result::Result<(), String> {
    if rows.len() != 3 {
        return Err(format!("expected 3 rows, got {}", rows.len()));
    }
    let speed = |i: usize| rows[i].summary.speed_mbps.mean;
    let conc = |i: usize| rows[i].summary.concurrency.mean;
    // Concurrency monotone in k (small tolerance between the two
    // near-identical aggressive settings); 1.05 strictly most
    // conservative.
    if !(conc(0) >= conc(1) - 0.15 && conc(0) > conc(2) && conc(1) > conc(2)) {
        return Err(format!(
            "concurrency must decrease with k: {:.2}/{:.2}/{:.2}",
            conc(0),
            conc(1),
            conc(2)
        ));
    }
    // k = 1.02 within 3% of the best speed (never materially beaten).
    let best = speed(0).max(speed(1)).max(speed(2));
    if speed(1) < best * 0.97 {
        return Err(format!(
            "k=1.02 materially beaten: speeds {:.1}/{:.1}/{:.1}",
            speed(0),
            speed(1),
            speed(2)
        ));
    }
    Ok(())
}
