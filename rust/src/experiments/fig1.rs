//! Figure 1: single-threaded downloads underutilize the network.
//!
//! The paper measures a single-threaded FTP download against the
//! available bandwidth reported by iperf3. We reproduce the same
//! comparison on the simulator: one continuously-busy flow (the
//! `fastq-dump` shape) against the link's instantaneous available
//! bandwidth, sampled per second.
//!
//! Shape under test: `mean(single-stream goodput) ≪ mean(available)` —
//! the gap is the per-connection server cap plus long-request decay,
//! which is exactly what parallel streams recover.

use crate::experiments::scenario;
use crate::netsim::NetSim;
use crate::Result;

/// Per-second traces of the comparison.
#[derive(Clone, Debug)]
pub struct Fig1Result {
    /// Seconds axis.
    pub t_s: Vec<f64>,
    /// Single-stream goodput (Mbps).
    pub single_stream_mbps: Vec<f64>,
    /// Available bandwidth (link − background, Mbps).
    pub available_mbps: Vec<f64>,
    pub mean_single: f64,
    pub mean_available: f64,
}

impl Fig1Result {
    /// Utilization fraction of the single stream.
    pub fn utilization(&self) -> f64 {
        if self.mean_available <= 0.0 {
            0.0
        } else {
            self.mean_single / self.mean_available
        }
    }
}

/// Run the Figure 1 measurement for `duration_s` simulated seconds.
pub fn run(duration_s: f64, seed: u64) -> Result<Fig1Result> {
    // Colab-like WAN: the Figure 1 setting (public archive over WAN).
    let mut cfg = scenario::colab_dataset("Breast-RNA-seq", seed)?.netsim;
    // A single endless request: disable staging latency, which is
    // irrelevant to this figure's point (the per-conn cap).
    cfg.server.first_byte_latency_s = 0.0;
    let mut sim = NetSim::new(cfg.clone(), seed)?;
    let flow = sim.open_flow()?;
    while !sim.flow_ready(flow) {
        sim.step(None);
    }
    sim.begin_request(flow, 1e15, false, 0)?;

    let mut t_s = Vec::new();
    let mut single = Vec::new();
    let mut avail = Vec::new();
    let mut acc_bytes = 0.0;
    let mut acc_avail = 0.0;
    let mut steps = 0usize;
    let steps_per_s = (1.0 / cfg.dt_s).round() as usize;
    let start = sim.now();
    while sim.now() - start < duration_s {
        let rep = sim.step(None);
        acc_bytes += rep.total_bytes;
        acc_avail += (cfg.link_capacity_mbps - rep.background_mbps).max(0.0);
        steps += 1;
        if steps == steps_per_s {
            t_s.push((sim.now() - start).round());
            single.push(acc_bytes * 8.0 / 1e6);
            avail.push(acc_avail / steps as f64);
            acc_bytes = 0.0;
            acc_avail = 0.0;
            steps = 0;
        }
    }
    let mean_single = single.iter().sum::<f64>() / single.len().max(1) as f64;
    let mean_available = avail.iter().sum::<f64>() / avail.len().max(1) as f64;
    Ok(Fig1Result {
        t_s,
        single_stream_mbps: single,
        available_mbps: avail,
        mean_single,
        mean_available,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_stream_underutilizes() {
        let r = run(60.0, 3).unwrap();
        assert_eq!(r.t_s.len(), 60);
        assert!(
            r.utilization() < 0.35,
            "single stream should use <35% of available, got {:.2}",
            r.utilization()
        );
        assert!(r.mean_single > 0.0);
    }
}
