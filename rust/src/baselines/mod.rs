//! Behavioural models of the comparison tools (paper §5.1).
//!
//! The paper compares FastBioDL against the SRA Toolkit's `prefetch`,
//! `pysradb`, and (motivationally) `fastq-dump`. We cannot run the real
//! binaries against real archives offline, so each tool is modelled by
//! the four behaviours that determine its transfer performance — all
//! documented from the tools' public behaviour and the paper's own
//! description (§2, §5.1):
//!
//! | Tool        | Concurrency | Granularity | Connections     | Resolution        |
//! |-------------|-------------|-------------|-----------------|-------------------|
//! | prefetch    | fixed 3     | whole-file  | fresh per file  | per-file, serial  |
//! | pysradb     | fixed 8     | whole-file  | fresh per file  | per-file, serial  |
//! | fastq-dump  | fixed 1     | whole-file  | fresh per file  | per-file, serial  |
//! | FastBioDL   | adaptive    | chunked     | keep-alive pool | batch up front    |
//!
//! "Per-file, serial" resolution is the shared SRA name-resolution
//! path both baselines funnel through; it is why their Amplicon-Digester
//! speeds are nearly identical (29.15 vs 29.10 Mbps in Table 3) despite
//! 3 vs 8 workers — see `accession::resolver` for the model.
//!
//! Each model produces a [`ToolBehavior`] plus an
//! [`crate::config::OptimizerConfig`] for its (fixed) controller, so a
//! baseline run uses the *identical* session driver as FastBioDL.

use crate::accession::resolver::ResolutionCost;
use crate::config::{DownloadConfig, OptimizerConfig, OptimizerKind};
use crate::coordinator::scheduler::SchedulerMode;
use crate::session::sim::ToolBehavior;

/// Default serialized resolution latency per file (s) for SRA-toolkit
/// style tools (name service round trip + local metadata bookkeeping;
/// calibrated in DESIGN.md §6 / EXPERIMENTS.md §Calibration).
pub const SRA_RESOLVE_LATENCY_S: f64 = 11.0;

/// A named baseline tool model.
#[derive(Clone, Debug)]
pub struct BaselineTool {
    pub behavior: ToolBehavior,
    pub optimizer: OptimizerConfig,
}

impl BaselineTool {
    /// SRA Toolkit `prefetch`: static 3 threads, whole files.
    pub fn prefetch() -> BaselineTool {
        BaselineTool::fixed_tool("prefetch", 3, SRA_RESOLVE_LATENCY_S)
    }

    /// `pysradb`: static 8 threads (the paper's choice), whole files.
    pub fn pysradb() -> BaselineTool {
        BaselineTool::fixed_tool("pysradb", 8, SRA_RESOLVE_LATENCY_S)
    }

    /// `fastq-dump`: single-threaded (the Figure 1 motivation case).
    pub fn fastq_dump() -> BaselineTool {
        BaselineTool::fixed_tool("fastq-dump", 1, SRA_RESOLVE_LATENCY_S)
    }

    /// A FastBioDL-shaped tool pinned to a fixed concurrency — the
    /// "fixed concurrency levels of 3 and 5" arms of Figure 6 (chunked,
    /// keep-alive, batch resolution; only the controller is static).
    pub fn fixed_fastbiodl(level: usize, cfg: &DownloadConfig) -> BaselineTool {
        let mut optimizer = cfg.optimizer.clone();
        optimizer.kind = OptimizerKind::Fixed;
        optimizer.fixed_level = level;
        optimizer.c_init = level;
        let mut behavior = ToolBehavior::fastbiodl(cfg);
        behavior.name = format!("fixed-{level}");
        BaselineTool {
            behavior,
            optimizer,
        }
    }

    fn fixed_tool(name: &str, level: usize, resolve_s: f64) -> BaselineTool {
        let optimizer = OptimizerConfig {
            kind: OptimizerKind::Fixed,
            fixed_level: level,
            c_init: level,
            // c_max bounds the status array; fixed tools never move.
            c_max: level.max(8),
            ..OptimizerConfig::default()
        };
        BaselineTool {
            behavior: ToolBehavior {
                name: name.into(),
                mode: SchedulerMode::WholeFile,
                keep_alive: false,
                resolution: ResolutionCost::PerFileSerialized {
                    latency_s: resolve_s,
                },
            },
            optimizer,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_shape() {
        let t = BaselineTool::prefetch();
        assert_eq!(t.optimizer.fixed_level, 3);
        assert_eq!(t.behavior.mode, SchedulerMode::WholeFile);
        assert!(!t.behavior.keep_alive);
        assert_eq!(
            t.behavior.resolution,
            ResolutionCost::PerFileSerialized {
                latency_s: SRA_RESOLVE_LATENCY_S
            }
        );
        t.optimizer.validate().unwrap();
    }

    #[test]
    fn pysradb_is_eight_threads() {
        let t = BaselineTool::pysradb();
        assert_eq!(t.optimizer.fixed_level, 8);
        t.optimizer.validate().unwrap();
    }

    #[test]
    fn fixed_fastbiodl_keeps_fastbiodl_behaviour() {
        let cfg = DownloadConfig::default();
        let t = BaselineTool::fixed_fastbiodl(5, &cfg);
        assert_eq!(t.behavior.name, "fixed-5");
        assert!(t.behavior.keep_alive);
        assert!(matches!(t.behavior.mode, SchedulerMode::Chunked { .. }));
        assert_eq!(t.optimizer.fixed_level, 5);
        t.optimizer.validate().unwrap();
    }
}
