//! Counting global allocator — the "simple counting allocator" the
//! macro-benchmark harness uses to report allocations per control-loop
//! tick.
//!
//! [`CountingAlloc`] wraps [`std::alloc::System`] and bumps a
//! **per-thread** counter on every allocation path. Per-thread for two
//! reasons: `cargo test` runs suites concurrently, and a process-wide
//! count would attribute a neighbouring test's allocations to the case
//! being measured; and a shared atomic would put a contended
//! cache-line RMW on every allocation of the real multi-threaded
//! download path, which nothing would even read. The overhead is one
//! TLS increment per allocation, far below measurement noise for
//! anything the harness times.
//!
//! The allocator is installed crate-wide (`#[global_allocator]` below),
//! so the engine's "allocation-free steady-state tick" claim is
//! checkable from any test or binary linking `fastbiodl`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

/// System allocator plus a per-thread allocation counter (see module
/// docs).
pub struct CountingAlloc;

thread_local! {
    // `const` init: reading/writing the cell can never itself allocate,
    // which would recurse into the allocator.
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn count_one() {
    // `try_with`: TLS may already be torn down during thread exit;
    // losing those few counts is fine, panicking in `alloc` is not.
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count_one();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count_one();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        count_one();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Allocations performed by the *current thread* since it started.
/// Subtract two readings to count a measured region.
pub fn thread_allocations() -> u64 {
    THREAD_ALLOCS.try_with(Cell::get).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_counter_observes_allocations() {
        let before = thread_allocations();
        let v: Vec<u64> = Vec::with_capacity(128);
        std::hint::black_box(&v);
        let after = thread_allocations();
        assert!(after > before, "allocation was not counted");
        drop(v);
        // Frees are not counted.
        let freed = thread_allocations();
        assert_eq!(freed, after);
    }
}
