//! `bench` — the deterministic macro-benchmark subsystem.
//!
//! The paper's core claim is *throughput*, yet nothing in the repo
//! previously emitted a machine-readable performance trajectory. This
//! module runs a named suite of scenarios over the virtual-clock
//! netsim path and measures, per case:
//!
//! * **simulated outcome** (deterministic per `(suite, seed)`):
//!   goodput, bytes, retries, resets, rejects, mirror switches, probe
//!   count — identical on every machine and every run;
//! * **real control-loop cost** (varies with the machine): wall time,
//!   engine ticks, ns/tick, ticks/sec, allocations per tick (via the
//!   [`self::alloc`] counting allocator), and the slot-reconciliation
//!   scan cost ([`crate::session::EngineStats::slots_scanned`]).
//!
//! The full suite is the grid *three Table-2 dataset presets ×
//! {benign, slowmirror, brownout, flashcrowd} × {gd, bayes, fixed} ×
//! c_max ∈ {16, 64, 256}* — 108 cases — capped at
//! [`CASE_HORIZON_S`] virtual seconds each so hostile cells stay
//! bounded. Results serialize to a schema-versioned `BENCH_engine.json`
//! ([`BenchReport::to_json`]) suitable for cross-PR diffing, and
//! [`diff`] compares a fresh report against a stored baseline —
//! flagging timing regressions (ns/tick beyond a tolerance),
//! determinism drift (simulated fields that should be bit-stable), and
//! vanished cases.
//!
//! `fastbiodl bench --suite full` is the CLI entry;
//! `--reconcile full-scan` re-runs the same grid on the naive
//! slot-reconciliation path so the batched engine's win is measurable
//! (`rust/tests/engine_tick.rs` asserts it directionally at
//! `c_max = 256`).

pub mod alloc;

use std::time::Instant;

use crate::config::{OptimizerKind, ReconcileMode};
use crate::experiments::scenario;
use crate::netsim::FaultProfile;
use crate::optimizer::build_controller;
use crate::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use crate::util::json::{obj, Json};
use crate::{Error, Result};

/// Schema tag written into every report; bump on breaking layout
/// changes so baseline diffing fails loudly instead of silently.
pub const SCHEMA_VERSION: &str = "fastbiodl-bench-v1";

/// Virtual-time cap per case (s): hostile cells (brownouts at
/// `c_max = 16`) would otherwise run long; every case reports goodput
/// over the time it actually ran, `completed` says whether it finished
/// inside the cap. Deterministic either way.
pub const CASE_HORIZON_S: f64 = 240.0;

/// Default relative ns/tick increase treated as a timing regression by
/// [`diff`].
pub const DEFAULT_TIMING_TOLERANCE: f64 = 0.35;

/// A named benchmark suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// 4 fast cases (CI artifact): Amplicon-Digester × {benign,
    /// slowmirror} × gd × c_max {16, 256}.
    Smoke,
    /// The full 108-case grid (see module docs).
    Full,
}

impl Suite {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Suite> {
        match s.to_ascii_lowercase().as_str() {
            "smoke" => Ok(Suite::Smoke),
            "full" => Ok(Suite::Full),
            other => Err(Error::Config(format!(
                "unknown bench suite '{other}' (expected smoke | full)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Suite::Smoke => "smoke",
            Suite::Full => "full",
        }
    }
}

/// One scenario×fault×controller×c_max cell of the grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CaseSpec {
    /// Table-2 dataset alias (`Breast-RNA-seq` | `HiFi-WGS` |
    /// `Amplicon-Digester`).
    pub dataset: &'static str,
    /// Fault overlay (`None` = benign network).
    pub profile: FaultProfile,
    /// Concurrency controller under test.
    pub optimizer: OptimizerKind,
    /// Worker-pool capacity.
    pub c_max: usize,
}

/// Short controller tag used in case ids ("gd" | "bayes" | "fixed").
fn optimizer_tag(kind: OptimizerKind) -> &'static str {
    match kind {
        OptimizerKind::GradientDescent => "gd",
        OptimizerKind::Bayesian => "bayes",
        OptimizerKind::Fixed => "fixed",
    }
}

impl CaseSpec {
    /// Stable identifier used as the baseline-diff key.
    pub fn id(&self) -> String {
        format!(
            "{}/{}/{}/c{}",
            self.dataset,
            self.profile.name(),
            optimizer_tag(self.optimizer),
            self.c_max
        )
    }
}

/// Expand a suite into its ordered case list.
pub fn suite_cases(suite: Suite) -> Vec<CaseSpec> {
    let mut cases = Vec::new();
    match suite {
        Suite::Smoke => {
            for profile in [FaultProfile::None, FaultProfile::SlowMirror] {
                for c_max in [16, 256] {
                    cases.push(CaseSpec {
                        dataset: "Amplicon-Digester",
                        profile,
                        optimizer: OptimizerKind::GradientDescent,
                        c_max,
                    });
                }
            }
        }
        Suite::Full => {
            for dataset in ["Breast-RNA-seq", "HiFi-WGS", "Amplicon-Digester"] {
                for profile in [
                    FaultProfile::None,
                    FaultProfile::SlowMirror,
                    FaultProfile::Brownout,
                    FaultProfile::FlashCrowd,
                ] {
                    for optimizer in [
                        OptimizerKind::GradientDescent,
                        OptimizerKind::Bayesian,
                        OptimizerKind::Fixed,
                    ] {
                        for c_max in [16, 64, 256] {
                            cases.push(CaseSpec {
                                dataset,
                                profile,
                                optimizer,
                                c_max,
                            });
                        }
                    }
                }
            }
        }
    }
    cases
}

/// One measured cell: the spec, the deterministic simulated outcome,
/// and the machine-dependent control-loop timing.
#[derive(Clone, Debug)]
pub struct CaseResult {
    /// Stable case id (`dataset/profile/controller/cN`).
    pub id: String,
    pub dataset: String,
    pub profile: String,
    pub optimizer: String,
    pub c_max: usize,
    // --- Deterministic per (suite, seed): ---
    pub goodput_mbps: f64,
    pub total_bytes: u64,
    pub duration_s: f64,
    pub chunk_retries: u64,
    pub connection_resets: u64,
    pub server_rejects: u64,
    pub mirror_switches: u64,
    pub probes: u64,
    pub files_completed: u64,
    pub completed: bool,
    // --- Timing (varies run to run): ---
    pub wall_s: f64,
    pub ticks: u64,
    pub ns_per_tick: f64,
    pub ticks_per_sec: f64,
    pub allocs_per_tick: f64,
    pub slots_scanned_per_tick: f64,
    pub max_probe_releases_per_tick: u64,
}

/// Run one grid cell to completion (or the [`CASE_HORIZON_S`] cap).
///
/// Runtime-free by construction (pure-Rust mirror controllers), so the
/// harness produces identical simulated fields on any machine,
/// including bare checkouts without compiled XLA artifacts.
pub fn run_case(spec: &CaseSpec, seed: u64, reconcile: ReconcileMode) -> Result<CaseResult> {
    let mut sc = scenario::colab_dataset(spec.dataset, seed)?;
    sc.download.optimizer.kind = spec.optimizer;
    sc.download.optimizer.c_max = spec.c_max;
    if spec.optimizer == OptimizerKind::Fixed {
        sc.download.optimizer.c_init = sc.download.optimizer.fixed_level;
    }
    sc.download.reconcile = reconcile;
    if spec.profile != FaultProfile::None {
        sc = sc.with_fault_profile(spec.profile, seed, CASE_HORIZON_S);
    }
    let controller = build_controller(&sc.download.optimizer, None)?;
    let behavior = ToolBehavior::fastbiodl(&sc.download);
    let session = SimSession::new(SimSessionParams {
        download: sc.download,
        behavior,
        netsim: sc.netsim,
        records: sc.records,
        controller,
        runtime: None,
        seed,
    })
    .with_checkpoint_after(CASE_HORIZON_S);

    let allocs_before = alloc::thread_allocations();
    let t0 = Instant::now();
    let (report, stats) = session.run_with_stats()?;
    let wall_s = t0.elapsed().as_secs_f64();
    let allocs = alloc::thread_allocations().saturating_sub(allocs_before);

    let ticks = stats.ticks.max(1);
    Ok(CaseResult {
        id: spec.id(),
        dataset: spec.dataset.to_string(),
        profile: spec.profile.name().to_string(),
        optimizer: optimizer_tag(spec.optimizer).to_string(),
        c_max: spec.c_max,
        goodput_mbps: report.mean_throughput_mbps,
        total_bytes: report.total_bytes,
        duration_s: report.duration_s,
        chunk_retries: report.chunk_retries as u64,
        connection_resets: report.connection_resets as u64,
        server_rejects: report.server_rejects as u64,
        mirror_switches: report.mirror_switches as u64,
        probes: report.probes as u64,
        files_completed: report.files_completed as u64,
        completed: report.completed,
        wall_s,
        ticks: stats.ticks,
        ns_per_tick: wall_s * 1e9 / ticks as f64,
        ticks_per_sec: ticks as f64 / wall_s.max(1e-12),
        allocs_per_tick: allocs as f64 / ticks as f64,
        slots_scanned_per_tick: stats.slots_scanned as f64 / ticks as f64,
        max_probe_releases_per_tick: stats.max_probe_releases_per_tick as u64,
    })
}

/// A complete benchmark report (header + per-case records).
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub suite: String,
    pub seed: u64,
    pub reconcile: String,
    pub cases: Vec<CaseResult>,
}

impl BenchReport {
    /// Serialize to the schema-versioned JSON document (deterministic
    /// key order; the `timing` sub-objects are the only fields expected
    /// to differ between two runs of the same suite+seed).
    pub fn to_json(&self) -> Json {
        let cpus = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(0);
        let machine = obj(vec![
            ("os", Json::Str(std::env::consts::OS.into())),
            ("arch", Json::Str(std::env::consts::ARCH.into())),
            ("cpus", Json::Num(cpus as f64)),
        ]);
        let header = obj(vec![
            ("schema", Json::Str(SCHEMA_VERSION.into())),
            ("suite", Json::Str(self.suite.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("reconcile", Json::Str(self.reconcile.clone())),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            ("machine", machine),
        ]);
        let cases: Vec<Json> = self
            .cases
            .iter()
            .map(|c| {
                obj(vec![
                    ("id", Json::Str(c.id.clone())),
                    ("dataset", Json::Str(c.dataset.clone())),
                    ("profile", Json::Str(c.profile.clone())),
                    ("optimizer", Json::Str(c.optimizer.clone())),
                    ("c_max", Json::Num(c.c_max as f64)),
                    (
                        "det",
                        obj(vec![
                            ("goodput_mbps", Json::Num(c.goodput_mbps)),
                            ("total_bytes", Json::Num(c.total_bytes as f64)),
                            ("duration_s", Json::Num(c.duration_s)),
                            ("chunk_retries", Json::Num(c.chunk_retries as f64)),
                            ("connection_resets", Json::Num(c.connection_resets as f64)),
                            ("server_rejects", Json::Num(c.server_rejects as f64)),
                            ("mirror_switches", Json::Num(c.mirror_switches as f64)),
                            ("probes", Json::Num(c.probes as f64)),
                            ("files_completed", Json::Num(c.files_completed as f64)),
                            ("completed", Json::Bool(c.completed)),
                        ]),
                    ),
                    (
                        "timing",
                        obj(vec![
                            ("wall_s", Json::Num(c.wall_s)),
                            ("ticks", Json::Num(c.ticks as f64)),
                            ("ns_per_tick", Json::Num(c.ns_per_tick)),
                            ("ticks_per_sec", Json::Num(c.ticks_per_sec)),
                            ("allocs_per_tick", Json::Num(c.allocs_per_tick)),
                            ("slots_scanned_per_tick", Json::Num(c.slots_scanned_per_tick)),
                            (
                                "max_probe_releases_per_tick",
                                Json::Num(c.max_probe_releases_per_tick as f64),
                            ),
                        ]),
                    ),
                ])
            })
            .collect();
        obj(vec![("header", header), ("cases", Json::Arr(cases))])
    }

    /// Parse a report previously written by [`BenchReport::to_json`].
    pub fn from_json(text: &str) -> Result<BenchReport> {
        let j = Json::parse(text)?;
        let header = j.require("header")?;
        let schema = header
            .require("schema")?
            .as_str()
            .ok_or_else(|| Error::Config("bench header.schema must be a string".into()))?;
        if schema != SCHEMA_VERSION {
            return Err(Error::Config(format!(
                "bench schema mismatch: file is '{schema}', this binary reads '{SCHEMA_VERSION}'"
            )));
        }
        let req_str = |v: &Json, k: &str| -> Result<String> {
            Ok(v.require(k)?
                .as_str()
                .ok_or_else(|| Error::Config(format!("bench field '{k}' must be a string")))?
                .to_string())
        };
        let req_f64 = |v: &Json, k: &str| -> Result<f64> {
            v.require(k)?
                .as_f64()
                .ok_or_else(|| Error::Config(format!("bench field '{k}' must be a number")))
        };
        let req_u64 = |v: &Json, k: &str| -> Result<u64> {
            v.require(k)?
                .as_u64()
                .ok_or_else(|| Error::Config(format!("bench field '{k}' must be an integer")))
        };
        let mut cases = Vec::new();
        for c in j
            .require("cases")?
            .as_arr()
            .ok_or_else(|| Error::Config("bench 'cases' must be an array".into()))?
        {
            let det = c.require("det")?;
            let timing = c.require("timing")?;
            cases.push(CaseResult {
                id: req_str(c, "id")?,
                dataset: req_str(c, "dataset")?,
                profile: req_str(c, "profile")?,
                optimizer: req_str(c, "optimizer")?,
                c_max: req_u64(c, "c_max")? as usize,
                goodput_mbps: req_f64(det, "goodput_mbps")?,
                total_bytes: req_u64(det, "total_bytes")?,
                duration_s: req_f64(det, "duration_s")?,
                chunk_retries: req_u64(det, "chunk_retries")?,
                connection_resets: req_u64(det, "connection_resets")?,
                server_rejects: req_u64(det, "server_rejects")?,
                mirror_switches: req_u64(det, "mirror_switches")?,
                probes: req_u64(det, "probes")?,
                files_completed: req_u64(det, "files_completed")?,
                completed: matches!(*det.require("completed")?, Json::Bool(true)),
                wall_s: req_f64(timing, "wall_s")?,
                ticks: req_u64(timing, "ticks")?,
                ns_per_tick: req_f64(timing, "ns_per_tick")?,
                ticks_per_sec: req_f64(timing, "ticks_per_sec")?,
                allocs_per_tick: req_f64(timing, "allocs_per_tick")?,
                slots_scanned_per_tick: req_f64(timing, "slots_scanned_per_tick")?,
                max_probe_releases_per_tick: req_u64(timing, "max_probe_releases_per_tick")?,
            });
        }
        Ok(BenchReport {
            suite: req_str(header, "suite")?,
            seed: req_u64(header, "seed")?,
            reconcile: req_str(header, "reconcile")?,
            cases,
        })
    }
}

/// What kind of baseline deviation [`diff`] found.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegressionKind {
    /// ns/tick grew beyond the tolerance.
    Timing,
    /// A simulated field that must be bit-stable for the same
    /// suite+seed changed — the engine's behaviour drifted.
    Determinism,
    /// A baseline case is missing from the current report.
    Missing,
}

impl RegressionKind {
    /// Short label for CLI output.
    pub fn name(&self) -> &'static str {
        match self {
            RegressionKind::Timing => "timing",
            RegressionKind::Determinism => "determinism",
            RegressionKind::Missing => "missing",
        }
    }
}

/// One flagged deviation from the baseline.
#[derive(Clone, Debug)]
pub struct Regression {
    pub case_id: String,
    pub kind: RegressionKind,
    pub detail: String,
}

/// Compare `current` against `baseline`; returns every regression
/// found (empty = clean). Timing regressions use `tolerance` as the
/// allowed relative ns/tick increase; determinism checks only apply
/// when the two reports ran the same suite and seed.
pub fn diff(current: &BenchReport, baseline: &BenchReport, tolerance: f64) -> Vec<Regression> {
    let mut out = Vec::new();
    let comparable = current.suite == baseline.suite && current.seed == baseline.seed;
    for base in &baseline.cases {
        let Some(cur) = current.cases.iter().find(|c| c.id == base.id) else {
            out.push(Regression {
                case_id: base.id.clone(),
                kind: RegressionKind::Missing,
                detail: "case present in baseline but not in current report".into(),
            });
            continue;
        };
        if comparable {
            let det_drift = cur.total_bytes != base.total_bytes
                || cur.chunk_retries != base.chunk_retries
                || cur.connection_resets != base.connection_resets
                || cur.server_rejects != base.server_rejects
                || cur.mirror_switches != base.mirror_switches
                || cur.probes != base.probes
                || cur.files_completed != base.files_completed
                || cur.completed != base.completed
                || (cur.goodput_mbps - base.goodput_mbps).abs() > base.goodput_mbps.abs() * 1e-9;
            if det_drift {
                out.push(Regression {
                    case_id: base.id.clone(),
                    kind: RegressionKind::Determinism,
                    detail: format!(
                        "simulated fields drifted (goodput {:.3} -> {:.3} Mbps, bytes {} -> {}, \
                         retries {} -> {})",
                        base.goodput_mbps,
                        cur.goodput_mbps,
                        base.total_bytes,
                        cur.total_bytes,
                        base.chunk_retries,
                        cur.chunk_retries
                    ),
                });
            }
        }
        if base.ns_per_tick > 0.0 && cur.ns_per_tick > base.ns_per_tick * (1.0 + tolerance) {
            out.push(Regression {
                case_id: base.id.clone(),
                kind: RegressionKind::Timing,
                detail: format!(
                    "ns/tick {:.0} -> {:.0} (+{:.0}%, tolerance {:.0}%)",
                    base.ns_per_tick,
                    cur.ns_per_tick,
                    (cur.ns_per_tick / base.ns_per_tick - 1.0) * 100.0,
                    tolerance * 100.0
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_report() -> BenchReport {
        BenchReport {
            suite: "smoke".into(),
            seed: 1,
            reconcile: "batched".into(),
            cases: vec![CaseResult {
                id: "Amplicon-Digester/none/gd/c16".into(),
                dataset: "Amplicon-Digester".into(),
                profile: "none".into(),
                optimizer: "gd".into(),
                c_max: 16,
                goodput_mbps: 812.5,
                total_bytes: 1_910_000_000,
                duration_s: 19.0,
                chunk_retries: 0,
                connection_resets: 0,
                server_rejects: 0,
                mirror_switches: 2,
                probes: 4,
                files_completed: 43,
                completed: true,
                wall_s: 0.02,
                ticks: 400,
                ns_per_tick: 50_000.0,
                ticks_per_sec: 20_000.0,
                allocs_per_tick: 0.4,
                slots_scanned_per_tick: 9.0,
                max_probe_releases_per_tick: 1,
            }],
        }
    }

    #[test]
    fn report_json_roundtrips() {
        let r = tiny_report();
        let text = r.to_json().to_string_compact();
        let back = BenchReport::from_json(&text).unwrap();
        assert_eq!(back.suite, r.suite);
        assert_eq!(back.seed, r.seed);
        assert_eq!(back.cases.len(), 1);
        let (a, b) = (&back.cases[0], &r.cases[0]);
        assert_eq!(a.id, b.id);
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.ticks, b.ticks);
        assert!((a.goodput_mbps - b.goodput_mbps).abs() < 1e-9);
        assert!(a.completed);
    }

    #[test]
    fn from_json_rejects_other_schemas() {
        let r = tiny_report();
        let text = r
            .to_json()
            .to_string_compact()
            .replace(SCHEMA_VERSION, "fastbiodl-bench-v0");
        assert!(BenchReport::from_json(&text).is_err());
    }

    #[test]
    fn baseline_diff_flags_a_synthetic_timing_regression() {
        let baseline = tiny_report();
        let mut current = tiny_report();
        current.cases[0].ns_per_tick *= 2.0;
        let regs = diff(&current, &baseline, DEFAULT_TIMING_TOLERANCE);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert_eq!(regs[0].kind, RegressionKind::Timing);
        assert_eq!(regs[0].case_id, baseline.cases[0].id);
        // Inside the tolerance nothing fires.
        let mut ok = tiny_report();
        ok.cases[0].ns_per_tick *= 1.0 + DEFAULT_TIMING_TOLERANCE * 0.5;
        assert!(diff(&ok, &baseline, DEFAULT_TIMING_TOLERANCE).is_empty());
    }

    #[test]
    fn baseline_diff_flags_determinism_drift_and_missing_cases() {
        let baseline = tiny_report();
        let mut drift = tiny_report();
        drift.cases[0].total_bytes += 1;
        let regs = diff(&drift, &baseline, 10.0);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].kind, RegressionKind::Determinism);
        // A different seed must NOT be compared field-for-field.
        let mut other_seed = drift.clone();
        other_seed.seed = 2;
        assert!(diff(&other_seed, &baseline, 10.0).is_empty());
        // Vanished case.
        let mut empty = tiny_report();
        empty.cases.clear();
        let regs = diff(&empty, &baseline, 10.0);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].kind, RegressionKind::Missing);
    }

    #[test]
    fn suites_have_the_advertised_shapes() {
        let smoke = suite_cases(Suite::Smoke);
        assert_eq!(smoke.len(), 4);
        let full = suite_cases(Suite::Full);
        assert_eq!(full.len(), 108, "full grid is 3 x 4 x 3 x 3");
        assert!(full.len() >= 30);
        // Ids are unique (they key the baseline diff).
        let mut ids: Vec<String> = full.iter().map(CaseSpec::id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), full.len());
        assert!(Suite::parse("full").is_ok());
        assert!(Suite::parse("everything").is_err());
    }

    #[test]
    fn smoke_case_is_deterministic_across_two_runs() {
        let spec = CaseSpec {
            dataset: "Amplicon-Digester",
            profile: FaultProfile::SlowMirror,
            optimizer: OptimizerKind::GradientDescent,
            c_max: 16,
        };
        let a = run_case(&spec, 7, ReconcileMode::Batched).unwrap();
        let b = run_case(&spec, 7, ReconcileMode::Batched).unwrap();
        assert_eq!(a.goodput_mbps.to_bits(), b.goodput_mbps.to_bits());
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        assert_eq!(
            (a.chunk_retries, a.connection_resets, a.server_rejects),
            (b.chunk_retries, b.connection_resets, b.server_rejects)
        );
        assert_eq!(a.mirror_switches, b.mirror_switches);
        assert_eq!(a.probes, b.probes);
        assert_eq!(a.ticks, b.ticks, "tick count is part of the replay");
        assert!(a.total_bytes > 0, "case moved no bytes");
    }
}
