//! `bench` — the deterministic macro-benchmark subsystem.
//!
//! The paper's core claim is *throughput*, yet nothing in the repo
//! previously emitted a machine-readable performance trajectory. This
//! module runs a named suite of scenarios over the virtual-clock
//! netsim path and measures, per case:
//!
//! * **simulated outcome** (deterministic per `(suite, seed)`):
//!   goodput, bytes, retries, resets, rejects, mirror switches, probe
//!   count — identical on every machine and every run;
//! * **real control-loop cost** (varies with the machine): wall time,
//!   engine ticks, ns/tick, ticks/sec, allocations per tick (via the
//!   [`self::alloc`] counting allocator), and the slot-reconciliation
//!   scan cost ([`crate::session::EngineStats::slots_scanned`]).
//!
//! The full suite is the grid *three Table-2 dataset presets ×
//! {benign, slowmirror, brownout, flashcrowd} × {gd, bayes, fixed} ×
//! c_max ∈ {16, 64, 256}* — 108 cases — capped at
//! [`CASE_HORIZON_S`] virtual seconds each so hostile cells stay
//! bounded. Results serialize to a schema-versioned `BENCH_engine.json`
//! ([`BenchReport::to_json`]) suitable for cross-PR diffing, and
//! [`diff`] compares a fresh report against a stored baseline —
//! flagging timing regressions (ns/tick beyond a tolerance),
//! determinism drift (simulated fields that should be bit-stable), and
//! vanished cases.
//!
//! `fastbiodl bench --suite full` is the CLI entry;
//! `--reconcile full-scan` re-runs the same grid on the naive
//! slot-reconciliation path so the batched engine's win is measurable
//! (`rust/tests/engine_tick.rs` asserts it directionally at
//! `c_max = 256`).

pub mod alloc;

use std::time::Instant;

use crate::config::{OptimizerKind, ReconcileMode};
use crate::experiments::scenario;
use crate::netsim::FaultProfile;
use crate::optimizer::build_controller_with;
use crate::session::sim::{SimSession, SimSessionParams, ToolBehavior};
use crate::trace::{Tracer, DEFAULT_CAPACITY};
use crate::util::json::{obj, Json};
use crate::{Error, Result};

/// Schema tag written into every report; bump on breaking layout
/// changes so baseline diffing fails loudly instead of silently.
/// v2 added the control-plane signal fields (`retry_rate`,
/// `reject_rate`, `chunks_scaled`) to the `det` record. v3 added the
/// disk-path fields (`write_syscalls_per_chunk`, `sink_queue_peak`,
/// `reactor_stall_ns`) to the timing record — zero on the simulated
/// grid, populated by real-transport runs through the same
/// `EngineStats` plumbing. v4 added the integrity dimension: a
/// `verify` case flag and the measured `hash_ns_per_mb` timing field
/// (SHA-256 cost per MiB of payload; 0 on non-verify cases). v5 added
/// the observability dimension: a `trace` case flag (the case ran with
/// the flight recorder attached) and the deterministic `trace_events`
/// det field (events recorded; 0 on non-trace cases). v6 added the
/// campaign dimension: the `campaign` suite (many-small / mixed /
/// many-large synthetic presets run in campaign mode with request
/// trains and pipelining) and the deterministic `files_per_sec` det
/// field (files completed per simulated second) on every case.
pub const SCHEMA_VERSION: &str = "fastbiodl-bench-v6";

/// Virtual-time cap per case (s): hostile cells (brownouts at
/// `c_max = 16`) would otherwise run long; every case reports goodput
/// over the time it actually ran, `completed` says whether it finished
/// inside the cap. Deterministic either way.
pub const CASE_HORIZON_S: f64 = 240.0;

/// Default relative ns/tick increase treated as a timing regression by
/// [`diff`].
pub const DEFAULT_TIMING_TOLERANCE: f64 = 0.35;

/// A named benchmark suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// 5 fast cases (CI artifact): Amplicon-Digester × {benign,
    /// slowmirror} × gd × c_max {16, 256}, plus one benign
    /// c_max = 1024 case guarding the engine hot path at the
    /// reactor-era slot-table scale.
    Smoke,
    /// The full 108-case grid (see module docs).
    Full,
    /// The 3 many-file campaign presets (many-small / mixed /
    /// many-large; see [`crate::experiments::scenario::campaign`]) run
    /// in campaign mode — request trains + pipelining — with files/sec
    /// as the headline deterministic metric.
    Campaign,
}

impl Suite {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Suite> {
        match s.to_ascii_lowercase().as_str() {
            "smoke" => Ok(Suite::Smoke),
            "full" => Ok(Suite::Full),
            "campaign" => Ok(Suite::Campaign),
            other => Err(Error::Config(format!(
                "unknown bench suite '{other}' (expected smoke | full | campaign)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Suite::Smoke => "smoke",
            Suite::Full => "full",
            Suite::Campaign => "campaign",
        }
    }
}

/// One scenario×fault×controller×c_max cell of the grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CaseSpec {
    /// Table-2 dataset alias (`Breast-RNA-seq` | `HiFi-WGS` |
    /// `Amplicon-Digester`).
    pub dataset: &'static str,
    /// Fault overlay (`None` = benign network).
    pub profile: FaultProfile,
    /// Concurrency controller under test.
    pub optimizer: OptimizerKind,
    /// Worker-pool capacity.
    pub c_max: usize,
    /// Per-chunk SHA-256 verification on (`--verify`): the case also
    /// measures raw hashing cost as `hash_ns_per_mb`.
    pub verify: bool,
    /// Flight recorder attached (`--trace-out`): the case runs with a
    /// live [`crate::trace::Tracer`] and reports the deterministic
    /// event count, guarding that tracing never perturbs the sim.
    pub trace: bool,
    /// Campaign mode: `dataset` names a
    /// [`crate::experiments::scenario::campaign`] preset (many-small |
    /// mixed | many-large) instead of a Table-2 alias, and the case
    /// runs with request trains + pipelining enabled.
    pub campaign: bool,
}

/// Short controller tag used in case ids ("gd" | "bayes" | "fixed").
fn optimizer_tag(kind: OptimizerKind) -> &'static str {
    match kind {
        OptimizerKind::GradientDescent => "gd",
        OptimizerKind::Bayesian => "bayes",
        OptimizerKind::Fixed => "fixed",
    }
}

impl CaseSpec {
    /// Stable identifier used as the baseline-diff key. Verify and
    /// trace cases carry a `+verify` / `+trace` suffix so they never
    /// collide with (or shadow) the plain cell of the same grid
    /// coordinates.
    pub fn id(&self) -> String {
        format!(
            "{}{}/{}/{}/c{}{}{}",
            if self.campaign { "campaign/" } else { "" },
            self.dataset,
            self.profile.name(),
            optimizer_tag(self.optimizer),
            self.c_max,
            if self.verify { "+verify" } else { "" },
            if self.trace { "+trace" } else { "" }
        )
    }
}

/// Expand a suite into its ordered case list.
pub fn suite_cases(suite: Suite) -> Vec<CaseSpec> {
    let mut cases = Vec::new();
    match suite {
        Suite::Smoke => {
            for profile in [FaultProfile::None, FaultProfile::SlowMirror] {
                for c_max in [16, 256] {
                    cases.push(CaseSpec {
                        dataset: "Amplicon-Digester",
                        profile,
                        optimizer: OptimizerKind::GradientDescent,
                        c_max,
                        verify: false,
                        trace: false,
                        campaign: false,
                    });
                }
            }
            // One high-capacity cell: the sparse slot table and the
            // per-tick reconciliation must stay flat-cost when the
            // configured ceiling jumps past the old 512-thread limit.
            cases.push(CaseSpec {
                dataset: "Amplicon-Digester",
                profile: FaultProfile::None,
                optimizer: OptimizerKind::GradientDescent,
                c_max: 1024,
                verify: false,
                trace: false,
                campaign: false,
            });
            // One benign verify cell: per-chunk SHA-256 on, measuring
            // raw hashing cost (hash_ns_per_mb) and guarding that
            // verification does not perturb the simulated outcome.
            cases.push(CaseSpec {
                dataset: "Amplicon-Digester",
                profile: FaultProfile::None,
                optimizer: OptimizerKind::GradientDescent,
                c_max: 16,
                verify: true,
                trace: false,
                campaign: false,
            });
            // One benign trace cell: the flight recorder attached,
            // guarding that tracing perturbs neither the simulated
            // outcome nor the engine hot path, and pinning the
            // deterministic event count.
            cases.push(CaseSpec {
                dataset: "Amplicon-Digester",
                profile: FaultProfile::None,
                optimizer: OptimizerKind::GradientDescent,
                c_max: 16,
                verify: false,
                trace: true,
                campaign: false,
            });
        }
        Suite::Campaign => {
            for preset in ["many-small", "mixed", "many-large"] {
                cases.push(CaseSpec {
                    dataset: preset,
                    profile: FaultProfile::None,
                    optimizer: OptimizerKind::GradientDescent,
                    c_max: 16,
                    verify: false,
                    trace: false,
                    campaign: true,
                });
            }
        }
        Suite::Full => {
            for dataset in ["Breast-RNA-seq", "HiFi-WGS", "Amplicon-Digester"] {
                for profile in [
                    FaultProfile::None,
                    FaultProfile::SlowMirror,
                    FaultProfile::Brownout,
                    FaultProfile::FlashCrowd,
                ] {
                    for optimizer in [
                        OptimizerKind::GradientDescent,
                        OptimizerKind::Bayesian,
                        OptimizerKind::Fixed,
                    ] {
                        for c_max in [16, 64, 256] {
                            cases.push(CaseSpec {
                                dataset,
                                profile,
                                optimizer,
                                c_max,
                                verify: false,
                                trace: false,
                                campaign: false,
                            });
                        }
                    }
                }
            }
        }
    }
    cases
}

/// One measured cell: the spec, the deterministic simulated outcome,
/// and the machine-dependent control-loop timing.
#[derive(Clone, Debug)]
pub struct CaseResult {
    /// Stable case id (`dataset/profile/controller/cN`).
    pub id: String,
    pub dataset: String,
    pub profile: String,
    pub optimizer: String,
    pub c_max: usize,
    // --- Deterministic per (suite, seed): ---
    pub goodput_mbps: f64,
    pub total_bytes: u64,
    pub duration_s: f64,
    pub chunk_retries: u64,
    pub connection_resets: u64,
    pub server_rejects: u64,
    pub mirror_switches: u64,
    pub probes: u64,
    pub files_completed: u64,
    /// Files completed per simulated second — the campaign suite's
    /// headline metric, deterministic like every other det field
    /// (derived from `files_completed / duration_s` on the virtual
    /// clock).
    pub files_per_sec: f64,
    pub completed: bool,
    /// Chunk requeues per simulated second (the control plane's
    /// `retry_rate` signal, averaged over the whole case).
    pub retry_rate: f64,
    /// Server rejections per simulated second (the `reject_rate`
    /// signal, averaged over the whole case).
    pub reject_rate: f64,
    /// Chunks cut below full size by adaptive chunk sizing (0 with the
    /// default fault-blind config the grid runs under).
    pub chunks_scaled: u64,
    /// Flight-recorder events recorded (trace cases only; 0 otherwise).
    /// Deterministic per (suite, seed) like every other det field —
    /// replay drift shows up here before it shows up in goodput.
    pub trace_events: u64,
    // --- Timing (varies run to run): ---
    pub wall_s: f64,
    pub ticks: u64,
    pub ns_per_tick: f64,
    pub ticks_per_sec: f64,
    pub allocs_per_tick: f64,
    pub slots_scanned_per_tick: f64,
    pub max_probe_releases_per_tick: u64,
    /// Positional disk writes per completed chunk (after sink
    /// coalescing; 0 on the simulated grid, which has no disk path).
    pub write_syscalls_per_chunk: f64,
    /// High-water mark of bytes queued in the write-behind sink.
    pub sink_queue_peak: u64,
    /// Nanoseconds connections spent parked on sink backpressure.
    pub reactor_stall_ns: f64,
    /// Measured SHA-256 cost per MiB of synthetic payload (verify
    /// cases only; 0 otherwise). This is the raw per-byte price of the
    /// integrity layer, measured on this machine with the same hasher
    /// the transports feed.
    pub hash_ns_per_mb: f64,
}

/// Gradient-descent hyperparameter overrides for a sweep cell (see
/// [`sweep_grid`]). `None` in [`run_case_tuned`] keeps the scenario's
/// calibrated defaults.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GdTune {
    /// Utility penalty coefficient `k`.
    pub k: f64,
    /// Gradient-descent learning rate.
    pub lr: f64,
    /// Probing interval (s).
    pub probe_interval_s: f64,
}

/// Run one grid cell to completion (or the [`CASE_HORIZON_S`] cap).
///
/// Runtime-free by construction (pure-Rust mirror controllers), so the
/// harness produces identical simulated fields on any machine,
/// including bare checkouts without compiled XLA artifacts.
pub fn run_case(spec: &CaseSpec, seed: u64, reconcile: ReconcileMode) -> Result<CaseResult> {
    run_case_tuned(spec, seed, reconcile, None)
}

/// [`run_case`] with optional GD hyperparameter overrides — the
/// hostile-profile sweep's measurement path.
pub fn run_case_tuned(
    spec: &CaseSpec,
    seed: u64,
    reconcile: ReconcileMode,
    tune: Option<&GdTune>,
) -> Result<CaseResult> {
    let mut sc = if spec.campaign {
        scenario::campaign(spec.dataset, seed)?
    } else {
        scenario::colab_dataset(spec.dataset, seed)?
    };
    sc.download.optimizer.kind = spec.optimizer;
    sc.download.optimizer.c_max = spec.c_max;
    if spec.optimizer == OptimizerKind::Fixed {
        sc.download.optimizer.c_init = sc.download.optimizer.fixed_level;
    }
    if let Some(t) = tune {
        sc.download.optimizer.k = t.k;
        sc.download.optimizer.lr = t.lr;
        sc.download.optimizer.probe_interval_s = t.probe_interval_s;
    }
    sc.download.reconcile = reconcile;
    sc.download.integrity.verify = spec.verify;
    if spec.profile != FaultProfile::None {
        sc = sc.with_fault_profile(spec.profile, seed, CASE_HORIZON_S);
    }
    let controller = build_controller_with(&sc.download.optimizer, &sc.download.control, None)?;
    let behavior = ToolBehavior::fastbiodl(&sc.download);
    let chunk_bytes = sc.download.chunk_bytes;
    let tracer = spec
        .trace
        .then(|| std::sync::Arc::new(Tracer::with_capacity(DEFAULT_CAPACITY)));
    let mut session = SimSession::new(SimSessionParams {
        download: sc.download,
        behavior,
        netsim: sc.netsim,
        records: sc.records,
        controller,
        runtime: None,
        seed,
    })
    .with_checkpoint_after(CASE_HORIZON_S);
    if let Some(tr) = &tracer {
        session = session.with_tracer(tr.clone());
    }

    let allocs_before = alloc::thread_allocations();
    let t0 = Instant::now();
    let (report, stats) = session.run_with_stats()?;
    let wall_s = t0.elapsed().as_secs_f64();
    let allocs = alloc::thread_allocations().saturating_sub(allocs_before);

    // Verify cases also price the hasher itself: SHA-256 over 1 MiB of
    // deterministic synthetic payload, best of a few reps. The virtual
    // clock makes simulated goodput blind to real hashing time, so
    // this measured figure is the honest per-byte cost the real
    // transports pay on the writer/reactor threads.
    let hash_ns_per_mb = if spec.verify {
        let mut buf = vec![0u8; 1 << 20];
        crate::transport::http_server::fill_payload(seed, 0, &mut buf);
        let mut best = f64::INFINITY;
        let mut fold = 0u8;
        for _ in 0..4 {
            let t = Instant::now();
            let digest = crate::util::sha256::sha256(&buf);
            best = best.min(t.elapsed().as_nanos() as f64);
            fold ^= digest[0];
        }
        std::hint::black_box(fold);
        best
    } else {
        0.0
    };

    let ticks = stats.ticks.max(1);
    Ok(CaseResult {
        id: spec.id(),
        dataset: spec.dataset.to_string(),
        profile: spec.profile.name().to_string(),
        optimizer: optimizer_tag(spec.optimizer).to_string(),
        c_max: spec.c_max,
        goodput_mbps: report.mean_throughput_mbps,
        total_bytes: report.total_bytes,
        duration_s: report.duration_s,
        chunk_retries: report.chunk_retries as u64,
        connection_resets: report.connection_resets as u64,
        server_rejects: report.server_rejects as u64,
        mirror_switches: report.mirror_switches as u64,
        probes: report.probes as u64,
        files_completed: report.files_completed as u64,
        files_per_sec: report.files_completed as f64 / report.duration_s.max(f64::EPSILON),
        completed: report.completed,
        retry_rate: report.chunk_retries as f64 / report.duration_s.max(f64::EPSILON),
        reject_rate: report.server_rejects as f64 / report.duration_s.max(f64::EPSILON),
        chunks_scaled: stats.chunks_scaled,
        trace_events: tracer.as_ref().map_or(0, |t| t.events_recorded()),
        wall_s,
        ticks: stats.ticks,
        ns_per_tick: wall_s * 1e9 / ticks as f64,
        ticks_per_sec: ticks as f64 / wall_s.max(1e-12),
        allocs_per_tick: allocs as f64 / ticks as f64,
        slots_scanned_per_tick: stats.slots_scanned as f64 / ticks as f64,
        max_probe_releases_per_tick: stats.max_probe_releases_per_tick as u64,
        // Chunk count is approximated from delivered bytes; exact on
        // completed benign runs, a safe lower bound otherwise.
        write_syscalls_per_chunk: stats.write_syscalls as f64
            / (report.total_bytes / chunk_bytes).max(1) as f64,
        sink_queue_peak: stats.sink_queue_peak,
        reactor_stall_ns: stats.reactor_stall_ns as f64,
        hash_ns_per_mb,
    })
}

/// A complete benchmark report (header + per-case records).
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub suite: String,
    pub seed: u64,
    pub reconcile: String,
    pub cases: Vec<CaseResult>,
}

impl BenchReport {
    /// Serialize to the schema-versioned JSON document (deterministic
    /// key order; the `timing` sub-objects are the only fields expected
    /// to differ between two runs of the same suite+seed).
    pub fn to_json(&self) -> Json {
        let cpus = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(0);
        let machine = obj(vec![
            ("os", Json::Str(std::env::consts::OS.into())),
            ("arch", Json::Str(std::env::consts::ARCH.into())),
            ("cpus", Json::Num(cpus as f64)),
        ]);
        let header = obj(vec![
            ("schema", Json::Str(SCHEMA_VERSION.into())),
            ("suite", Json::Str(self.suite.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("reconcile", Json::Str(self.reconcile.clone())),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            ("machine", machine),
        ]);
        let cases: Vec<Json> = self
            .cases
            .iter()
            .map(|c| {
                obj(vec![
                    ("id", Json::Str(c.id.clone())),
                    ("dataset", Json::Str(c.dataset.clone())),
                    ("profile", Json::Str(c.profile.clone())),
                    ("optimizer", Json::Str(c.optimizer.clone())),
                    ("c_max", Json::Num(c.c_max as f64)),
                    (
                        "det",
                        obj(vec![
                            ("goodput_mbps", Json::Num(c.goodput_mbps)),
                            ("total_bytes", Json::Num(c.total_bytes as f64)),
                            ("duration_s", Json::Num(c.duration_s)),
                            ("chunk_retries", Json::Num(c.chunk_retries as f64)),
                            ("connection_resets", Json::Num(c.connection_resets as f64)),
                            ("server_rejects", Json::Num(c.server_rejects as f64)),
                            ("mirror_switches", Json::Num(c.mirror_switches as f64)),
                            ("probes", Json::Num(c.probes as f64)),
                            ("files_completed", Json::Num(c.files_completed as f64)),
                            ("files_per_sec", Json::Num(c.files_per_sec)),
                            ("completed", Json::Bool(c.completed)),
                            ("retry_rate", Json::Num(c.retry_rate)),
                            ("reject_rate", Json::Num(c.reject_rate)),
                            ("chunks_scaled", Json::Num(c.chunks_scaled as f64)),
                            ("trace_events", Json::Num(c.trace_events as f64)),
                        ]),
                    ),
                    (
                        "timing",
                        obj(vec![
                            ("wall_s", Json::Num(c.wall_s)),
                            ("ticks", Json::Num(c.ticks as f64)),
                            ("ns_per_tick", Json::Num(c.ns_per_tick)),
                            ("ticks_per_sec", Json::Num(c.ticks_per_sec)),
                            ("allocs_per_tick", Json::Num(c.allocs_per_tick)),
                            ("slots_scanned_per_tick", Json::Num(c.slots_scanned_per_tick)),
                            (
                                "max_probe_releases_per_tick",
                                Json::Num(c.max_probe_releases_per_tick as f64),
                            ),
                            (
                                "write_syscalls_per_chunk",
                                Json::Num(c.write_syscalls_per_chunk),
                            ),
                            ("sink_queue_peak", Json::Num(c.sink_queue_peak as f64)),
                            ("reactor_stall_ns", Json::Num(c.reactor_stall_ns)),
                            ("hash_ns_per_mb", Json::Num(c.hash_ns_per_mb)),
                        ]),
                    ),
                ])
            })
            .collect();
        obj(vec![("header", header), ("cases", Json::Arr(cases))])
    }

    /// Parse a report previously written by [`BenchReport::to_json`].
    pub fn from_json(text: &str) -> Result<BenchReport> {
        let j = Json::parse(text)?;
        let header = j.require("header")?;
        let schema = header
            .require("schema")?
            .as_str()
            .ok_or_else(|| Error::Config("bench header.schema must be a string".into()))?;
        if schema != SCHEMA_VERSION {
            return Err(Error::Config(format!(
                "bench schema mismatch: file is '{schema}', this binary reads '{SCHEMA_VERSION}'"
            )));
        }
        let req_str = |v: &Json, k: &str| -> Result<String> {
            Ok(v.require(k)?
                .as_str()
                .ok_or_else(|| Error::Config(format!("bench field '{k}' must be a string")))?
                .to_string())
        };
        let req_f64 = |v: &Json, k: &str| -> Result<f64> {
            v.require(k)?
                .as_f64()
                .ok_or_else(|| Error::Config(format!("bench field '{k}' must be a number")))
        };
        let req_u64 = |v: &Json, k: &str| -> Result<u64> {
            v.require(k)?
                .as_u64()
                .ok_or_else(|| Error::Config(format!("bench field '{k}' must be an integer")))
        };
        let mut cases = Vec::new();
        for c in j
            .require("cases")?
            .as_arr()
            .ok_or_else(|| Error::Config("bench 'cases' must be an array".into()))?
        {
            let det = c.require("det")?;
            let timing = c.require("timing")?;
            cases.push(CaseResult {
                id: req_str(c, "id")?,
                dataset: req_str(c, "dataset")?,
                profile: req_str(c, "profile")?,
                optimizer: req_str(c, "optimizer")?,
                c_max: req_u64(c, "c_max")? as usize,
                goodput_mbps: req_f64(det, "goodput_mbps")?,
                total_bytes: req_u64(det, "total_bytes")?,
                duration_s: req_f64(det, "duration_s")?,
                chunk_retries: req_u64(det, "chunk_retries")?,
                connection_resets: req_u64(det, "connection_resets")?,
                server_rejects: req_u64(det, "server_rejects")?,
                mirror_switches: req_u64(det, "mirror_switches")?,
                probes: req_u64(det, "probes")?,
                files_completed: req_u64(det, "files_completed")?,
                files_per_sec: req_f64(det, "files_per_sec")?,
                completed: matches!(*det.require("completed")?, Json::Bool(true)),
                retry_rate: req_f64(det, "retry_rate")?,
                reject_rate: req_f64(det, "reject_rate")?,
                chunks_scaled: req_u64(det, "chunks_scaled")?,
                trace_events: req_u64(det, "trace_events")?,
                wall_s: req_f64(timing, "wall_s")?,
                ticks: req_u64(timing, "ticks")?,
                ns_per_tick: req_f64(timing, "ns_per_tick")?,
                ticks_per_sec: req_f64(timing, "ticks_per_sec")?,
                allocs_per_tick: req_f64(timing, "allocs_per_tick")?,
                slots_scanned_per_tick: req_f64(timing, "slots_scanned_per_tick")?,
                max_probe_releases_per_tick: req_u64(timing, "max_probe_releases_per_tick")?,
                write_syscalls_per_chunk: req_f64(timing, "write_syscalls_per_chunk")?,
                sink_queue_peak: req_u64(timing, "sink_queue_peak")?,
                reactor_stall_ns: req_f64(timing, "reactor_stall_ns")?,
                hash_ns_per_mb: req_f64(timing, "hash_ns_per_mb")?,
            });
        }
        Ok(BenchReport {
            suite: req_str(header, "suite")?,
            seed: req_u64(header, "seed")?,
            reconcile: req_str(header, "reconcile")?,
            cases,
        })
    }
}

/// What kind of baseline deviation [`diff`] found.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegressionKind {
    /// ns/tick grew beyond the tolerance.
    Timing,
    /// A simulated field that must be bit-stable for the same
    /// suite+seed changed — the engine's behaviour drifted.
    Determinism,
    /// A baseline case is missing from the current report.
    Missing,
}

impl RegressionKind {
    /// Short label for CLI output.
    pub fn name(&self) -> &'static str {
        match self {
            RegressionKind::Timing => "timing",
            RegressionKind::Determinism => "determinism",
            RegressionKind::Missing => "missing",
        }
    }
}

/// One flagged deviation from the baseline.
#[derive(Clone, Debug)]
pub struct Regression {
    pub case_id: String,
    pub kind: RegressionKind,
    pub detail: String,
}

/// Compare `current` against `baseline`; returns every regression
/// found (empty = clean). Timing regressions use `tolerance` as the
/// allowed relative ns/tick increase; determinism checks only apply
/// when the two reports ran the same suite and seed.
pub fn diff(current: &BenchReport, baseline: &BenchReport, tolerance: f64) -> Vec<Regression> {
    let mut out = Vec::new();
    let comparable = current.suite == baseline.suite && current.seed == baseline.seed;
    for base in &baseline.cases {
        let Some(cur) = current.cases.iter().find(|c| c.id == base.id) else {
            out.push(Regression {
                case_id: base.id.clone(),
                kind: RegressionKind::Missing,
                detail: "case present in baseline but not in current report".into(),
            });
            continue;
        };
        if comparable {
            let det_drift = cur.total_bytes != base.total_bytes
                || cur.chunk_retries != base.chunk_retries
                || cur.connection_resets != base.connection_resets
                || cur.server_rejects != base.server_rejects
                || cur.mirror_switches != base.mirror_switches
                || cur.probes != base.probes
                || cur.files_completed != base.files_completed
                || (cur.files_per_sec - base.files_per_sec).abs()
                    > base.files_per_sec.abs() * 1e-9
                || cur.completed != base.completed
                || cur.chunks_scaled != base.chunks_scaled
                || cur.trace_events != base.trace_events
                || (cur.goodput_mbps - base.goodput_mbps).abs() > base.goodput_mbps.abs() * 1e-9;
            if det_drift {
                out.push(Regression {
                    case_id: base.id.clone(),
                    kind: RegressionKind::Determinism,
                    detail: format!(
                        "simulated fields drifted (goodput {:.3} -> {:.3} Mbps, bytes {} -> {}, \
                         retries {} -> {})",
                        base.goodput_mbps,
                        cur.goodput_mbps,
                        base.total_bytes,
                        cur.total_bytes,
                        base.chunk_retries,
                        cur.chunk_retries
                    ),
                });
            }
        }
        if base.ns_per_tick > 0.0 && cur.ns_per_tick > base.ns_per_tick * (1.0 + tolerance) {
            out.push(Regression {
                case_id: base.id.clone(),
                kind: RegressionKind::Timing,
                detail: format!(
                    "ns/tick {:.0} -> {:.0} (+{:.0}%, tolerance {:.0}%)",
                    base.ns_per_tick,
                    cur.ns_per_tick,
                    (cur.ns_per_tick / base.ns_per_tick - 1.0) * 100.0,
                    tolerance * 100.0
                ),
            });
        }
    }
    out
}

// --- Hostile-profile hyperparameter sweep (`fastbiodl bench --sweep`).

/// Hostile profiles covered by the GD hyperparameter sweep (the
/// ROADMAP tuning item: the GD defaults were tuned on benign
/// networks).
pub const SWEEP_PROFILES: [FaultProfile; 3] = [
    FaultProfile::SlowMirror,
    FaultProfile::Brownout,
    FaultProfile::FlashCrowd,
];

/// Utility-penalty grid of the sweep (Table 1's candidates).
pub const SWEEP_KS: [f64; 3] = [1.01, 1.02, 1.05];

/// Learning-rate grid of the sweep (half / default / double).
pub const SWEEP_LRS: [f64; 3] = [1.5, 3.0, 6.0];

/// Probe-interval grid of the sweep (s): the paper's 5 s evaluation
/// cadence vs a twice-as-reactive controller.
pub const SWEEP_PROBE_INTERVALS: [f64; 2] = [2.5, 5.0];

/// Dataset preset and pool size every sweep cell runs on — the
/// cold-staging-heavy Amplicon workload, small enough that the whole
/// 54-cell grid finishes in seconds of wall time.
pub const SWEEP_DATASET: &str = "Amplicon-Digester";
/// Worker-pool capacity of every sweep cell.
pub const SWEEP_C_MAX: usize = 16;

/// One measured sweep cell: the hostile profile, the GD
/// hyperparameters, and the resulting (deterministic) case record.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub profile: FaultProfile,
    pub tune: GdTune,
    pub result: CaseResult,
}

impl SweepCell {
    /// Stable identifier (`profile/kX/lrY/pZ`).
    pub fn id(&self) -> String {
        format!(
            "{}/k{}/lr{}/p{}",
            self.profile.name(),
            self.tune.k,
            self.tune.lr,
            self.tune.probe_interval_s
        )
    }
}

/// The deterministic sweep grid: every hostile profile crossed with
/// every `(k, lr, probe_interval)` combination, in a stable order.
pub fn sweep_grid() -> Vec<(FaultProfile, GdTune)> {
    let mut out = Vec::new();
    for profile in SWEEP_PROFILES {
        for k in SWEEP_KS {
            for lr in SWEEP_LRS {
                for probe_interval_s in SWEEP_PROBE_INTERVALS {
                    out.push((
                        profile,
                        GdTune {
                            k,
                            lr,
                            probe_interval_s,
                        },
                    ));
                }
            }
        }
    }
    out
}

/// Run one sweep cell: gradient descent with the given hyperparameters
/// on the [`SWEEP_DATASET`] preset under the given hostile profile.
/// Deterministic per `(profile, tune, seed)` like every bench case.
pub fn run_sweep_cell(
    profile: FaultProfile,
    tune: GdTune,
    seed: u64,
    reconcile: ReconcileMode,
) -> Result<SweepCell> {
    let spec = CaseSpec {
        dataset: SWEEP_DATASET,
        profile,
        optimizer: OptimizerKind::GradientDescent,
        c_max: SWEEP_C_MAX,
        verify: false,
        trace: false,
        campaign: false,
    };
    let result = run_case_tuned(&spec, seed, reconcile, Some(&tune))?;
    Ok(SweepCell {
        profile,
        tune,
        result,
    })
}

/// Best cell per sweep profile: completion first (a capped cell never
/// beats a completed one), then goodput; ties break toward the
/// earliest grid cell, so the report is deterministic.
pub fn best_per_profile(cells: &[SweepCell]) -> Vec<&SweepCell> {
    SWEEP_PROFILES
        .iter()
        .filter_map(|&profile| {
            cells
                .iter()
                .filter(|c| c.profile == profile)
                .fold(None::<&SweepCell>, |best, c| match best {
                    None => Some(c),
                    Some(b) => {
                        let better = (c.result.completed, c.result.goodput_mbps)
                            > (b.result.completed, b.result.goodput_mbps);
                        if better {
                            Some(c)
                        } else {
                            Some(b)
                        }
                    }
                })
        })
        .collect()
}

/// Serialize a sweep run (all cells + the winners) to JSON.
pub fn sweep_to_json(cells: &[SweepCell], seed: u64, reconcile: ReconcileMode) -> Json {
    let header = obj(vec![
        ("schema", Json::Str("fastbiodl-sweep-v1".into())),
        ("dataset", Json::Str(SWEEP_DATASET.into())),
        ("c_max", Json::Num(SWEEP_C_MAX as f64)),
        ("seed", Json::Num(seed as f64)),
        ("reconcile", Json::Str(reconcile.name().into())),
    ]);
    let cell_json = |c: &SweepCell| {
        obj(vec![
            ("id", Json::Str(c.id())),
            ("profile", Json::Str(c.profile.name().into())),
            ("k", Json::Num(c.tune.k)),
            ("lr", Json::Num(c.tune.lr)),
            ("probe_interval_s", Json::Num(c.tune.probe_interval_s)),
            ("goodput_mbps", Json::Num(c.result.goodput_mbps)),
            ("duration_s", Json::Num(c.result.duration_s)),
            ("chunk_retries", Json::Num(c.result.chunk_retries as f64)),
            ("server_rejects", Json::Num(c.result.server_rejects as f64)),
            ("completed", Json::Bool(c.result.completed)),
        ])
    };
    obj(vec![
        ("header", header),
        ("cells", Json::Arr(cells.iter().map(cell_json).collect())),
        (
            "best",
            Json::Arr(best_per_profile(cells).into_iter().map(cell_json).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_report() -> BenchReport {
        BenchReport {
            suite: "smoke".into(),
            seed: 1,
            reconcile: "batched".into(),
            cases: vec![CaseResult {
                id: "Amplicon-Digester/none/gd/c16".into(),
                dataset: "Amplicon-Digester".into(),
                profile: "none".into(),
                optimizer: "gd".into(),
                c_max: 16,
                goodput_mbps: 812.5,
                total_bytes: 1_910_000_000,
                duration_s: 19.0,
                chunk_retries: 0,
                connection_resets: 0,
                server_rejects: 0,
                mirror_switches: 2,
                probes: 4,
                files_completed: 43,
                files_per_sec: 43.0 / 19.0,
                completed: true,
                retry_rate: 0.0,
                reject_rate: 0.0,
                chunks_scaled: 0,
                trace_events: 0,
                wall_s: 0.02,
                ticks: 400,
                ns_per_tick: 50_000.0,
                ticks_per_sec: 20_000.0,
                allocs_per_tick: 0.4,
                slots_scanned_per_tick: 9.0,
                max_probe_releases_per_tick: 1,
                write_syscalls_per_chunk: 1.25,
                sink_queue_peak: 524_288,
                reactor_stall_ns: 1_500.0,
                hash_ns_per_mb: 0.0,
            }],
        }
    }

    #[test]
    fn report_json_roundtrips() {
        let r = tiny_report();
        let text = r.to_json().to_string_compact();
        let back = BenchReport::from_json(&text).unwrap();
        assert_eq!(back.suite, r.suite);
        assert_eq!(back.seed, r.seed);
        assert_eq!(back.cases.len(), 1);
        let (a, b) = (&back.cases[0], &r.cases[0]);
        assert_eq!(a.id, b.id);
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.ticks, b.ticks);
        assert!((a.goodput_mbps - b.goodput_mbps).abs() < 1e-9);
        assert!((a.files_per_sec - b.files_per_sec).abs() < 1e-9);
        assert!((a.write_syscalls_per_chunk - b.write_syscalls_per_chunk).abs() < 1e-9);
        assert_eq!(a.sink_queue_peak, b.sink_queue_peak);
        assert!((a.reactor_stall_ns - b.reactor_stall_ns).abs() < 1e-9);
        assert!(a.completed);
    }

    #[test]
    fn from_json_rejects_other_schemas() {
        let r = tiny_report();
        let text = r
            .to_json()
            .to_string_compact()
            .replace(SCHEMA_VERSION, "fastbiodl-bench-v1");
        assert!(BenchReport::from_json(&text).is_err());
    }

    #[test]
    fn baseline_diff_flags_a_synthetic_timing_regression() {
        let baseline = tiny_report();
        let mut current = tiny_report();
        current.cases[0].ns_per_tick *= 2.0;
        let regs = diff(&current, &baseline, DEFAULT_TIMING_TOLERANCE);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert_eq!(regs[0].kind, RegressionKind::Timing);
        assert_eq!(regs[0].case_id, baseline.cases[0].id);
        // Inside the tolerance nothing fires.
        let mut ok = tiny_report();
        ok.cases[0].ns_per_tick *= 1.0 + DEFAULT_TIMING_TOLERANCE * 0.5;
        assert!(diff(&ok, &baseline, DEFAULT_TIMING_TOLERANCE).is_empty());
    }

    #[test]
    fn baseline_diff_flags_determinism_drift_and_missing_cases() {
        let baseline = tiny_report();
        let mut drift = tiny_report();
        drift.cases[0].total_bytes += 1;
        let regs = diff(&drift, &baseline, 10.0);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].kind, RegressionKind::Determinism);
        // A different seed must NOT be compared field-for-field.
        let mut other_seed = drift.clone();
        other_seed.seed = 2;
        assert!(diff(&other_seed, &baseline, 10.0).is_empty());
        // Vanished case.
        let mut empty = tiny_report();
        empty.cases.clear();
        let regs = diff(&empty, &baseline, 10.0);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].kind, RegressionKind::Missing);
    }

    #[test]
    fn suites_have_the_advertised_shapes() {
        let smoke = suite_cases(Suite::Smoke);
        assert_eq!(
            smoke.len(),
            7,
            "4 grid cells + the c_max=1024 cell + the verify cell + the trace cell"
        );
        assert_eq!(smoke[4].c_max, 1024);
        assert!(smoke[5].verify, "smoke cell 5 exercises integrity hashing");
        assert!(smoke[5].id().ends_with("+verify"));
        assert!(smoke[6].trace, "last smoke cell runs with the flight recorder");
        assert!(smoke[6].id().ends_with("+trace"));
        assert!(smoke[..5].iter().all(|s| !s.verify));
        assert!(smoke[..6].iter().all(|s| !s.trace));
        let full = suite_cases(Suite::Full);
        assert_eq!(full.len(), 108, "full grid is 3 x 4 x 3 x 3");
        assert!(full.len() >= 30);
        let camp = suite_cases(Suite::Campaign);
        assert_eq!(camp.len(), 3, "many-small, mixed, many-large");
        assert!(camp.iter().all(|c| c.campaign));
        assert_eq!(camp[0].id(), "campaign/many-small/none/gd/c16");
        assert!(smoke.iter().chain(&full).all(|c| !c.campaign));
        // Ids are unique (they key the baseline diff).
        let mut ids: Vec<String> = full.iter().map(CaseSpec::id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), full.len());
        assert!(Suite::parse("full").is_ok());
        assert!(Suite::parse("everything").is_err());
    }

    #[test]
    fn sweep_grid_shape_and_winner_selection() {
        let grid = sweep_grid();
        assert_eq!(grid.len(), 3 * 3 * 3 * 2, "3 profiles x 3 k x 3 lr x 2 probe");
        // Cells are unique.
        let mut ids: Vec<String> = grid
            .iter()
            .map(|(p, t)| format!("{}/{}/{}/{}", p.name(), t.k, t.lr, t.probe_interval_s))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), grid.len());

        // Winner selection: completed beats capped, then goodput wins.
        let cell = |profile, goodput, completed| SweepCell {
            profile,
            tune: GdTune {
                k: 1.02,
                lr: 3.0,
                probe_interval_s: 5.0,
            },
            result: CaseResult {
                goodput_mbps: goodput,
                completed,
                ..tiny_report().cases[0].clone()
            },
        };
        let cells = vec![
            cell(FaultProfile::SlowMirror, 900.0, false),
            cell(FaultProfile::SlowMirror, 500.0, true),
            cell(FaultProfile::SlowMirror, 700.0, true),
            cell(FaultProfile::Brownout, 100.0, true),
        ];
        let best = best_per_profile(&cells);
        assert_eq!(best.len(), 2, "only profiles with cells appear");
        assert_eq!(best[0].result.goodput_mbps, 700.0, "completed + fastest wins");
        assert_eq!(best[1].result.goodput_mbps, 100.0);
        // The JSON document carries header, every cell, and the winners.
        let j = sweep_to_json(&cells, 1, ReconcileMode::Batched).to_string_compact();
        assert!(j.contains("fastbiodl-sweep-v1"));
        assert!(j.contains("\"best\""));
    }

    #[test]
    fn sweep_cell_is_deterministic_and_tune_changes_the_run() {
        let tune = GdTune {
            k: 1.05,
            lr: 1.5,
            probe_interval_s: 2.5,
        };
        let a = run_sweep_cell(FaultProfile::SlowMirror, tune, 5, ReconcileMode::Batched).unwrap();
        let b = run_sweep_cell(FaultProfile::SlowMirror, tune, 5, ReconcileMode::Batched).unwrap();
        assert_eq!(a.result.goodput_mbps.to_bits(), b.result.goodput_mbps.to_bits());
        assert_eq!(a.result.total_bytes, b.result.total_bytes);
        assert_eq!(a.result.probes, b.result.probes);
        // A different probe interval must change the probe count — the
        // sweep is not vacuous.
        let slow = GdTune {
            probe_interval_s: 5.0,
            ..tune
        };
        let c = run_sweep_cell(FaultProfile::SlowMirror, slow, 5, ReconcileMode::Batched).unwrap();
        assert_ne!(a.result.probes, c.result.probes, "probe cadence ignored");
    }

    #[test]
    fn smoke_case_is_deterministic_across_two_runs() {
        let spec = CaseSpec {
            dataset: "Amplicon-Digester",
            profile: FaultProfile::SlowMirror,
            optimizer: OptimizerKind::GradientDescent,
            c_max: 16,
            verify: false,
            trace: false,
            campaign: false,
        };
        let a = run_case(&spec, 7, ReconcileMode::Batched).unwrap();
        let b = run_case(&spec, 7, ReconcileMode::Batched).unwrap();
        assert_eq!(a.goodput_mbps.to_bits(), b.goodput_mbps.to_bits());
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        assert_eq!(
            (a.chunk_retries, a.connection_resets, a.server_rejects),
            (b.chunk_retries, b.connection_resets, b.server_rejects)
        );
        assert_eq!(a.mirror_switches, b.mirror_switches);
        assert_eq!(a.probes, b.probes);
        assert_eq!(a.ticks, b.ticks, "tick count is part of the replay");
        assert!(a.total_bytes > 0, "case moved no bytes");
    }

    #[test]
    fn campaign_case_is_deterministic_and_reports_files_per_sec() {
        let spec = suite_cases(Suite::Campaign)[0]; // many-small
        let a = run_case(&spec, 7, ReconcileMode::Batched).unwrap();
        let b = run_case(&spec, 7, ReconcileMode::Batched).unwrap();
        assert_eq!(a.goodput_mbps.to_bits(), b.goodput_mbps.to_bits());
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.files_per_sec.to_bits(), b.files_per_sec.to_bits());
        assert!(a.completed, "many-small must finish inside the horizon");
        assert_eq!(a.files_completed, 96);
        assert!(a.files_per_sec > 0.0);
    }

    #[test]
    fn verify_case_matches_benign_outcome_and_reports_hash_cost() {
        let plain = CaseSpec {
            dataset: "Amplicon-Digester",
            profile: FaultProfile::None,
            optimizer: OptimizerKind::GradientDescent,
            c_max: 16,
            verify: false,
            trace: false,
            campaign: false,
        };
        let verified = CaseSpec {
            verify: true,
            ..plain
        };
        assert!(verified.id().ends_with("+verify"));
        let a = run_case(&plain, 7, ReconcileMode::Batched).unwrap();
        let b = run_case(&verified, 7, ReconcileMode::Batched).unwrap();
        // Hashing must not perturb the simulated run: same bytes, same
        // schedule, goodput within the 5% noise budget the paper claims.
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.ticks, b.ticks, "verify changed the replay");
        assert!(b.completed);
        let delta = (a.goodput_mbps - b.goodput_mbps).abs() / a.goodput_mbps;
        assert!(delta < 0.05, "verify cost {delta:.3} of goodput");
        // The real hashing cost is surfaced out-of-band.
        assert!(b.hash_ns_per_mb > 0.0, "verify case must measure hashing");
        assert_eq!(a.hash_ns_per_mb, 0.0);
    }

    #[test]
    fn trace_case_matches_plain_outcome_and_counts_events() {
        let plain = CaseSpec {
            dataset: "Amplicon-Digester",
            profile: FaultProfile::None,
            optimizer: OptimizerKind::GradientDescent,
            c_max: 16,
            verify: false,
            trace: false,
            campaign: false,
        };
        let traced = CaseSpec {
            trace: true,
            campaign: false,
            ..plain
        };
        assert!(traced.id().ends_with("+trace"));
        let a = run_case(&plain, 7, ReconcileMode::Batched).unwrap();
        let b = run_case(&traced, 7, ReconcileMode::Batched).unwrap();
        // The flight recorder must not perturb the simulated run.
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!(a.ticks, b.ticks, "tracing changed the replay");
        assert_eq!(a.goodput_mbps.to_bits(), b.goodput_mbps.to_bits());
        assert_eq!(a.trace_events, 0, "plain case records nothing");
        assert!(b.trace_events > 0, "trace case recorded no events");
        // And the event count itself is part of the deterministic replay.
        let c = run_case(&traced, 7, ReconcileMode::Batched).unwrap();
        assert_eq!(b.trace_events, c.trace_events);
    }
}
