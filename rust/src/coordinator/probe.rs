//! Probe-window collection: raw monitor samples → XLA-aggregated stats.
//!
//! During each probing interval the monitor deposits instantaneous
//! throughput samples here; at the probe boundary the optimizer loop
//! aggregates them through the `throughput_window` artifact (count,
//! mean, std, min, max, exponentially-weighted mean) and resets the
//! window. The fixed artifact shape (`SAMPLES = 256`) comfortably holds
//! a 5 s probe at the default 4 Hz monitor rate; if a window ever
//! overflows, the oldest samples are dropped (the EW-mean weights make
//! this nearly lossless).

use crate::runtime::XlaRuntime;
use crate::Result;

/// Aggregated probe-window statistics (output of the
/// `throughput_window` artifact).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowStats {
    pub count: f64,
    pub mean_mbps: f64,
    pub std_mbps: f64,
    pub min_mbps: f64,
    pub max_mbps: f64,
    /// Exponentially-weighted mean (recent samples count more).
    pub ew_mean_mbps: f64,
}

/// Sample buffer for one probe window.
#[derive(Debug)]
pub struct ProbeWindow {
    samples: Vec<f32>,
    capacity: usize,
    /// Per-sample EW decay (newest weight 1, previous ×decay, …).
    decay: f32,
    dropped: usize,
}

impl ProbeWindow {
    /// `capacity` must equal the artifact's SAMPLES constant (256);
    /// `decay` in (0, 1] sets the exponential recency weighting.
    pub fn new(capacity: usize, decay: f64) -> ProbeWindow {
        assert!(capacity > 0);
        assert!((0.0..=1.0).contains(&decay) && decay > 0.0);
        ProbeWindow {
            samples: Vec::with_capacity(capacity),
            capacity,
            decay: decay as f32,
            dropped: 0,
        }
    }

    /// Deposit one instantaneous throughput sample (Mbps).
    pub fn push(&mut self, mbps: f64) {
        if self.samples.len() == self.capacity {
            self.samples.remove(0);
            self.dropped += 1;
        }
        self.samples.push(mbps as f32);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Samples dropped to overflow since the last reset (diagnostics).
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Aggregate through the XLA artifact and clear the window.
    pub fn aggregate_and_reset(&mut self, rt: &XlaRuntime) -> Result<WindowStats> {
        let n = self.samples.len();
        let mut samples = vec![0.0f32; self.capacity];
        let mut valid = vec![0.0f32; self.capacity];
        let mut weights = vec![0.0f32; self.capacity];
        samples[..n].copy_from_slice(&self.samples);
        for i in 0..n {
            valid[i] = 1.0;
            // Newest sample (index n-1) has weight 1.
            weights[i] = self.decay.powi((n - 1 - i) as i32);
        }
        let out = rt.throughput_window(&samples, &valid, &weights)?;
        self.samples.clear();
        self.dropped = 0;
        Ok(WindowStats {
            count: out[0] as f64,
            mean_mbps: out[1] as f64,
            std_mbps: out[2] as f64,
            min_mbps: out[3] as f64,
            max_mbps: out[4] as f64,
            ew_mean_mbps: out[5] as f64,
        })
    }

    /// Pure-Rust aggregate + reset: the runtime-free analogue of
    /// [`ProbeWindow::aggregate_and_reset`], used by the session engine
    /// when no XLA runtime is attached. Keeps the window's configured
    /// capacity/decay (unlike rebuilding the window from scratch).
    pub fn aggregate_mirror_and_reset(&mut self) -> WindowStats {
        let stats = self.aggregate_mirror();
        self.samples.clear();
        self.dropped = 0;
        stats
    }

    /// Pure-Rust aggregation fallback used by unit tests that run
    /// without artifacts (cross-checked against the XLA path in the
    /// integration suite).
    pub fn aggregate_mirror(&self) -> WindowStats {
        let n = self.samples.len();
        if n == 0 {
            return WindowStats::default();
        }
        let xs: Vec<f64> = self.samples.iter().map(|&x| x as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut wsum = 0.0;
        let mut wtot = 0.0;
        for (i, x) in xs.iter().enumerate() {
            let w = (self.decay as f64).powi((n - 1 - i) as i32);
            wsum += w * x;
            wtot += w;
        }
        WindowStats {
            count: n as f64,
            mean_mbps: mean,
            std_mbps: var.sqrt(),
            min_mbps: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max_mbps: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            ew_mean_mbps: wsum / wtot,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mirror_stats_correct() {
        let mut w = ProbeWindow::new(256, 0.9);
        for x in [10.0, 20.0, 30.0] {
            w.push(x);
        }
        let s = w.aggregate_mirror();
        assert_eq!(s.count, 3.0);
        assert!((s.mean_mbps - 20.0).abs() < 1e-6);
        assert_eq!(s.min_mbps, 10.0);
        assert_eq!(s.max_mbps, 30.0);
        // EW mean favors the most recent (30).
        assert!(s.ew_mean_mbps > 20.0);
    }

    #[test]
    fn overflow_drops_oldest() {
        let mut w = ProbeWindow::new(4, 1.0);
        for x in 0..6 {
            w.push(x as f64);
        }
        assert_eq!(w.len(), 4);
        assert_eq!(w.dropped(), 2);
        let s = w.aggregate_mirror();
        assert_eq!(s.min_mbps, 2.0);
        assert_eq!(s.max_mbps, 5.0);
    }

    #[test]
    fn empty_window_is_zero() {
        let w = ProbeWindow::new(16, 0.9);
        assert_eq!(w.aggregate_mirror(), WindowStats::default());
    }
}
