//! Chunk-integrity manifest: per-chunk SHA-256 + availability bitfield.
//!
//! The progress journal ([`super::resume`]) records how far each file
//! got; it says nothing about whether the bytes on disk are *correct*.
//! The manifest closes that gap: for every file it stores the chunk
//! grid (`chunk_bytes`, `total_bytes`), one SHA-256 per grid chunk
//! (learned as chunks complete — trust-on-first-use — or supplied up
//! front by a previous run), and a **big-endian availability bitfield**
//! (bit `i` of the field is `bits[i/8] & (0x80 >> (i % 8))`) marking
//! which chunks have been verified against their hash.
//!
//! Persistence mirrors the journal: one JSON document
//! (`<output_dir>/.fastbiodl-manifest`) written atomically (temp file +
//! rename) alongside `.fastbiodl-journal`, and — unlike the journal —
//! *kept* after a successful transfer, so a later delta resume can
//! harvest verified chunks from partial or even foreign output files
//! instead of trusting the journal frontier blindly.
//!
//! [`delta_scan`] is the resume-side half: it rehashes every on-disk
//! grid chunk whose expected hash is known and flips the availability
//! bits to match reality, so a corrupted tail or truncated write is
//! detected and re-scheduled rather than resumed over.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{obj, Json};
use crate::util::sha256::{from_hex, hex, Sha256};
use crate::{Error, Result};

/// Manifest file name inside the output directory.
pub const MANIFEST_FILE: &str = ".fastbiodl-manifest";

fn grid_count(total_bytes: u64, chunk_bytes: u64) -> usize {
    if total_bytes == 0 {
        0
    } else {
        ((total_bytes + chunk_bytes - 1) / chunk_bytes) as usize
    }
}

/// Per-file chunk grid: hashes + availability bits.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkManifest {
    /// File size the grid covers.
    pub total_bytes: u64,
    /// Grid chunk size (the transfer's `chunk_bytes`; the last chunk is
    /// the remainder). Verification requires grid-aligned cuts, which
    /// the config layer enforces by rejecting `verify` + adaptive chunk
    /// scaling.
    pub chunk_bytes: u64,
    /// Expected SHA-256 per grid chunk; `None` until first observed.
    hashes: Vec<Option<[u8; 32]>>,
    /// Big-endian availability bitfield: bit `i` lives at
    /// `bits[i / 8]`, mask `0x80 >> (i % 8)`.
    bits: Vec<u8>,
}

impl ChunkManifest {
    /// Empty manifest for a file: no hashes known, nothing available.
    pub fn new(total_bytes: u64, chunk_bytes: u64) -> Self {
        assert!(chunk_bytes > 0, "chunk_bytes must be positive");
        let n = grid_count(total_bytes, chunk_bytes);
        ChunkManifest {
            total_bytes,
            chunk_bytes,
            hashes: vec![None; n],
            bits: vec![0u8; (n + 7) / 8],
        }
    }

    /// Number of grid chunks.
    pub fn chunk_count(&self) -> usize {
        self.hashes.len()
    }

    /// Byte length of grid chunk `idx` (the last one is the remainder).
    pub fn chunk_len(&self, idx: usize) -> u64 {
        let offset = idx as u64 * self.chunk_bytes;
        self.chunk_bytes.min(self.total_bytes - offset)
    }

    /// Grid index of the chunk starting at `offset`.
    pub fn chunk_index(&self, offset: u64) -> usize {
        (offset / self.chunk_bytes) as usize
    }

    /// Expected hash of chunk `idx`, if known.
    pub fn expected(&self, idx: usize) -> Option<&[u8; 32]> {
        self.hashes.get(idx).and_then(|h| h.as_ref())
    }

    /// Record the expected hash of chunk `idx`.
    pub fn record_hash(&mut self, idx: usize, digest: [u8; 32]) {
        self.hashes[idx] = Some(digest);
    }

    /// Flip availability bit `idx`.
    pub fn set_available(&mut self, idx: usize, avail: bool) {
        assert!(idx < self.chunk_count(), "chunk index out of range");
        let mask = 0x80u8 >> (idx % 8);
        if avail {
            self.bits[idx / 8] |= mask;
        } else {
            self.bits[idx / 8] &= !mask;
        }
    }

    /// Is chunk `idx` verified-available?
    pub fn is_available(&self, idx: usize) -> bool {
        idx < self.chunk_count() && self.bits[idx / 8] & (0x80u8 >> (idx % 8)) != 0
    }

    /// How many chunks are verified-available.
    pub fn available_count(&self) -> usize {
        (0..self.chunk_count()).filter(|&i| self.is_available(i)).count()
    }

    /// Raw big-endian bitfield (for serialization and tests).
    pub fn bitfield(&self) -> &[u8] {
        &self.bits
    }

    /// Verified byte ranges, as merged `(offset, len)` spans of
    /// consecutive available chunks — the shape the scheduler's
    /// verified-span skip list consumes.
    pub fn verified_spans(&self) -> Vec<(u64, u64)> {
        let mut spans = Vec::new();
        let n = self.chunk_count();
        let mut i = 0;
        while i < n {
            if self.is_available(i) {
                let start = i as u64 * self.chunk_bytes;
                let mut len = self.chunk_len(i);
                i += 1;
                while i < n && self.is_available(i) {
                    len += self.chunk_len(i);
                    i += 1;
                }
                spans.push((start, len));
            } else {
                i += 1;
            }
        }
        spans
    }

    /// Bytes covered by verified chunks.
    pub fn verified_bytes(&self) -> u64 {
        (0..self.chunk_count())
            .filter(|&i| self.is_available(i))
            .map(|i| self.chunk_len(i))
            .sum()
    }

    fn to_json(&self, accession: &str) -> Json {
        obj(vec![
            ("accession", Json::Str(accession.to_string())),
            ("bytes", Json::Num(self.total_bytes as f64)),
            ("chunk_bytes", Json::Num(self.chunk_bytes as f64)),
            (
                // Hex strings, not numbers: JSON numbers are f64 and
                // cannot carry 256 bits. Empty string = hash unknown.
                "hashes",
                Json::Arr(
                    self.hashes
                        .iter()
                        .map(|h| Json::Str(h.as_ref().map(hex).unwrap_or_default()))
                        .collect(),
                ),
            ),
            (
                "bits",
                Json::Str(self.bits.iter().map(|b| format!("{b:02x}")).collect()),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<(String, ChunkManifest)> {
        let bad = |what: &str| Error::Session(format!("manifest: bad {what}"));
        let accession = j
            .require("accession")?
            .as_str()
            .ok_or_else(|| bad("accession"))?
            .to_string();
        let total_bytes = j.require("bytes")?.as_u64().ok_or_else(|| bad("bytes"))?;
        let chunk_bytes = j
            .require("chunk_bytes")?
            .as_u64()
            .ok_or_else(|| bad("chunk_bytes"))?;
        if chunk_bytes == 0 {
            return Err(bad("chunk_bytes"));
        }
        let mut m = ChunkManifest::new(total_bytes, chunk_bytes);
        let hashes = j.require("hashes")?.as_arr().ok_or_else(|| bad("hashes"))?;
        if hashes.len() != m.chunk_count() {
            return Err(bad("hash count"));
        }
        for (i, h) in hashes.iter().enumerate() {
            let s = h.as_str().ok_or_else(|| bad("hash entry"))?;
            if !s.is_empty() {
                m.hashes[i] = Some(from_hex(s).ok_or_else(|| bad("hash hex"))?);
            }
        }
        let bits_hex = j.require("bits")?.as_str().ok_or_else(|| bad("bits"))?;
        if bits_hex.len() != m.bits.len() * 2 {
            return Err(bad("bitfield length"));
        }
        for (i, pair) in bits_hex.as_bytes().chunks(2).enumerate() {
            let hi = (pair[0] as char).to_digit(16).ok_or_else(|| bad("bitfield hex"))?;
            let lo = (pair[1] as char).to_digit(16).ok_or_else(|| bad("bitfield hex"))?;
            m.bits[i] = ((hi << 4) | lo) as u8;
        }
        // A set bit without its hash would mean "available but
        // unverifiable" — reject rather than trust.
        for i in 0..m.chunk_count() {
            if m.is_available(i) && m.expected(i).is_none() {
                return Err(bad("available chunk without hash"));
            }
        }
        Ok((accession, m))
    }
}

/// All per-file manifests of a transfer, keyed by accession, persisted
/// as one JSON document next to the progress journal.
///
/// Persistence is incremental: each entry's compact serialization is
/// cached, and every mutable access (`get_mut`, `entry`, `insert`)
/// invalidates only that entry's cache, so [`ManifestSet::save`]
/// re-serializes the changed entries and splices the rest from cache.
/// On a many-file campaign a probe/fault checkpoint touching one file
/// costs one entry serialization, not O(files) — the document itself
/// is still written whole (atomic temp + rename).
#[derive(Clone, Debug, Default)]
pub struct ManifestSet {
    files: BTreeMap<String, ChunkManifest>,
    /// Compact per-entry JSON, present iff the entry is clean (in sync
    /// with `files`). Never holds keys absent from `files`.
    cache: BTreeMap<String, String>,
    /// Cumulative entry serializations performed by `save` — the
    /// observable the batching satellite's upper-bound test pins.
    serialized: u64,
}

impl PartialEq for ManifestSet {
    fn eq(&self, other: &Self) -> bool {
        // The serialization cache is a performance detail, not state.
        self.files == other.files
    }
}

impl ManifestSet {
    pub fn new() -> Self {
        ManifestSet::default()
    }

    pub fn len(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    pub fn get(&self, accession: &str) -> Option<&ChunkManifest> {
        self.files.get(accession)
    }

    pub fn get_mut(&mut self, accession: &str) -> Option<&mut ChunkManifest> {
        // Handing out &mut means the entry may change: drop its cached
        // serialization (conservative — a no-op mutation re-serializes
        // once, which is still O(1) entries, not O(files)).
        self.cache.remove(accession);
        self.files.get_mut(accession)
    }

    /// Entry serializations performed by [`ManifestSet::save`] so far
    /// (cumulative). With the dirty-entry cache this grows by the
    /// number of *changed* entries per save, not by `len()`.
    pub fn entries_serialized(&self) -> u64 {
        self.serialized
    }

    /// Manifest for `accession`, creating (or replacing, if the file
    /// size or chunk grid changed — stale hashes must not survive a
    /// reshape) an entry with the given grid.
    pub fn entry(
        &mut self,
        accession: &str,
        total_bytes: u64,
        chunk_bytes: u64,
    ) -> &mut ChunkManifest {
        let stale = self
            .files
            .get(accession)
            .map(|m| m.total_bytes != total_bytes || m.chunk_bytes != chunk_bytes)
            .unwrap_or(true);
        if stale {
            self.files
                .insert(accession.to_string(), ChunkManifest::new(total_bytes, chunk_bytes));
        }
        self.cache.remove(accession);
        self.files.get_mut(accession).unwrap()
    }

    pub fn insert(&mut self, accession: &str, manifest: ChunkManifest) {
        self.cache.remove(accession);
        self.files.insert(accession.to_string(), manifest);
    }

    /// Manifest path for an output directory.
    pub fn path_for(dir: &Path) -> PathBuf {
        dir.join(MANIFEST_FILE)
    }

    /// Atomic write (temp + rename), same idiom as the journal.
    /// Incremental: only entries whose cached serialization was
    /// invalidated since the last save are re-serialized; the document
    /// is assembled by splicing per-entry buffers (byte-identical to
    /// serializing the whole set through the JSON printer).
    pub fn save(&mut self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        // Key order matches the JSON printer's BTreeMap order
        // ("files" < "version"), keeping the document byte-identical
        // to a whole-set serialization.
        let mut body = String::with_capacity(self.files.len() * 64 + 32);
        body.push_str("{\"files\":[");
        for (i, (acc, m)) in self.files.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            if !self.cache.contains_key(acc) {
                self.cache
                    .insert(acc.clone(), m.to_json(acc).to_string_compact());
                self.serialized += 1;
            }
            body.push_str(&self.cache[acc]);
        }
        body.push_str("],\"version\":1}");
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, body)?;
        std::fs::rename(&tmp, Self::path_for(dir))?;
        Ok(())
    }

    /// Load a manifest set if one exists.
    pub fn load(dir: &Path) -> Result<Option<ManifestSet>> {
        let path = Self::path_for(dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        let j = Json::parse(&text)
            .map_err(|e| Error::Session(format!("corrupt manifest {}: {e}", path.display())))?;
        let mut set = ManifestSet::new();
        for f in j
            .require("files")?
            .as_arr()
            .ok_or_else(|| Error::Session("manifest: 'files' not an array".into()))?
        {
            let (acc, m) = ChunkManifest::from_json(f)?;
            set.files.insert(acc, m);
        }
        Ok(Some(set))
    }

    /// Remove the manifest (only used by tests; real sessions keep it
    /// after completion so later runs can delta-resume).
    pub fn remove(dir: &Path) -> Result<()> {
        match std::fs::remove_file(Self::path_for(dir)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }
}

/// Rehash every on-disk grid chunk of `path` whose expected hash is
/// known and set the availability bits to match reality: a chunk is
/// available iff it is fully on disk *and* its bytes hash to the
/// expected digest. Chunks without a recorded hash, beyond the disk
/// length, or with mismatching bytes are cleared — they will be
/// (re-)scheduled. Returns the number of chunks verified.
///
/// This is the delta-resume scan: it runs at cold start, so its cost is
/// one sequential read of the partial file, not anything on the
/// transfer hot path.
pub fn delta_scan(path: &Path, m: &mut ChunkManifest) -> Result<usize> {
    use std::io::{Read, Seek, SeekFrom};
    let mut file = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            for i in 0..m.chunk_count() {
                m.set_available(i, false);
            }
            return Ok(0);
        }
        Err(e) => return Err(e.into()),
    };
    let disk_len = file.metadata()?.len();
    let mut buf = vec![0u8; 256 * 1024];
    let mut verified = 0usize;
    for idx in 0..m.chunk_count() {
        let offset = idx as u64 * m.chunk_bytes;
        let len = m.chunk_len(idx);
        if m.expected(idx).is_none() || offset + len > disk_len {
            m.set_available(idx, false);
            continue;
        }
        file.seek(SeekFrom::Start(offset))?;
        let mut h = Sha256::new();
        let mut left = len;
        while left > 0 {
            let take = (buf.len() as u64).min(left) as usize;
            file.read_exact(&mut buf[..take])?;
            h.update(&buf[..take]);
            left -= take as u64;
        }
        let digest = h.finalize();
        let ok = m.expected(idx) == Some(&digest);
        m.set_available(idx, ok);
        if ok {
            verified += 1;
        }
    }
    Ok(verified)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sha256::sha256;

    #[test]
    fn bitfield_is_big_endian() {
        let mut m = ChunkManifest::new(10 * 100, 100); // 10 chunks
        assert_eq!(m.bitfield().len(), 2);
        m.set_available(0, true);
        assert_eq!(m.bitfield()[0], 0x80);
        m.set_available(7, true);
        assert_eq!(m.bitfield()[0], 0x81);
        m.set_available(8, true);
        assert_eq!(m.bitfield()[1], 0x80);
        m.set_available(0, false);
        assert_eq!(m.bitfield()[0], 0x01);
        assert!(!m.is_available(0) && m.is_available(7) && m.is_available(8));
        assert_eq!(m.available_count(), 2);
    }

    #[test]
    fn chunk_grid_covers_remainder() {
        let m = ChunkManifest::new(250, 100);
        assert_eq!(m.chunk_count(), 3);
        assert_eq!(m.chunk_len(0), 100);
        assert_eq!(m.chunk_len(2), 50);
        assert_eq!(m.chunk_index(0), 0);
        assert_eq!(m.chunk_index(200), 2);
        assert_eq!(ChunkManifest::new(0, 100).chunk_count(), 0);
    }

    #[test]
    fn verified_spans_merge_consecutive_chunks() {
        let mut m = ChunkManifest::new(550, 100); // chunks 0..=5, last is 50 B
        for i in [0usize, 1, 3, 5] {
            m.record_hash(i, [i as u8; 32]);
            m.set_available(i, true);
        }
        assert_eq!(m.verified_spans(), vec![(0, 200), (300, 100), (500, 50)]);
        assert_eq!(m.verified_bytes(), 350);
    }

    #[test]
    fn set_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join(format!("fbdl-manifest-{}", std::process::id()));
        let mut set = ManifestSet::new();
        let m = set.entry("SRR0000001", 250, 100);
        m.record_hash(0, sha256(b"chunk0"));
        m.set_available(0, true);
        m.record_hash(2, sha256(b"chunk2"));
        set.entry("SRR0000002", 90, 100); // single partial chunk, nothing known
        set.save(&dir).unwrap();
        let loaded = ManifestSet::load(&dir).unwrap().unwrap();
        assert_eq!(loaded, set);
        ManifestSet::remove(&dir).unwrap();
        assert!(ManifestSet::load(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_reserializes_only_dirty_entries() {
        let dir = std::env::temp_dir().join(format!("fbdl-manifest-dirty-{}", std::process::id()));
        let mut set = ManifestSet::new();
        for i in 0..20 {
            set.entry(&format!("SRR{i:07}"), 250, 100);
        }
        set.save(&dir).unwrap();
        assert_eq!(set.entries_serialized(), 20, "cold save serializes everything");
        set.save(&dir).unwrap();
        assert_eq!(set.entries_serialized(), 20, "clean save serializes nothing");
        // Touch one file (the per-probe checkpoint pattern): exactly
        // one entry re-serializes, regardless of set size.
        let m = set.get_mut("SRR0000003").unwrap();
        m.record_hash(0, sha256(b"x"));
        m.set_available(0, true);
        set.save(&dir).unwrap();
        assert_eq!(set.entries_serialized(), 21, "one dirty entry, one serialization");
        // The spliced incremental document round-trips like a full one.
        let loaded = ManifestSet::load(&dir).unwrap().unwrap();
        assert_eq!(loaded, set);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn entry_replaces_on_grid_reshape() {
        let mut set = ManifestSet::new();
        let m = set.entry("SRR0000001", 250, 100);
        m.record_hash(0, sha256(b"x"));
        m.set_available(0, true);
        // Same grid: entry preserves state.
        assert_eq!(set.entry("SRR0000001", 250, 100).available_count(), 1);
        // Changed chunk size: stale hashes are discarded.
        assert_eq!(set.entry("SRR0000001", 250, 50).available_count(), 0);
        assert_eq!(set.get("SRR0000001").unwrap().chunk_count(), 5);
    }

    #[test]
    fn corrupt_documents_are_rejected() {
        let dir = std::env::temp_dir().join(format!("fbdl-manifest-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(ManifestSet::path_for(&dir), "not json").unwrap();
        assert!(ManifestSet::load(&dir).is_err());
        // A set availability bit without its hash must not load.
        std::fs::write(
            ManifestSet::path_for(&dir),
            r#"{"files":[{"accession":"A","bytes":100,"chunk_bytes":100,"hashes":[""],"bits":"80"}],"version":1}"#,
        )
        .unwrap();
        assert!(ManifestSet::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn delta_scan_verifies_good_chunks_and_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("fbdl-deltascan-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("SRRX");
        let payload: Vec<u8> = (0..250u32).map(|i| (i * 31 + 7) as u8).collect();
        std::fs::write(&path, &payload).unwrap();
        let mut m = ChunkManifest::new(250, 100);
        m.record_hash(0, sha256(&payload[0..100]));
        m.record_hash(1, sha256(&payload[100..200]));
        m.record_hash(2, sha256(&payload[200..250]));
        assert_eq!(delta_scan(&path, &mut m).unwrap(), 3);
        assert_eq!(m.verified_spans(), vec![(0, 250)]);

        // Corrupt one byte in chunk 1: only that chunk drops out.
        let mut corrupt = payload.clone();
        corrupt[150] ^= 0xFF;
        std::fs::write(&path, &corrupt).unwrap();
        assert_eq!(delta_scan(&path, &mut m).unwrap(), 2);
        assert_eq!(m.verified_spans(), vec![(0, 100), (200, 50)]);

        // Truncated tail: chunk 2 is incomplete, chunk 1 still corrupt.
        std::fs::write(&path, &payload[..220]).unwrap();
        assert_eq!(delta_scan(&path, &mut m).unwrap(), 1);
        assert_eq!(m.verified_spans(), vec![(0, 100)]);

        // Missing file: nothing survives.
        std::fs::remove_file(&path).unwrap();
        assert_eq!(delta_scan(&path, &mut m).unwrap(), 0);
        assert!(m.verified_spans().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
