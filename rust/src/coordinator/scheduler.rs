//! Chunk scheduling: files → range requests → workers.
//!
//! Three modes; the first two mirror the two tool families in the
//! paper:
//!
//! * [`SchedulerMode::Chunked`] — FastBioDL: every file is cut into
//!   fixed-size range requests; at most `max_open_files` distinct files
//!   are in flight, and chunks of the open files are served in file
//!   order. This keeps sink-side writes near-sequential (few open
//!   files) while still letting many connections share one big file.
//!   The first chunk of each file is *cold* (pays the server's staging
//!   latency); subsequent chunks of the same file are warm.
//! * [`SchedulerMode::WholeFile`] — prefetch/pysradb: one request per
//!   file, as many files open as there are workers.
//! * [`SchedulerMode::Campaign`] — many-file campaigns: files at or
//!   below `coalesce_bytes` become whole-file *train* chunks
//!   ([`Chunk::train`]) that the engine may pipeline back to back on
//!   one keep-alive connection ([`ChunkScheduler::next_train_chunk`]),
//!   amortizing request setup and cold staging; larger files keep the
//!   chunked striping semantics. One scheduler instance is the single
//!   global chunk pool for the whole manifest, so controllers and the
//!   resume journal see one campaign, not N sessions.
//!
//! Chunked mode additionally supports **striping-aware chunk sizing**
//! ([`ChunkScheduler::next_chunk_scaled`]): the session engine passes a
//! per-issue scale in `(0, 1]` — derived from the controller's
//! [`crate::control::ControlAction::chunk_scale`] and the issuing
//! slot's mirror degradation — and the scheduler cuts the next chunk at
//! `scale × chunk_bytes` (never below [`MIN_CHUNK_BYTES`]). A probe
//! chunk on a deeply slowed mirror then occupies its slot for seconds
//! instead of minutes. Scale `1.0` (the default path, and everything
//! with `adaptive_chunks` off) is byte-identical to the unscaled
//! scheduler; requeued chunks always keep their original byte range,
//! so the tiling invariants below are unaffected.
//!
//! The scheduler is transport-agnostic and single-threaded by design:
//! the unified session engine owns it on the control thread for both
//! simulated and real transfers (workers receive chunk assignments over
//! channels, so no lock ever touches the byte path). It is equally
//! mirror-agnostic — chunks are file ranges; which mirror serves a
//! range is decided at fetch time by the slot's binding, which the
//! engine's [`crate::session::mirrors::MirrorBoard`] spreads across
//! healthy mirrors in proportion to their scores (weighted striping)
//! or concentrates on the best one (failover baseline). That split is
//! what lets a requeued chunk retry on a different mirror than the one
//! that failed it, and what stripes one file's chunks across several
//! mirrors concurrently.
//!
//! Invariants (property-tested in `rust/tests/prop_coordinator.rs`):
//! chunks of one file never overlap and exactly tile `[0, size)`; a
//! chunk is outstanding at most once; `bytes_done` never exceeds the
//! total; completion implies every chunk of every file was delivered.

use crate::accession::RunRecord;

/// Absolute floor of a scaled chunk (bytes): below this, per-request
/// overhead (headers, first-byte latency) dominates the payload and
/// shrinking further only multiplies requests. Matches the
/// `chunk_bytes` validation floor in [`crate::config::DownloadConfig`].
pub const MIN_CHUNK_BYTES: u64 = 64 * 1024;

/// Chunk length for a given scale: `scale × chunk_bytes`, clamped to
/// `[MIN_CHUNK_BYTES, chunk_bytes]` (a `chunk_bytes` already below the
/// floor is returned unchanged). `scale >= 1` short-circuits to
/// `chunk_bytes` so the unscaled path performs no float arithmetic.
fn effective_chunk_bytes(chunk_bytes: u64, scale: f64) -> u64 {
    if scale >= 1.0 {
        return chunk_bytes;
    }
    debug_assert!(scale.is_finite() && scale > 0.0, "bad chunk scale {scale}");
    ((chunk_bytes as f64 * scale) as u64).clamp(MIN_CHUNK_BYTES.min(chunk_bytes), chunk_bytes)
}

/// One range request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Chunk {
    /// Index into the scheduler's file list.
    pub file: usize,
    /// Chunk ordinal within the file.
    pub index: usize,
    /// Byte offset of the range.
    pub offset: u64,
    /// Range length (bytes); > 0.
    pub len: u64,
    /// First chunk of its file (pays cold first-byte latency).
    pub cold: bool,
    /// Train-eligible whole-file request (Campaign mode, small files):
    /// the engine may pipeline further train chunks behind this one on
    /// the same connection. Always `false` in the other modes, so
    /// depth-1 behavior is byte-identical.
    pub train: bool,
}

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerMode {
    /// Fixed-size range requests, bounded distinct open files.
    Chunked {
        chunk_bytes: u64,
        max_open_files: usize,
    },
    /// One request per file (baseline tools).
    WholeFile,
    /// Many-file campaign: files at or below `coalesce_bytes` become
    /// whole-file train chunks (pipelinable back to back); larger files
    /// keep chunked striping under the same `chunk_bytes` /
    /// `max_open_files` bounds. Train files do not count against
    /// `max_open_files` — coalescing many small files is the point.
    Campaign {
        chunk_bytes: u64,
        max_open_files: usize,
        coalesce_bytes: u64,
    },
}

#[derive(Clone, Debug)]
struct FileState {
    bytes: u64,
    /// Next not-yet-handed-out offset.
    next_offset: u64,
    /// Chunks handed out but not yet completed.
    outstanding: usize,
    /// Bytes confirmed delivered.
    bytes_done: u64,
    /// Chunks handed out so far (ordinal source).
    chunks_issued: usize,
    opened: bool,
    completed: bool,
    /// Campaign mode: file is at or below the coalesce threshold and is
    /// handed out as one train-eligible whole-file chunk. Always
    /// `false` in the other modes.
    small: bool,
    /// Completed byte spans, kept merged and sorted (resume support:
    /// the contiguous-from-zero frontier is what the progress journal
    /// persists).
    spans: Vec<(u64, u64)>,
    /// Verified-on-disk `(start, end)` ranges (delta resume): already
    /// counted into `bytes_done`, never cut into chunks. Sorted,
    /// disjoint. Empty unless integrity verification seeded reuse.
    skip: Vec<(u64, u64)>,
}

impl FileState {
    /// Insert a completed span, merging adjacent/overlapping entries.
    fn add_span(&mut self, offset: u64, len: u64) {
        let (mut start, mut end) = (offset, offset + len);
        let mut merged = Vec::with_capacity(self.spans.len() + 1);
        for &(s, e) in &self.spans {
            if e < start || s > end {
                merged.push((s, e));
            } else {
                start = start.min(s);
                end = end.max(e);
            }
        }
        merged.push((start, end));
        merged.sort_unstable();
        self.spans = merged;
    }

    /// Contiguous completed prefix starting at byte 0.
    fn frontier(&self) -> u64 {
        match self.spans.first() {
            Some(&(0, end)) => end,
            _ => 0,
        }
    }

    /// Advance `next_offset` past any verified span covering it, so the
    /// `next_offset < bytes` hand-out predicates stay exact with gaps
    /// in the middle of a file. `skip` is sorted, so one pass chases
    /// chains of spans.
    fn skip_verified(&mut self) {
        for &(s, e) in &self.skip {
            if s <= self.next_offset && self.next_offset < e {
                self.next_offset = e;
            }
        }
    }
}

/// The scheduler.
#[derive(Debug)]
pub struct ChunkScheduler {
    files: Vec<FileState>,
    mode: SchedulerMode,
    /// Indices of files currently open (chunked mode bookkeeping).
    open: Vec<usize>,
    /// Requeued chunks (failures / worker shutdowns) served first.
    requeued: Vec<Chunk>,
    /// All files below this index are opened or completed. Files only
    /// ever transition unopened→opened and open→completed, so the
    /// cursor is monotone — it turns the "next file to open" lookup
    /// from an O(files) rescan per idle worker per tick into amortized
    /// O(1) (43-file workloads at c_max = 256 hit this hard; see the
    /// `bench` subsystem). In Campaign mode this cursor serves the
    /// large (chunked) files only.
    first_unopened: usize,
    /// Campaign mode's second monotone cursor, over the small (train)
    /// files; unused in the other modes.
    first_unopened_small: usize,
    total_bytes: u64,
    bytes_done: u64,
    /// Chunks cut below their full size because of a scale < 1 (tail
    /// chunks clipped by the file end do not count). Surfaced through
    /// [`crate::session::EngineStats`] and the bench harness.
    chunks_scaled: usize,
}

impl ChunkScheduler {
    /// Build from resolved records.
    pub fn new(records: &[RunRecord], mode: SchedulerMode) -> ChunkScheduler {
        Self::new_with_progress(records, mode, None)
    }

    /// Build with prior progress: `done_prefix[i]` bytes of file `i`
    /// are already on disk (a resume journal's contiguous frontiers —
    /// see [`crate::coordinator::resume`]). Those bytes are never
    /// re-requested.
    pub fn new_with_progress(
        records: &[RunRecord],
        mode: SchedulerMode,
        done_prefix: Option<&[u64]>,
    ) -> ChunkScheduler {
        match mode {
            SchedulerMode::Chunked {
                chunk_bytes,
                max_open_files,
            }
            | SchedulerMode::Campaign {
                chunk_bytes,
                max_open_files,
                ..
            } => {
                assert!(chunk_bytes > 0, "chunk_bytes must be > 0");
                assert!(max_open_files > 0, "max_open_files must be > 0");
            }
            SchedulerMode::WholeFile => {}
        }
        if let Some(p) = done_prefix {
            assert_eq!(p.len(), records.len(), "done_prefix arity mismatch");
        }
        let coalesce = match mode {
            SchedulerMode::Campaign { coalesce_bytes, .. } => coalesce_bytes,
            _ => 0,
        };
        let mut bytes_done_total = 0u64;
        let files: Vec<FileState> = records
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let prefix = done_prefix
                    .map(|p| p[i].min(r.bytes))
                    .unwrap_or(0);
                bytes_done_total += prefix;
                FileState {
                    bytes: r.bytes,
                    next_offset: prefix,
                    outstanding: 0,
                    bytes_done: prefix,
                    chunks_issued: 0,
                    opened: false,
                    completed: prefix >= r.bytes,
                    small: r.bytes <= coalesce,
                    spans: if prefix > 0 {
                        vec![(0, prefix)]
                    } else {
                        Vec::new()
                    },
                    skip: Vec::new(),
                }
            })
            .collect();
        let total_bytes = records.iter().map(|r| r.bytes).sum();
        ChunkScheduler {
            files,
            mode,
            open: Vec::new(),
            requeued: Vec::new(),
            first_unopened: 0,
            first_unopened_small: 0,
            total_bytes,
            bytes_done: bytes_done_total,
            chunks_scaled: 0,
        }
    }

    /// Mark verified-on-disk byte ranges of file `file` (`(offset,
    /// len)` chunk-grid spans from the integrity manifest's delta-resume
    /// scan): they count as delivered, are never cut into chunks, and
    /// complete the file outright when they cover it. Must be called
    /// before any chunk of the file is handed out. Whole-file mode
    /// cannot skip interior ranges, so there only full-file coverage
    /// takes effect; partial spans are ignored.
    pub fn set_verified_spans(&mut self, file: usize, spans: &[(u64, u64)]) {
        let f = &mut self.files[file];
        assert_eq!(f.chunks_issued, 0, "verified spans must be set before scheduling");
        assert_eq!(f.outstanding, 0, "verified spans with chunks in flight");
        let prefix = f.next_offset; // resume-journal done prefix, if any
        let mut skip: Vec<(u64, u64)> = Vec::with_capacity(spans.len());
        for &(off, len) in spans {
            assert!(len > 0 && off + len <= f.bytes, "verified span out of range");
            // Bytes under the done prefix are already accounted.
            let (s, e) = (off.max(prefix), (off + len).max(prefix));
            if s < e {
                skip.push((s, e));
            }
        }
        skip.sort_unstable();
        for w in skip.windows(2) {
            assert!(w[0].1 <= w[1].0, "verified spans overlap");
        }
        // Whole-file requests (WholeFile mode, and Campaign's small
        // train files) cannot skip interior ranges: only full coverage
        // takes effect.
        let whole_file_only = matches!(self.mode, SchedulerMode::WholeFile)
            || (matches!(self.mode, SchedulerMode::Campaign { .. }) && f.small);
        if whole_file_only {
            let covers_all = skip.first() == Some(&(prefix, f.bytes)) && skip.len() == 1;
            if !covers_all {
                return;
            }
        }
        let mut added = 0u64;
        for &(s, e) in &skip {
            f.bytes_done += e - s;
            f.add_span(s, e - s);
            added += e - s;
        }
        f.skip = skip;
        f.skip_verified();
        if f.bytes_done >= f.bytes {
            f.completed = true;
        }
        self.bytes_done += added;
    }

    /// Index of the first file that is neither opened nor completed,
    /// advancing the monotone cursor past settled files. In Campaign
    /// mode this is the *large-file* cursor (small files are skipped —
    /// they have their own cursor in [`ChunkScheduler::next_unopened_small`]).
    fn next_unopened(&mut self) -> Option<usize> {
        while let Some(f) = self.files.get(self.first_unopened) {
            if !f.opened && !f.completed && !f.small {
                return Some(self.first_unopened);
            }
            self.first_unopened += 1;
        }
        None
    }

    /// Campaign mode: first small (train) file neither opened nor
    /// completed, via its own monotone cursor.
    fn next_unopened_small(&mut self) -> Option<usize> {
        while let Some(f) = self.files.get(self.first_unopened_small) {
            if !f.opened && !f.completed && f.small {
                return Some(self.first_unopened_small);
            }
            self.first_unopened_small += 1;
        }
        None
    }

    /// Contiguous completed prefix of each file (what the resume
    /// journal persists; restart re-requests only beyond these).
    pub fn frontiers(&self) -> Vec<u64> {
        self.files.iter().map(FileState::frontier).collect()
    }

    /// Pull the next chunk for a worker, or `None` if nothing is
    /// currently available (either all work is in flight or done).
    pub fn next_chunk(&mut self) -> Option<Chunk> {
        self.next_chunk_scaled(1.0)
    }

    /// [`ChunkScheduler::next_chunk`] with a chunk scale in `(0, 1]`:
    /// a freshly cut chunked-mode chunk is at most
    /// `scale × chunk_bytes` long (floored at [`MIN_CHUNK_BYTES`]).
    /// Requeued chunks are re-served with their original range, and
    /// whole-file mode ignores the scale. `scale = 1.0` is
    /// byte-identical to [`ChunkScheduler::next_chunk`].
    pub fn next_chunk_scaled(&mut self, scale: f64) -> Option<Chunk> {
        if let Some(c) = self.requeued.pop() {
            self.files[c.file].outstanding += 1;
            return Some(c);
        }
        match self.mode {
            SchedulerMode::WholeFile => {
                let idx = self.next_unopened()?;
                Some(self.issue_whole_file(idx, false))
            }
            SchedulerMode::Chunked {
                chunk_bytes,
                max_open_files,
            } => self.next_chunked(chunk_bytes, max_open_files, scale),
            SchedulerMode::Campaign {
                chunk_bytes,
                max_open_files,
                ..
            } => {
                // Large (chunked) work first, then small train files.
                if let Some(c) = self.next_chunked(chunk_bytes, max_open_files, scale) {
                    return Some(c);
                }
                let idx = self.next_unopened_small()?;
                Some(self.issue_whole_file(idx, true))
            }
        }
    }

    /// Campaign mode: pull the next *train-eligible* chunk — a requeued
    /// train chunk, else the next unopened small file as a whole-file
    /// request. The engine uses this to extend a request train behind a
    /// train head already in flight on the same connection; `None` in
    /// the other modes (nothing is ever train-eligible there).
    pub fn next_train_chunk(&mut self) -> Option<Chunk> {
        if !matches!(self.mode, SchedulerMode::Campaign { .. }) {
            return None;
        }
        // Requeued train chunks first (LIFO among trains, matching the
        // requeue order of next_chunk_scaled).
        if let Some(pos) = self.requeued.iter().rposition(|c| c.train) {
            let c = self.requeued.remove(pos);
            self.files[c.file].outstanding += 1;
            return Some(c);
        }
        let idx = self.next_unopened_small()?;
        Some(self.issue_whole_file(idx, true))
    }

    fn issue_whole_file(&mut self, idx: usize, train: bool) -> Chunk {
        let f = &mut self.files[idx];
        f.opened = true;
        let offset = f.next_offset; // 0, or the resume frontier
        f.next_offset = f.bytes;
        f.outstanding = 1;
        f.chunks_issued = 1;
        Chunk {
            file: idx,
            index: 0,
            offset,
            len: f.bytes - offset,
            cold: true,
            train,
        }
    }

    fn next_chunked(
        &mut self,
        chunk_bytes: u64,
        max_open_files: usize,
        scale: f64,
    ) -> Option<Chunk> {
        // Prefer an already-open file with bytes left to hand out.
        let pick = self
            .open
            .iter()
            .copied()
            .find(|&i| self.files[i].next_offset < self.files[i].bytes);
        let idx = match pick {
            Some(i) => i,
            None => {
                if self.open.len() >= max_open_files {
                    return None; // all open files fully handed out, wait
                }
                let next = self.next_unopened()?;
                self.files[next].opened = true;
                self.open.push(next);
                next
            }
        };
        let f = &mut self.files[idx];
        let offset = f.next_offset;
        // Clip the cut at the next verified span (delta resume): reused
        // bytes are never re-requested, so the chunk ends where the
        // verified range begins. Span-clipped cuts are grid-aligned by
        // construction (spans are chunk-grid multiples) and do not
        // count as "scaled".
        let mut limit = f.bytes - offset;
        if let Some(&(s, _)) = f.skip.iter().find(|&&(s, _)| s > offset) {
            limit = limit.min(s - offset);
        }
        let full = chunk_bytes.min(limit);
        let len = effective_chunk_bytes(chunk_bytes, scale).min(limit);
        debug_assert!(len > 0);
        if len < full {
            self.chunks_scaled += 1;
        }
        f.next_offset += len;
        // Jump the hand-out cursor over the verified range it landed on.
        f.skip_verified();
        let index = f.chunks_issued;
        f.chunks_issued += 1;
        f.outstanding += 1;
        Some(Chunk {
            file: idx,
            index,
            offset,
            len,
            cold: index == 0,
            train: false,
        })
    }

    /// A chunk finished delivering all its bytes.
    pub fn chunk_done(&mut self, chunk: &Chunk) {
        let f = &mut self.files[chunk.file];
        assert!(f.outstanding > 0, "chunk_done with no outstanding chunks");
        f.outstanding -= 1;
        f.bytes_done += chunk.len;
        f.add_span(chunk.offset, chunk.len);
        self.bytes_done += chunk.len;
        debug_assert!(f.bytes_done <= f.bytes, "file over-delivered");
        if f.bytes_done >= f.bytes && f.outstanding == 0 {
            f.completed = true;
            self.open.retain(|&i| i != chunk.file);
        }
    }

    /// A chunk failed (connection died); requeue it for another worker.
    pub fn chunk_failed(&mut self, chunk: Chunk) {
        let f = &mut self.files[chunk.file];
        assert!(f.outstanding > 0, "chunk_failed with no outstanding chunks");
        f.outstanding -= 1;
        self.requeued.push(chunk);
    }

    /// All bytes of all files delivered.
    pub fn all_done(&self) -> bool {
        self.files.iter().all(|f| f.completed)
    }

    /// Distinct files currently open (drives the client-profile
    /// distinct-file penalty in simulation).
    pub fn open_files(&self) -> usize {
        match self.mode {
            SchedulerMode::Chunked { .. } => self.open.len(),
            SchedulerMode::WholeFile => self
                .files
                .iter()
                .filter(|f| f.opened && !f.completed)
                .count(),
            // Large chunked files plus every small file in flight.
            SchedulerMode::Campaign { .. } => {
                self.open.len()
                    + self
                        .files
                        .iter()
                        .filter(|f| f.small && f.opened && !f.completed)
                        .count()
            }
        }
    }

    /// Whether any chunk is currently available without waiting.
    pub fn has_ready_work(&self) -> bool {
        if !self.requeued.is_empty() {
            return true;
        }
        match self.mode {
            SchedulerMode::WholeFile => self.files.iter().any(|f| !f.opened && !f.completed),
            SchedulerMode::Chunked { max_open_files, .. } => {
                let open_has_work = self
                    .open
                    .iter()
                    .any(|&i| self.files[i].next_offset < self.files[i].bytes);
                let can_open_new = self.open.len() < max_open_files
                    && self.files.iter().any(|f| !f.opened && !f.completed);
                open_has_work || can_open_new
            }
            SchedulerMode::Campaign { max_open_files, .. } => {
                let open_has_work = self
                    .open
                    .iter()
                    .any(|&i| self.files[i].next_offset < self.files[i].bytes);
                let can_open_large = self.open.len() < max_open_files
                    && self
                        .files
                        .iter()
                        .any(|f| !f.small && !f.opened && !f.completed);
                let small_waiting = self
                    .files
                    .iter()
                    .any(|f| f.small && !f.opened && !f.completed);
                open_has_work || can_open_large || small_waiting
            }
        }
    }

    /// Chunks currently handed out to workers (not yet completed,
    /// failed, or requeued). `all_done()` can only become true once
    /// this reaches zero — a worker that drops a chunk without calling
    /// [`ChunkScheduler::chunk_done`] or
    /// [`ChunkScheduler::chunk_failed`] wedges the transfer, which is
    /// why every abort path must requeue.
    pub fn outstanding_chunks(&self) -> usize {
        self.files.iter().map(|f| f.outstanding).sum()
    }

    /// Chunks waiting in the retry queue.
    pub fn requeued_chunks(&self) -> usize {
        self.requeued.len()
    }

    /// Chunks cut below their full size by a scale < 1 (adaptive chunk
    /// sizing; tail clipping does not count).
    pub fn chunks_scaled(&self) -> usize {
        self.chunks_scaled
    }

    /// Bytes delivered so far / total.
    pub fn progress(&self) -> (u64, u64) {
        (self.bytes_done, self.total_bytes)
    }

    /// Number of files fully completed.
    pub fn files_completed(&self) -> usize {
        self.files.iter().filter(|f| f.completed).count()
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn records(sizes: &[u64]) -> Vec<RunRecord> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &bytes)| {
                RunRecord::new(format!("SRR{i:07}"), "TEST", bytes, format!("sim://file{i}"))
            })
            .collect()
    }

    #[test]
    fn chunked_tiles_files_exactly() {
        let recs = records(&[100, 250, 64]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 64,
                max_open_files: 2,
            },
        );
        let mut per_file: Vec<Vec<(u64, u64)>> = vec![Vec::new(); 3];
        let mut chunks = Vec::new();
        while let Some(c) = s.next_chunk() {
            per_file[c.file].push((c.offset, c.len));
            chunks.push(c.clone());
            s.chunk_done(&c);
        }
        assert!(s.all_done());
        for (i, spans) in per_file.iter().enumerate() {
            let mut sorted = spans.clone();
            sorted.sort();
            let mut cursor = 0;
            for (off, len) in sorted {
                assert_eq!(off, cursor, "file {i} has a gap/overlap");
                cursor = off + len;
            }
            assert_eq!(cursor, recs[i].bytes, "file {i} not fully tiled");
        }
        // First chunk of each file is cold, others warm.
        for c in &chunks {
            assert_eq!(c.cold, c.index == 0);
        }
    }

    #[test]
    fn max_open_files_respected() {
        let recs = records(&[1000, 1000, 1000, 1000]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 100,
                max_open_files: 2,
            },
        );
        // Pull chunks without completing: only files 0 and 1 may open.
        let mut pulled = Vec::new();
        while let Some(c) = s.next_chunk() {
            pulled.push(c);
        }
        assert!(s.open_files() <= 2);
        let files: std::collections::BTreeSet<usize> = pulled.iter().map(|c| c.file).collect();
        assert_eq!(files.len(), 2);
        // Completing file 0 opens file 2.
        for c in pulled.iter().filter(|c| c.file == 0) {
            s.chunk_done(c);
        }
        let c = s.next_chunk().expect("new file should open");
        assert_eq!(c.file, 2);
    }

    #[test]
    fn whole_file_mode_hands_out_full_files() {
        let recs = records(&[500, 700]);
        let mut s = ChunkScheduler::new(&recs, SchedulerMode::WholeFile);
        let a = s.next_chunk().unwrap();
        let b = s.next_chunk().unwrap();
        assert_eq!((a.offset, a.len), (0, 500));
        assert_eq!((b.offset, b.len), (0, 700));
        assert!(a.cold && b.cold);
        assert!(s.next_chunk().is_none());
        s.chunk_done(&a);
        s.chunk_done(&b);
        assert!(s.all_done());
    }

    #[test]
    fn requeue_serves_failed_chunk_first() {
        let recs = records(&[300]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 100,
                max_open_files: 1,
            },
        );
        let c0 = s.next_chunk().unwrap();
        let c1 = s.next_chunk().unwrap();
        s.chunk_failed(c0.clone());
        let again = s.next_chunk().unwrap();
        assert_eq!(again, c0);
        s.chunk_done(&again);
        s.chunk_done(&c1);
        let c2 = s.next_chunk().unwrap();
        s.chunk_done(&c2);
        assert!(s.all_done());
    }

    #[test]
    fn abort_requeue_keeps_outstanding_accounting_exact() {
        // Regression for the worker-park leak: a chunk pulled but
        // aborted (worker parked/died before issuing it) must return
        // via chunk_failed, or outstanding never drains and all_done
        // can never become true.
        let recs = records(&[500]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 100,
                max_open_files: 1,
            },
        );
        let a = s.next_chunk().unwrap();
        let b = s.next_chunk().unwrap();
        assert_eq!(s.outstanding_chunks(), 2);
        // Worker holding `a` parks before issuing the request.
        s.chunk_failed(a.clone());
        assert_eq!(s.outstanding_chunks(), 1);
        assert_eq!(s.requeued_chunks(), 1);
        // The requeued chunk is re-served and the file still completes.
        s.chunk_done(&b);
        let a2 = s.next_chunk().unwrap();
        assert_eq!(a2, a);
        s.chunk_done(&a2);
        while let Some(c) = s.next_chunk() {
            s.chunk_done(&c);
        }
        assert!(s.all_done());
        assert_eq!(s.outstanding_chunks(), 0);
        assert_eq!(s.progress(), (500, 500));
    }

    #[test]
    fn scaled_chunks_shrink_floor_and_still_tile_exactly() {
        let recs = records(&[1_000_000]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 256 * 1024,
                max_open_files: 1,
            },
        );
        // Scale 0.5: a half-size chunk, counted as scaled.
        let a = s.next_chunk_scaled(0.5).unwrap();
        assert_eq!(a.len, 128 * 1024);
        assert_eq!(s.chunks_scaled(), 1);
        // Tiny scale floors at MIN_CHUNK_BYTES.
        let b = s.next_chunk_scaled(1e-6).unwrap();
        assert_eq!(b.len, MIN_CHUNK_BYTES);
        assert_eq!(b.offset, a.offset + a.len, "scaled chunks stay contiguous");
        // Scale 1.0 is the unscaled cut.
        let c = s.next_chunk_scaled(1.0).unwrap();
        assert_eq!(c.len, 256 * 1024);
        assert_eq!(s.chunks_scaled(), 2, "full-size cuts are not counted");
        // A requeued chunk keeps its original range even under scale.
        s.chunk_failed(a.clone());
        let again = s.next_chunk_scaled(0.25).unwrap();
        assert_eq!(again, a);
        // Drain with a mix of scales: the file must tile exactly.
        s.chunk_done(&again);
        s.chunk_done(&b);
        s.chunk_done(&c);
        let mut scale = 0.3;
        while let Some(ch) = s.next_chunk_scaled(scale) {
            scale = if scale >= 1.0 { 0.3 } else { scale + 0.35 };
            s.chunk_done(&ch);
        }
        assert!(s.all_done());
        assert_eq!(s.progress(), (1_000_000, 1_000_000));
        assert_eq!(s.frontiers(), vec![1_000_000]);
    }

    #[test]
    fn effective_chunk_bytes_clamps() {
        assert_eq!(effective_chunk_bytes(1 << 20, 1.0), 1 << 20);
        assert_eq!(effective_chunk_bytes(1 << 20, 2.0), 1 << 20);
        assert_eq!(effective_chunk_bytes(1 << 20, 0.5), 1 << 19);
        assert_eq!(effective_chunk_bytes(1 << 20, 1e-9), MIN_CHUNK_BYTES);
        // chunk_bytes already below the floor passes through.
        assert_eq!(effective_chunk_bytes(1024, 0.5), 1024);
    }

    #[test]
    fn verified_spans_are_never_recut() {
        // File of 600 with chunks of 100; chunks 1 and 3-4 verified on
        // disk (delta resume) — only chunks 0, 2 and 5 may be cut.
        let recs = records(&[600]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 100,
                max_open_files: 1,
            },
        );
        s.set_verified_spans(0, &[(100, 100), (300, 200)]);
        assert_eq!(s.progress(), (300, 600));
        let mut cuts = Vec::new();
        while let Some(c) = s.next_chunk() {
            cuts.push((c.offset, c.len));
            s.chunk_done(&c);
        }
        assert_eq!(cuts, vec![(0, 100), (200, 100), (500, 100)]);
        assert!(s.all_done());
        assert_eq!(s.progress(), (600, 600));
        assert_eq!(s.frontiers(), vec![600]);
        assert_eq!(s.chunks_scaled(), 0, "span clipping is not scaling");
    }

    #[test]
    fn verified_spans_clip_wide_cuts_and_complete_files() {
        // Chunk size larger than the gap before a verified span: the
        // cut must stop at the span boundary.
        let recs = records(&[1_000, 500]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 400,
                max_open_files: 2,
            },
        );
        s.set_verified_spans(0, &[(200, 400)]);
        // A fully verified file completes without ever opening.
        s.set_verified_spans(1, &[(0, 500)]);
        assert_eq!(s.files_completed(), 1);
        let a = s.next_chunk().unwrap();
        assert_eq!((a.file, a.offset, a.len), (0, 0, 200));
        let b = s.next_chunk().unwrap();
        assert_eq!((b.file, b.offset, b.len), (0, 600, 400));
        assert!(s.next_chunk().is_none());
        s.chunk_done(&a);
        s.chunk_done(&b);
        assert!(s.all_done());
    }

    #[test]
    fn verified_spans_respect_resume_prefix() {
        // A journal prefix of 150 plus verified spans overlapping it:
        // overlap bytes must not double-count.
        let recs = records(&[400]);
        let mut s = ChunkScheduler::new_with_progress(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 100,
                max_open_files: 1,
            },
            Some(&[150]),
        );
        s.set_verified_spans(0, &[(100, 100), (300, 100)]);
        // 150 prefix + 50 non-overlapping from span 1 + 100 from span 2.
        assert_eq!(s.progress(), (300, 400));
        let mut cuts = Vec::new();
        while let Some(c) = s.next_chunk() {
            cuts.push((c.offset, c.len));
            s.chunk_done(&c);
        }
        assert_eq!(cuts, vec![(200, 100)]);
        assert!(s.all_done());
    }

    #[test]
    fn whole_file_mode_only_reuses_full_files() {
        let recs = records(&[500, 500]);
        let mut s = ChunkScheduler::new(&recs, SchedulerMode::WholeFile);
        s.set_verified_spans(0, &[(0, 250)]); // partial: ignored
        s.set_verified_spans(1, &[(0, 500)]); // full: completed
        assert_eq!(s.files_completed(), 1);
        let a = s.next_chunk().unwrap();
        assert_eq!((a.file, a.offset, a.len), (0, 0, 500));
        s.chunk_done(&a);
        assert!(s.all_done());
    }

    #[test]
    fn zero_byte_files_complete_immediately() {
        let recs = records(&[0, 100]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 64,
                max_open_files: 4,
            },
        );
        assert_eq!(s.files_completed(), 1);
        while let Some(c) = s.next_chunk() {
            s.chunk_done(&c);
        }
        assert!(s.all_done());
    }

    #[test]
    fn campaign_splits_trains_from_chunked_and_tiles_exactly() {
        // Files ≤ 200 become whole-file train chunks; the 1000-byte
        // file keeps chunked striping. Everything must tile exactly.
        let recs = records(&[150, 1_000, 200, 50]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Campaign {
                chunk_bytes: 300,
                max_open_files: 2,
                coalesce_bytes: 200,
            },
        );
        let mut per_file: Vec<Vec<(u64, u64)>> = vec![Vec::new(); 4];
        let mut pulled = Vec::new();
        while let Some(c) = s.next_chunk() {
            per_file[c.file].push((c.offset, c.len));
            pulled.push(c.clone());
            s.chunk_done(&c);
        }
        assert!(s.all_done());
        for (i, spans) in per_file.iter().enumerate() {
            let mut sorted = spans.clone();
            sorted.sort();
            let mut cursor = 0;
            for (off, len) in sorted {
                assert_eq!(off, cursor, "file {i} has a gap/overlap");
                cursor = off + len;
            }
            assert_eq!(cursor, recs[i].bytes, "file {i} not fully tiled");
        }
        // Small files arrive as single train chunks, large ones as
        // plain chunked cuts.
        for c in &pulled {
            let small = recs[c.file].bytes <= 200;
            assert_eq!(c.train, small, "train flag wrong on file {}", c.file);
            if small {
                assert_eq!((c.offset, c.len), (0, recs[c.file].bytes));
                assert!(c.cold);
            }
        }
        assert_eq!(s.progress(), (1_400, 1_400));
    }

    #[test]
    fn campaign_trains_do_not_count_against_open_files() {
        // One large file slot available, but all small files may open
        // concurrently as trains regardless of max_open_files.
        let recs = records(&[1_000, 1_000, 10, 10, 10]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Campaign {
                chunk_bytes: 500,
                max_open_files: 1,
                coalesce_bytes: 100,
            },
        );
        let mut pulled = Vec::new();
        while let Some(c) = s.next_chunk() {
            pulled.push(c);
        }
        // File 0 fully handed out (2 chunks), file 1 blocked behind
        // max_open_files, all three small files issued as trains.
        let large: Vec<usize> = pulled.iter().filter(|c| !c.train).map(|c| c.file).collect();
        assert_eq!(large, vec![0, 0]);
        assert_eq!(pulled.iter().filter(|c| c.train).count(), 3);
        assert!(!s.has_ready_work());
        // Completing file 0 unblocks file 1.
        for c in pulled.iter().filter(|c| c.file == 0) {
            s.chunk_done(c);
        }
        assert!(s.has_ready_work());
        let c = s.next_chunk().expect("large file 1 should open");
        assert_eq!((c.file, c.train), (1, false));
    }

    #[test]
    fn campaign_train_requeue_is_served_by_next_train_chunk() {
        let recs = records(&[40, 40, 40]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Campaign {
                chunk_bytes: 100,
                max_open_files: 1,
                coalesce_bytes: 100,
            },
        );
        let a = s.next_train_chunk().unwrap();
        let b = s.next_train_chunk().unwrap();
        assert!(a.train && b.train);
        assert_eq!((a.file, b.file), (0, 1));
        // A mid-train failure requeues; the retry is train-eligible
        // again and served before fresh small files.
        s.chunk_failed(b.clone());
        let again = s.next_train_chunk().unwrap();
        assert_eq!(again, b);
        s.chunk_done(&a);
        s.chunk_done(&again);
        let c = s.next_train_chunk().unwrap();
        assert_eq!(c.file, 2);
        s.chunk_done(&c);
        assert!(s.all_done());
        assert!(s.next_train_chunk().is_none());
        assert_eq!(s.progress(), (120, 120));
    }

    #[test]
    fn next_train_chunk_is_inert_outside_campaign_mode() {
        let recs = records(&[100]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Chunked {
                chunk_bytes: 64,
                max_open_files: 1,
            },
        );
        assert!(s.next_train_chunk().is_none());
        let c = s.next_chunk().unwrap();
        assert!(!c.train);
    }

    #[test]
    fn campaign_resume_prefix_and_verified_files_skip_trains() {
        // A small file fully verified on disk never becomes a train;
        // a partial verified span on a small file is ignored (whole-
        // file requests cannot skip interior ranges).
        let recs = records(&[80, 80, 900]);
        let mut s = ChunkScheduler::new(
            &recs,
            SchedulerMode::Campaign {
                chunk_bytes: 300,
                max_open_files: 2,
                coalesce_bytes: 100,
            },
        );
        s.set_verified_spans(0, &[(0, 80)]); // full: completed
        s.set_verified_spans(1, &[(0, 40)]); // partial: ignored
        assert_eq!(s.files_completed(), 1);
        let mut train_files = Vec::new();
        while let Some(c) = s.next_chunk() {
            if c.train {
                train_files.push(c.file);
                assert_eq!((c.offset, c.len), (0, 80));
            }
            s.chunk_done(&c);
        }
        assert_eq!(train_files, vec![1]);
        assert!(s.all_done());
    }

    #[test]
    fn progress_accounting() {
        let recs = records(&[100, 100]);
        let mut s = ChunkScheduler::new(&recs, SchedulerMode::WholeFile);
        assert_eq!(s.progress(), (0, 200));
        let a = s.next_chunk().unwrap();
        s.chunk_done(&a);
        assert_eq!(s.progress(), (100, 200));
    }
}
