//! Resume support: the progress journal.
//!
//! `prefetch`'s headline reliability feature is resuming interrupted
//! downloads (paper §2); FastBioDL matches it. The unified session
//! engine persists each file's *contiguous completed frontier* (chunks
//! can finish out of order; the frontier is the prefix that is
//! certainly on disk) on **every fault/retry event** plus once per
//! probe interval — deduplicated via `PartialEq`, so a fault storm
//! costs one write per actual frontier change. On restart,
//! [`ProgressJournal::load`] feeds the frontiers to
//! [`crate::coordinator::scheduler::ChunkScheduler::new_with_progress`],
//! which re-requests only the remainder — at most one chunk per file is
//! re-downloaded.
//!
//! Format: a single JSON document (`<output_dir>/.fastbiodl-journal`),
//! written atomically (temp file + rename) so a crash mid-write leaves
//! the previous journal intact.

use std::path::{Path, PathBuf};

use crate::accession::RunRecord;
use crate::util::json::{obj, Json};
use crate::{Error, Result};

/// Journal file name inside the output directory.
pub const JOURNAL_FILE: &str = ".fastbiodl-journal";

/// Persistent transfer progress.
#[derive(Clone, Debug, PartialEq)]
pub struct ProgressJournal {
    /// Chunk size the transfer runs with (a changed chunk size would
    /// invalidate in-flight assumptions; we only reuse frontiers, so a
    /// mismatch is allowed but recorded).
    pub chunk_bytes: u64,
    /// `(accession, total_bytes, frontier)` per file.
    pub files: Vec<(String, u64, u64)>,
}

impl ProgressJournal {
    /// Snapshot from the live transfer state.
    pub fn capture(records: &[RunRecord], frontiers: &[u64], chunk_bytes: u64) -> Self {
        assert_eq!(records.len(), frontiers.len());
        ProgressJournal {
            chunk_bytes,
            files: records
                .iter()
                .zip(frontiers)
                .map(|(r, &f)| (r.accession.clone(), r.bytes, f.min(r.bytes)))
                .collect(),
        }
    }

    /// Journal path for an output directory.
    pub fn path_for(dir: &Path) -> PathBuf {
        dir.join(JOURNAL_FILE)
    }

    /// Atomic write (temp + rename).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let doc = obj(vec![
            ("version", Json::Num(1.0)),
            ("chunk_bytes", Json::Num(self.chunk_bytes as f64)),
            (
                "files",
                Json::Arr(
                    self.files
                        .iter()
                        .map(|(acc, bytes, frontier)| {
                            obj(vec![
                                ("accession", Json::Str(acc.clone())),
                                ("bytes", Json::Num(*bytes as f64)),
                                ("frontier", Json::Num(*frontier as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let tmp = dir.join(format!("{JOURNAL_FILE}.tmp"));
        std::fs::write(&tmp, doc.to_string_compact())?;
        std::fs::rename(&tmp, Self::path_for(dir))?;
        Ok(())
    }

    /// Load a journal if one exists.
    pub fn load(dir: &Path) -> Result<Option<ProgressJournal>> {
        let path = Self::path_for(dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        let j = Json::parse(&text)
            .map_err(|e| Error::Session(format!("corrupt journal {}: {e}", path.display())))?;
        let chunk_bytes = j
            .require("chunk_bytes")?
            .as_u64()
            .ok_or_else(|| Error::Session("journal: bad chunk_bytes".into()))?;
        let mut files = Vec::new();
        for f in j
            .require("files")?
            .as_arr()
            .ok_or_else(|| Error::Session("journal: 'files' not an array".into()))?
        {
            let acc = f
                .require("accession")?
                .as_str()
                .ok_or_else(|| Error::Session("journal: bad accession".into()))?
                .to_string();
            let bytes = f
                .require("bytes")?
                .as_u64()
                .ok_or_else(|| Error::Session("journal: bad bytes".into()))?;
            let frontier = f
                .require("frontier")?
                .as_u64()
                .ok_or_else(|| Error::Session("journal: bad frontier".into()))?;
            files.push((acc, bytes, frontier));
        }
        Ok(Some(ProgressJournal { chunk_bytes, files }))
    }

    /// Match this journal against a fresh record list; returns per-file
    /// frontiers (0 for files the journal does not know or whose sizes
    /// changed — those restart from scratch).
    pub fn frontiers_for(&self, records: &[RunRecord]) -> Vec<u64> {
        records
            .iter()
            .map(|r| {
                self.files
                    .iter()
                    .find(|(acc, bytes, _)| acc == &r.accession && *bytes == r.bytes)
                    .map(|&(_, _, frontier)| frontier)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Remove the journal (transfer completed).
    pub fn remove(dir: &Path) -> Result<()> {
        match std::fs::remove_file(Self::path_for(dir)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }

    /// Bytes left to transfer according to the journal.
    pub fn remaining_bytes(&self) -> u64 {
        self.files
            .iter()
            .map(|(_, bytes, frontier)| bytes - frontier.min(bytes))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn records() -> Vec<RunRecord> {
        (0..3)
            .map(|i| {
                RunRecord::new(
                    format!("SRR000000{i}"),
                    "T",
                    1_000 * (i + 1) as u64,
                    format!("http://x/{i}"),
                )
            })
            .collect()
    }

    #[test]
    fn roundtrip_through_disk() {
        let dir = std::env::temp_dir().join(format!("fbdl-journal-{}", std::process::id()));
        let recs = records();
        let j = ProgressJournal::capture(&recs, &[500, 2_000, 0], 256);
        j.save(&dir).unwrap();
        let loaded = ProgressJournal::load(&dir).unwrap().unwrap();
        assert_eq!(loaded, j);
        assert_eq!(loaded.frontiers_for(&recs), vec![500, 2_000, 0]);
        assert_eq!(loaded.remaining_bytes(), 500 + 0 + 3_000);
        ProgressJournal::remove(&dir).unwrap();
        assert!(ProgressJournal::load(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_journal_is_none() {
        let dir = std::env::temp_dir().join("fbdl-journal-none");
        assert!(ProgressJournal::load(&dir).unwrap().is_none());
    }

    #[test]
    fn size_mismatch_restarts_file() {
        let recs = records();
        let mut j = ProgressJournal::capture(&recs, &[100, 200, 300], 256);
        // Simulate the remote file having changed size.
        j.files[1].1 = 9_999;
        assert_eq!(j.frontiers_for(&recs), vec![100, 0, 300]);
    }

    #[test]
    fn capture_clamps_frontier_to_size() {
        let recs = records();
        let j = ProgressJournal::capture(&recs, &[5_000, 5_000, 5_000], 256);
        assert_eq!(j.files[0].2, 1_000);
        assert_eq!(j.remaining_bytes(), 0);
    }
}
