//! The coordinator: everything between "a list of files" and "bytes on
//! disk", minus the transport itself.
//!
//! This is the paper's Figure 3 pipeline and Algorithm 1 realized as
//! composable pieces shared by both session drivers (virtual-time
//! simulation and real sockets):
//!
//! * [`scheduler`] — splits resolved files into range-request chunks and
//!   hands them to workers, bounding how many distinct files are in
//!   flight (FastBioDL's file-ordered chunking) or running whole-file
//!   mode (the baseline tools);
//! * [`pool`] — the shared worker **status array** of Algorithm 1: the
//!   optimizer sets the first `C` slots to run, workers observe their
//!   slot each iteration and park/resume accordingly;
//! * [`probe`] — the per-probe sample window: raw monitor samples in,
//!   XLA-aggregated `(mean, std, …)` out, feeding the controller;
//! * [`resume`] / [`manifest`] — restart support: the progress journal
//!   records each file's contiguous completed frontier, and the chunk
//!   manifest (per-chunk SHA-256 + availability bitfield) upgrades it
//!   to *verified* delta resume when `--verify` is on.

pub mod manifest;
pub mod pool;
pub mod probe;
pub mod resume;
pub mod scheduler;

pub use manifest::{ChunkManifest, ManifestSet};
pub use pool::StatusArray;
pub use resume::ProgressJournal;
pub use probe::ProbeWindow;
pub use scheduler::{Chunk, ChunkScheduler, SchedulerMode};
