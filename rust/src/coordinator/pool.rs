//! Worker status array — the shared control structure of the paper's
//! Algorithm 1 ("Shared Process Status Arrays").
//!
//! The optimizer thread writes the target concurrency by flipping the
//! first `C` slots to RUN and the rest to PARK; each worker polls its
//! own slot between chunks and parks/resumes accordingly. On exit the
//! optimizer "sets all worker statuses to 0" (Algorithm 1 line 9) —
//! [`StatusArray::stop_all`].
//!
//! The array is plain atomics: one relaxed load per worker loop
//! iteration, one batch of stores per probe interval. No locks touch
//! the download hot path.

use std::sync::atomic::{AtomicU8, Ordering};

/// Worker slot states.
pub const PARKED: u8 = 0;
pub const RUNNING: u8 = 1;
/// Terminal: the session is over, workers must exit.
pub const STOPPED: u8 = 2;

/// Shared status array.
pub struct StatusArray {
    slots: Vec<AtomicU8>,
}

impl StatusArray {
    /// Create with `capacity` worker slots, all parked.
    pub fn new(capacity: usize) -> StatusArray {
        StatusArray {
            slots: (0..capacity).map(|_| AtomicU8::new(PARKED)).collect(),
        }
    }

    /// Max workers.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Set the target concurrency: slots `< target` run, the rest park.
    /// Stopped slots stay stopped. Returns the applied target (clamped
    /// to capacity).
    pub fn set_target(&self, target: usize) -> usize {
        let target = target.min(self.slots.len());
        for (i, slot) in self.slots.iter().enumerate() {
            let want = if i < target { RUNNING } else { PARKED };
            // Don't resurrect stopped slots.
            let _ = slot.compare_exchange(
                if want == RUNNING { PARKED } else { RUNNING },
                want,
                Ordering::AcqRel,
                Ordering::Acquire,
            );
        }
        target
    }

    /// Algorithm 1 line 9: ensure workers stop on exit.
    pub fn stop_all(&self) {
        for slot in &self.slots {
            slot.store(STOPPED, Ordering::Release);
        }
    }

    /// Worker-side: should worker `i` be transferring right now?
    #[inline]
    pub fn is_running(&self, i: usize) -> bool {
        self.slots[i].load(Ordering::Acquire) == RUNNING
    }

    /// Worker-side: has the session ended?
    #[inline]
    pub fn is_stopped(&self, i: usize) -> bool {
        self.slots[i].load(Ordering::Acquire) == STOPPED
    }

    /// Count of currently running slots (the live concurrency).
    pub fn running(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.load(Ordering::Acquire) == RUNNING)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_sets_prefix() {
        let a = StatusArray::new(8);
        assert_eq!(a.set_target(3), 3);
        assert_eq!(a.running(), 3);
        assert!(a.is_running(0) && a.is_running(2));
        assert!(!a.is_running(3));
    }

    #[test]
    fn target_clamped_to_capacity() {
        let a = StatusArray::new(4);
        assert_eq!(a.set_target(100), 4);
        assert_eq!(a.running(), 4);
    }

    #[test]
    fn shrink_parks_tail() {
        let a = StatusArray::new(8);
        a.set_target(6);
        a.set_target(2);
        assert_eq!(a.running(), 2);
        assert!(!a.is_running(5));
    }

    #[test]
    fn stop_all_is_terminal() {
        let a = StatusArray::new(4);
        a.set_target(4);
        a.stop_all();
        assert_eq!(a.running(), 0);
        assert!(a.is_stopped(0));
        // set_target cannot resurrect.
        a.set_target(4);
        assert_eq!(a.running(), 0);
        assert!(a.is_stopped(3));
    }

    #[test]
    fn concurrent_workers_observe_changes() {
        use std::sync::Arc;
        let a = Arc::new(StatusArray::new(4));
        a.set_target(4);
        let a2 = a.clone();
        let h = std::thread::spawn(move || {
            // Spin until parked.
            while a2.is_running(3) {
                std::hint::spin_loop();
            }
            true
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        a.set_target(1);
        assert!(h.join().unwrap());
    }
}
