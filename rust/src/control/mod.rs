//! The fault-aware control plane: one typed signal bus between the
//! session engine and the concurrency controllers.
//!
//! Before this layer existed, every new telemetry source needed a
//! bespoke setter on three controllers (the `MirrorHealth →
//! effective_k` side-channel being the canonical example), and rich
//! fault telemetry — retry/reject counts, per-mirror RTT — never
//! reached the optimizer at all. Now the engine assembles **one
//! [`ControlSignals`] snapshot per probe interval** and controllers
//! implement [`Controller`], consuming the snapshot and returning a
//! joint [`ControlAction`]: the next concurrency target *and* a chunk
//! scale driving striping-aware chunk sizing in the scheduler.
//!
//! ```text
//!  engine ──► ControlSignals ──► Controller ──► ControlAction ──┬─► slot pool (concurrency)
//!             goodput EWMA                                      └─► chunk scheduler (chunk_scale)
//!             retry/reject/reset rates
//!             mirror headroom + fail pressure
//!             connect-RTT
//! ```
//!
//! Two knobs gate the fault-aware behaviour
//! ([`crate::config::ControlConfig`]), both **off by default** so every
//! benign, single-mirror, and paper-figure run is bit-identical to the
//! fault-blind controllers:
//!
//! * `fault_penalty` (default `0.0`) — weight of the fault-penalty term
//!   in the adaptive utilities: the window goodput is discounted by the
//!   weighted retry/reject rate ([`discounted_goodput`], backed by
//!   [`crate::optimizer::mirror::fault_discount`]) before it enters the
//!   §4.1 utility `U = T/k^C`, so a concurrency level that "achieves"
//!   its throughput only by burning retries stops looking optimal.
//! * `adaptive_chunks` (default off) — controllers emit
//!   [`ControlAction::chunk_scale`] from the same fault pressure
//!   ([`chunk_scale`]), and the engine additionally shrinks chunks cut
//!   for slots bound to degraded mirrors, so a probe chunk on a
//!   crawling mirror stops tying a slot up for many seconds.

use crate::config::ControlConfig;
use crate::Result;

/// Relative weight of a transient server rejection vs a connection
/// reset in [`weighted_fault_rate`]: a reject costs one backoff and a
/// retried request; a reset additionally pays reconnect + ramp.
pub const REJECT_FAULT_WEIGHT: f64 = 0.5;

/// Gain of the fault pressure → [`chunk_scale`] mapping,
/// `scale = 1 / (1 + GAIN × pressure)` (floored by
/// [`crate::config::ControlConfig::chunk_scale_min`]): half a weighted
/// fault event per second already halves the chunk size.
pub const CHUNK_PRESSURE_GAIN: f64 = 2.0;

/// Aggregate mirror-health signal, part of every [`ControlSignals`]
/// snapshot. Condensed from the per-session
/// [`crate::session::mirrors::MirrorBoard`]: `headroom` is the
/// effective number of simultaneously useful mirrors
/// ([`crate::session::mirrors::MirrorBoard::concurrency_headroom`]),
/// `fail_pressure` the decayed failure rate across the fleet
/// ([`crate::session::mirrors::MirrorBoard::fail_pressure`]).
/// Single-mirror sessions always carry the neutral default, so their
/// controllers behave bit-identically to health-unaware ones.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MirrorHealth {
    /// Effective number of healthy mirrors, in `[1, mirror_count]`.
    pub headroom: f64,
    /// Decayed failure pressure across mirrors (0 = clean).
    pub fail_pressure: f64,
}

impl Default for MirrorHealth {
    /// Neutral signal: one mirror, no failures —
    /// [`crate::optimizer::effective_k`] returns `k` unchanged.
    fn default() -> Self {
        MirrorHealth {
            headroom: 1.0,
            fail_pressure: 0.0,
        }
    }
}

/// One per-probe-interval snapshot of everything the engine knows that
/// a controller could act on. Assembled exactly once per probe by
/// `session::engine`; every field is derived from state the engine
/// already tracks, so the snapshot is free to build and fully
/// deterministic in simulation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControlSignals {
    /// Concurrency target the window was measured at.
    pub concurrency: f64,
    /// Mean goodput over the monitor window (Mbps).
    pub goodput_mbps: f64,
    /// Span the rates below are computed over (s, > 0).
    pub window_s: f64,
    /// Chunk requeues per second over the window (every failure class
    /// requeues its chunk, so this is the superset rate).
    pub retry_rate: f64,
    /// Connection resets per second over the window.
    pub reset_rate: f64,
    /// Transient server rejections (5xx analogue) per second.
    pub reject_rate: f64,
    /// Aggregate mirror health (neutral for single-mirror sessions).
    pub mirror: MirrorHealth,
    /// Fleet mean connect-RTT EWMA (s); `0.0` until any transport
    /// reported a readiness transition.
    pub connect_rtt_s: f64,
}

impl ControlSignals {
    /// A snapshot carrying only a throughput observation — every other
    /// signal neutral. This is the legacy "probe" shape: a controller
    /// fed `ControlSignals::probe(c, t)` behaves exactly like the
    /// pre-signal-bus `on_probe(Probe { c, t })` did.
    pub fn probe(concurrency: f64, goodput_mbps: f64) -> ControlSignals {
        ControlSignals {
            concurrency,
            goodput_mbps,
            window_s: 1.0,
            retry_rate: 0.0,
            reset_rate: 0.0,
            reject_rate: 0.0,
            mirror: MirrorHealth::default(),
            connect_rtt_s: 0.0,
        }
    }
}

/// What a controller wants the engine to do until the next probe.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControlAction {
    /// Worker-pool concurrency target (Algorithm 1's decision).
    pub concurrency: usize,
    /// Scale in `(0, 1]` applied to newly cut chunks while
    /// `adaptive_chunks` is enabled (`1.0` = full-size chunks; the
    /// engine multiplies in a per-mirror degradation factor and floors
    /// the product at
    /// [`crate::config::ControlConfig::chunk_scale_min`]).
    pub chunk_scale: f64,
}

impl ControlAction {
    /// An action that only moves the concurrency target (full-size
    /// chunks) — what static controllers and tests emit.
    pub fn concurrency_only(concurrency: usize) -> ControlAction {
        ControlAction {
            concurrency,
            chunk_scale: 1.0,
        }
    }
}

/// A transfer controller: Algorithm 1's decision step, reworked to
/// consume the full [`ControlSignals`] snapshot and emit a joint
/// [`ControlAction`] (concurrency + chunk scale) instead of a bare
/// concurrency target.
///
/// Deliberately **not** `Send`: the PJRT client (and thus the
/// XLA-backed controllers) lives on the coordinating thread, exactly
/// like the paper's single optimizer thread. Worker threads never touch
/// the controller — they observe the
/// [`crate::coordinator::StatusArray`] it writes through the session
/// driver.
pub trait Controller {
    /// Consume one per-probe signal snapshot, return the next action.
    fn on_signals(&mut self, signals: &ControlSignals) -> Result<ControlAction>;

    /// Current action without new information (initial value).
    fn current(&self) -> ControlAction;

    /// Display name for logs/reports.
    fn name(&self) -> &'static str;
}

/// The weighted retry/reject rate (events/s) feeding both the utility
/// fault penalty and the chunk-scale mapping. Connection resets weigh
/// `1.0` (reconnect + ramp), rejections [`REJECT_FAULT_WEIGHT`]; the
/// superset `retry_rate` is deliberately *not* summed in — it already
/// counts every reset and reject once.
pub fn weighted_fault_rate(signals: &ControlSignals) -> f64 {
    signals.reset_rate.max(0.0) + REJECT_FAULT_WEIGHT * signals.reject_rate.max(0.0)
}

/// Window goodput after the fault penalty: the signal→utility mapping
/// of the adaptive controllers. Delegates the arithmetic to
/// [`crate::optimizer::mirror::fault_discount`] so the pure-Rust
/// utility cross-checks exercise the identical formula. With
/// `fault_penalty <= 0` (the default) or a clean window this returns
/// `signals.goodput_mbps` **unchanged** (same bits), which is what
/// keeps benign and paper-figure runs bit-identical.
pub fn discounted_goodput(signals: &ControlSignals, fault_penalty: f64) -> f64 {
    crate::optimizer::mirror::fault_discount(
        signals.goodput_mbps,
        weighted_fault_rate(signals),
        fault_penalty,
    )
}

/// Chunk scale from fault pressure: `1 / (1 + GAIN × pressure)`,
/// floored at `cfg.chunk_scale_min`, where pressure is the weighted
/// fault rate plus the fleet's decayed mirror fail-pressure. Returns
/// exactly `1.0` when `adaptive_chunks` is off or the window was clean,
/// so default and benign runs cut full-size chunks on the untouched
/// code path.
pub fn chunk_scale(signals: &ControlSignals, cfg: &ControlConfig) -> f64 {
    if !cfg.adaptive_chunks {
        return 1.0;
    }
    let pressure = weighted_fault_rate(signals) + signals.mirror.fail_pressure.max(0.0);
    if pressure <= 0.0 {
        return 1.0;
    }
    (1.0 / (1.0 + CHUNK_PRESSURE_GAIN * pressure)).clamp(cfg.chunk_scale_min.min(1.0), 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hostile(reset_rate: f64, reject_rate: f64) -> ControlSignals {
        ControlSignals {
            reset_rate,
            reject_rate,
            retry_rate: reset_rate + reject_rate,
            ..ControlSignals::probe(4.0, 100.0)
        }
    }

    #[test]
    fn probe_snapshot_is_neutral() {
        let s = ControlSignals::probe(3.0, 250.0);
        assert_eq!(weighted_fault_rate(&s), 0.0);
        assert_eq!(discounted_goodput(&s, 5.0).to_bits(), 250.0f64.to_bits());
        assert_eq!(s.mirror, MirrorHealth::default());
    }

    #[test]
    fn zero_penalty_returns_goodput_bit_identically() {
        let s = hostile(2.0, 4.0);
        assert_eq!(discounted_goodput(&s, 0.0).to_bits(), 100.0f64.to_bits());
        assert_eq!(discounted_goodput(&s, -1.0).to_bits(), 100.0f64.to_bits());
    }

    #[test]
    fn penalty_discounts_and_resets_weigh_more_than_rejects() {
        let resets = hostile(2.0, 0.0);
        let rejects = hostile(0.0, 2.0);
        let d_resets = discounted_goodput(&resets, 1.0);
        let d_rejects = discounted_goodput(&rejects, 1.0);
        assert!(d_resets < d_rejects, "{d_resets} vs {d_rejects}");
        assert!(d_rejects < 100.0);
        // Heavier penalty discounts harder.
        assert!(discounted_goodput(&resets, 3.0) < d_resets);
    }

    #[test]
    fn chunk_scale_is_one_when_off_or_clean() {
        let cfg = ControlConfig::default();
        assert!(!cfg.adaptive_chunks);
        assert_eq!(chunk_scale(&hostile(5.0, 5.0), &cfg), 1.0);
        let on = ControlConfig {
            adaptive_chunks: true,
            ..ControlConfig::default()
        };
        assert_eq!(chunk_scale(&ControlSignals::probe(4.0, 100.0), &on), 1.0);
    }

    #[test]
    fn chunk_scale_shrinks_under_pressure_and_floors() {
        let on = ControlConfig {
            adaptive_chunks: true,
            ..ControlConfig::default()
        };
        let mild = chunk_scale(&hostile(0.25, 0.0), &on);
        assert!(mild < 1.0 && mild > on.chunk_scale_min, "mild: {mild}");
        let storm = chunk_scale(&hostile(50.0, 50.0), &on);
        assert_eq!(storm, on.chunk_scale_min, "storm must floor: {storm}");
        // Mirror fail-pressure alone also shrinks chunks.
        let sick_fleet = ControlSignals {
            mirror: MirrorHealth {
                headroom: 1.0,
                fail_pressure: 1.0,
            },
            ..ControlSignals::probe(4.0, 100.0)
        };
        assert!(chunk_scale(&sick_fleet, &on) < 1.0);
    }

    #[test]
    fn concurrency_only_action_keeps_full_chunks() {
        let a = ControlAction::concurrency_only(7);
        assert_eq!(a.concurrency, 7);
        assert_eq!(a.chunk_scale, 1.0);
    }
}
