//! Declarative fault injection for the simulator.
//!
//! The benign engine (OU background + jitter) never exercises the
//! recovery machinery the paper's reliability claims rest on. This
//! module adds a **seeded, declarative fault schedule**: a sorted list
//! of `(timestamp, fault)` events the engine applies while stepping.
//! Everything stays deterministic — the schedule is data, and the only
//! randomness (victim selection, stall sampling, rejection draws) comes
//! from the engine's own seeded PRNG, so a `(config, seed)` pair replays
//! bit-identically, faults included.
//!
//! ## Fault classes
//!
//! | Kind | Models | Engine effect |
//! |------|--------|---------------|
//! | [`FaultKind::ConnectionReset`] | mid-stream TCP RST / NAT timeout | kills up to `count` busy flows; each emits a `failed` [`crate::netsim::FlowEvent`] |
//! | [`FaultKind::Stall`] | staging hiccup, head-of-line blocking | selected active flows deliver zero bytes until the stall expires |
//! | [`FaultKind::ServerError`] | transient HTTP 5xx window | requests *started* in the window are rejected after first-byte latency (`rejected` event; connection survives) |
//! | [`FaultKind::RateCollapse`] | path reroute, shaper clamp | per-connection cap multiplied by `factor` for the duration |
//! | [`FaultKind::FlashCrowd`] | competing bulk transfer burst | background traffic gains `extra_mbps` for the duration |
//! | [`FaultKind::Brownout`] | overloaded archive front-end | new connections queue behind the brownout; new requests are rejected until it ends |
//! | [`FaultKind::SlowMirror`] | one archive mirror slows while replicas stay healthy | per-connection cap × `factor`, but only for flows bound to the named mirror |
//! | [`FaultKind::MidBodyDrop`] | time-windowed mid-body resets (flaky middlebox, response truncation) | while the window is active, responses crossing `after_bytes` delivered are reset with probability `frac` |
//! | [`FaultKind::BurstLoss`] | Gilbert–Elliott-style correlated losses (flapping link, overloaded middlebox) | while the window is active, a two-state process alternates quiet spells and loss bursts; during a burst every busy flow is reset at `kill_prob`/s |
//! | [`FaultKind::DnsOutage`] | resolver outage / NXDOMAIN storm | connections *opened* during the outage fail at setup (the real driver's explicit DNS step erroring); established flows are untouched |
//! | [`FaultKind::BitFlip`] | silent payload corruption (bit-flip in transit, corrupted cache node) | while the window is active, responses delivering inside it are corrupted with probability `frac` — bytes arrive and count, but their content is wrong; only chunk-hash verification catches it |
//!
//! ## Profiles
//!
//! [`FaultProfile`] names ready-made hostile variants of any scenario —
//! `flaky`, `stalls`, `errors`, `collapse`, `flashcrowd`, `brownout`,
//! `slowmirror`, `burstloss`, `dnsoutage`, `bitflip`, and `chaos` (all
//! of the above interleaved). A profile expands to a
//! concrete [`FaultSchedule`] via [`FaultProfile::schedule`], fully
//! determined by `(profile, seed, horizon, link capacity)`. The CLI
//! exposes this as `fastbiodl download … --faults <profile>`; tests use
//! the same expansion for the controller×fault matrix.

use crate::util::prng::Prng;

/// One fault class with its parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// Abruptly close up to `count` busy (FirstByte/Active) flows.
    ConnectionReset {
        count: usize,
    },
    /// Freeze delivery on each active flow with probability `frac`,
    /// for `duration_s` of simulated time.
    Stall {
        frac: f64,
        duration_s: f64,
    },
    /// For `duration_s`, reject each newly issued request with
    /// probability `reject_prob` (transient 5xx; connection survives).
    ServerError {
        reject_prob: f64,
        duration_s: f64,
    },
    /// Multiply the per-connection rate cap by `factor` (in (0, 1])
    /// for `duration_s`.
    RateCollapse {
        factor: f64,
        duration_s: f64,
    },
    /// Add `extra_mbps` of background traffic for `duration_s`.
    FlashCrowd {
        extra_mbps: f64,
        duration_s: f64,
    },
    /// For `duration_s`: new connections queue until the brownout
    /// lifts, and every new request is rejected.
    Brownout {
        duration_s: f64,
    },
    /// Per-flow asymmetric fault: multiply the per-connection rate cap
    /// by `factor` (in (0, 1]) — but **only** for flows terminating at
    /// `mirror` — for `duration_s`. Models one archive mirror slowing
    /// down or browning out while its replicas stay healthy; the
    /// session engine's mirror failover is what this exercises.
    SlowMirror {
        mirror: usize,
        factor: f64,
        duration_s: f64,
    },
    /// **Windowed** mid-body connection drop: while the window is
    /// active (`duration_s` from the event time), any response that
    /// crosses `after_bytes` delivered bytes is reset with probability
    /// `frac` at the moment of crossing. The client sees a short body
    /// exactly like the loopback server's budget-based `fault_drop_*`
    /// knobs — but scheduled in *time* rather than spent from a
    /// server-wide budget, so a specific phase of a transfer can be
    /// targeted (the ROADMAP's "time-scheduled mid-body drops").
    MidBodyDrop {
        after_bytes: f64,
        frac: f64,
        duration_s: f64,
    },
    /// **Correlated burst losses** (Gilbert–Elliott-style): for
    /// `duration_s`, the link alternates between a *bad* state —
    /// every busy flow is reset with probability `kill_prob` per
    /// second — and a quiet *good* state. Phase lengths are drawn
    /// around `burst_s` (bad) and `gap_s` (good) from the engine's
    /// seeded PRNG, and the window opens in a burst. Unlike
    /// independent [`FaultKind::ConnectionReset`] events, losses
    /// cluster: several connections die within the same burst, which
    /// is exactly the reconnect-stampede pattern flapping links and
    /// overloaded middleboxes produce.
    BurstLoss {
        /// Mean loss-burst (bad-state) length, seconds (> 0).
        burst_s: f64,
        /// Mean quiet-spell (good-state) length, seconds (>= 0).
        gap_s: f64,
        /// Per-second reset probability for each busy flow while the
        /// bad state is active, in [0, 1].
        kill_prob: f64,
        /// Window length, seconds.
        duration_s: f64,
    },
    /// **Name-resolution outage**: for `duration_s`, every connection
    /// *opened* fails during setup (the simulated counterpart of the
    /// real driver's explicit DNS step erroring — see
    /// `transport::reactor`). Established flows keep streaming: DNS
    /// only matters at connect time, which is exactly the asymmetry
    /// that distinguishes this class from a brownout.
    DnsOutage {
        /// Outage length, seconds.
        duration_s: f64,
    },
    /// **Windowed payload corruption** (bit-flip in transit, corrupted
    /// cache node, mid-body swap): while the window is active
    /// (`duration_s` from the event time), each response that delivers
    /// bytes inside it is *silently corrupted* with probability `frac`
    /// — the bytes arrive, count toward progress, and the request
    /// completes normally, but the payload content is wrong. Unlike
    /// every other class, nothing at the transport level signals a
    /// problem; only per-chunk SHA-256 verification against the
    /// integrity manifest detects it. Windowed like
    /// [`FaultKind::MidBodyDrop`].
    BitFlip {
        /// Per-response corruption probability while the window is
        /// active, in [0, 1].
        frac: f64,
        /// Window length, seconds.
        duration_s: f64,
    },
}

impl FaultKind {
    /// Parameter sanity.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            FaultKind::ConnectionReset { count } => {
                if *count == 0 {
                    return Err("ConnectionReset count must be >= 1".into());
                }
            }
            FaultKind::Stall { frac, duration_s } => {
                if !(0.0..=1.0).contains(frac) {
                    return Err(format!("Stall frac {frac} outside [0, 1]"));
                }
                if *duration_s < 0.0 {
                    return Err("Stall duration must be >= 0".into());
                }
            }
            FaultKind::ServerError {
                reject_prob,
                duration_s,
            } => {
                if !(0.0..=1.0).contains(reject_prob) {
                    return Err(format!("ServerError prob {reject_prob} outside [0, 1]"));
                }
                if *duration_s < 0.0 {
                    return Err("ServerError duration must be >= 0".into());
                }
            }
            FaultKind::RateCollapse { factor, duration_s } => {
                if !(*factor > 0.0 && *factor <= 1.0) {
                    return Err(format!("RateCollapse factor {factor} outside (0, 1]"));
                }
                if *duration_s < 0.0 {
                    return Err("RateCollapse duration must be >= 0".into());
                }
            }
            FaultKind::FlashCrowd {
                extra_mbps,
                duration_s,
            } => {
                if *extra_mbps < 0.0 || *duration_s < 0.0 {
                    return Err("FlashCrowd params must be >= 0".into());
                }
            }
            FaultKind::Brownout { duration_s } => {
                if *duration_s < 0.0 {
                    return Err("Brownout duration must be >= 0".into());
                }
            }
            FaultKind::SlowMirror {
                factor, duration_s, ..
            } => {
                if !(*factor > 0.0 && *factor <= 1.0) {
                    return Err(format!("SlowMirror factor {factor} outside (0, 1]"));
                }
                if *duration_s < 0.0 {
                    return Err("SlowMirror duration must be >= 0".into());
                }
            }
            FaultKind::MidBodyDrop {
                after_bytes,
                frac,
                duration_s,
            } => {
                if !(*after_bytes >= 0.0 && after_bytes.is_finite()) {
                    return Err(format!("MidBodyDrop after_bytes {after_bytes} invalid"));
                }
                if !(0.0..=1.0).contains(frac) {
                    return Err(format!("MidBodyDrop frac {frac} outside [0, 1]"));
                }
                if *duration_s < 0.0 {
                    return Err("MidBodyDrop duration must be >= 0".into());
                }
            }
            FaultKind::BurstLoss {
                burst_s,
                gap_s,
                kill_prob,
                duration_s,
            } => {
                if !(*burst_s > 0.0 && burst_s.is_finite()) {
                    return Err(format!("BurstLoss burst_s {burst_s} must be > 0"));
                }
                if !(*gap_s >= 0.0 && gap_s.is_finite()) {
                    return Err(format!("BurstLoss gap_s {gap_s} must be >= 0"));
                }
                if !(0.0..=1.0).contains(kill_prob) {
                    return Err(format!("BurstLoss kill_prob {kill_prob} outside [0, 1]"));
                }
                if *duration_s < 0.0 {
                    return Err("BurstLoss duration must be >= 0".into());
                }
            }
            FaultKind::DnsOutage { duration_s } => {
                if *duration_s < 0.0 {
                    return Err("DnsOutage duration must be >= 0".into());
                }
            }
            FaultKind::BitFlip { frac, duration_s } => {
                if !(0.0..=1.0).contains(frac) {
                    return Err(format!("BitFlip frac {frac} outside [0, 1]"));
                }
                if *duration_s < 0.0 {
                    return Err("BitFlip duration must be >= 0".into());
                }
            }
        }
        Ok(())
    }

    /// Short label for logs.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::ConnectionReset { .. } => "connection-reset",
            FaultKind::Stall { .. } => "stall",
            FaultKind::ServerError { .. } => "server-error",
            FaultKind::RateCollapse { .. } => "rate-collapse",
            FaultKind::FlashCrowd { .. } => "flash-crowd",
            FaultKind::Brownout { .. } => "brownout",
            FaultKind::SlowMirror { .. } => "slow-mirror",
            FaultKind::MidBodyDrop { .. } => "mid-body-drop",
            FaultKind::BurstLoss { .. } => "burst-loss",
            FaultKind::DnsOutage { .. } => "dns-outage",
            FaultKind::BitFlip { .. } => "bit-flip",
        }
    }
}

/// One scheduled fault.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    /// Simulated time (s) at which the fault fires.
    pub at_s: f64,
    /// What happens at that time.
    pub kind: FaultKind,
}

/// A time-sorted list of faults the engine applies while stepping.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
}

impl FaultSchedule {
    /// Empty schedule (the benign default).
    pub fn none() -> FaultSchedule {
        FaultSchedule::default()
    }

    /// Build from events (sorted by time on construction).
    pub fn new(mut events: Vec<FaultEvent>) -> FaultSchedule {
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FaultSchedule { events }
    }

    /// Time-ordered event list.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// No scheduled events (benign network).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Validate every event.
    pub fn validate(&self) -> Result<(), String> {
        for ev in &self.events {
            if !ev.at_s.is_finite() || ev.at_s < 0.0 {
                return Err(format!("fault at_s {} must be finite and >= 0", ev.at_s));
            }
            ev.kind.validate()?;
        }
        Ok(())
    }

    /// Merge two schedules (re-sorted).
    pub fn merged(mut self, other: FaultSchedule) -> FaultSchedule {
        self.events.extend(other.events);
        FaultSchedule::new(self.events)
    }
}

/// Named hostile profiles — each expands deterministically into a
/// [`FaultSchedule`] for a given `(seed, horizon, link)` triple.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultProfile {
    /// No faults.
    None,
    /// Periodic mid-transfer connection resets (flaky WAN path).
    Flaky,
    /// Recurring multi-second delivery stalls on live flows.
    Stalls,
    /// Transient 5xx windows (overloaded archive front-end).
    ServerErrors,
    /// Deep per-connection rate collapses (path reroutes).
    RateCollapse,
    /// Background flash crowds eating most of the link.
    FlashCrowd,
    /// Server brownouts: no new connections or requests for a while.
    Brownout,
    /// One slow mirror: the primary endpoint's per-connection rate
    /// collapses early and stays degraded while replicas stay healthy
    /// (per-flow asymmetric fault; exercises mirror failover).
    SlowMirror,
    /// Correlated burst losses: recurring windows in which a
    /// Gilbert–Elliott two-state process clusters connection resets
    /// into short storms separated by quiet spells.
    BurstLoss,
    /// Recurring resolver outages: connections opened inside an outage
    /// window fail at setup, established flows keep streaming.
    DnsOutage,
    /// Recurring silent-corruption windows: responses delivering inside
    /// a window are corrupted at high probability. Needs `--verify` to
    /// surface at all — with verification off the transfer "succeeds"
    /// with wrong bytes.
    BitFlip,
    /// Everything above, interleaved.
    Chaos,
}

/// Profiles exercised by the controller×fault test matrix.
pub const MATRIX_PROFILES: [FaultProfile; 10] = [
    FaultProfile::Flaky,
    FaultProfile::Stalls,
    FaultProfile::ServerErrors,
    FaultProfile::RateCollapse,
    FaultProfile::FlashCrowd,
    FaultProfile::Brownout,
    FaultProfile::SlowMirror,
    FaultProfile::BurstLoss,
    FaultProfile::DnsOutage,
    FaultProfile::BitFlip,
];

impl FaultProfile {
    /// Parse a CLI/profile name.
    pub fn parse(s: &str) -> Result<FaultProfile, String> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Ok(FaultProfile::None),
            "flaky" | "resets" => Ok(FaultProfile::Flaky),
            "stalls" | "stall" => Ok(FaultProfile::Stalls),
            "errors" | "server-errors" | "5xx" => Ok(FaultProfile::ServerErrors),
            "collapse" | "rate-collapse" => Ok(FaultProfile::RateCollapse),
            "flashcrowd" | "flash-crowd" | "crowd" => Ok(FaultProfile::FlashCrowd),
            "brownout" => Ok(FaultProfile::Brownout),
            "slowmirror" | "slow-mirror" => Ok(FaultProfile::SlowMirror),
            "burstloss" | "burst-loss" | "bursts" => Ok(FaultProfile::BurstLoss),
            "dns" | "dnsoutage" | "dns-outage" => Ok(FaultProfile::DnsOutage),
            "bitflip" | "bit-flip" | "corruption" => Ok(FaultProfile::BitFlip),
            "chaos" | "all" => Ok(FaultProfile::Chaos),
            other => Err(format!(
                "unknown fault profile '{other}' (none|flaky|stalls|errors|collapse|\
                 flashcrowd|brownout|slowmirror|burstloss|dnsoutage|bitflip|chaos)"
            )),
        }
    }

    /// Canonical profile name (the `--faults` spelling).
    pub fn name(&self) -> &'static str {
        match self {
            FaultProfile::None => "none",
            FaultProfile::Flaky => "flaky",
            FaultProfile::Stalls => "stalls",
            FaultProfile::ServerErrors => "errors",
            FaultProfile::RateCollapse => "collapse",
            FaultProfile::FlashCrowd => "flashcrowd",
            FaultProfile::Brownout => "brownout",
            FaultProfile::SlowMirror => "slowmirror",
            FaultProfile::BurstLoss => "burstloss",
            FaultProfile::DnsOutage => "dnsoutage",
            FaultProfile::BitFlip => "bitflip",
            FaultProfile::Chaos => "chaos",
        }
    }

    /// Expand to a concrete schedule covering `[0, horizon_s)`.
    ///
    /// `link_mbps` scales the flash-crowd magnitude. Identical
    /// arguments produce identical schedules; the per-profile PRNG is
    /// forked from `seed` with a profile-specific label so `chaos`
    /// reproduces each component stream exactly.
    pub fn schedule(&self, seed: u64, horizon_s: f64, link_mbps: f64) -> FaultSchedule {
        let mut events = Vec::new();
        match self {
            FaultProfile::None => {}
            FaultProfile::Flaky => gen_flaky(seed, horizon_s, &mut events),
            FaultProfile::Stalls => gen_stalls(seed, horizon_s, &mut events),
            FaultProfile::ServerErrors => gen_errors(seed, horizon_s, &mut events),
            FaultProfile::RateCollapse => gen_collapse(seed, horizon_s, &mut events),
            FaultProfile::FlashCrowd => gen_crowd(seed, horizon_s, link_mbps, &mut events),
            FaultProfile::Brownout => gen_brownout(seed, horizon_s, &mut events),
            FaultProfile::SlowMirror => gen_slowmirror(seed, horizon_s, &mut events),
            FaultProfile::BurstLoss => gen_burstloss(seed, horizon_s, &mut events),
            FaultProfile::DnsOutage => gen_dns(seed, horizon_s, &mut events),
            FaultProfile::BitFlip => gen_bitflip(seed, horizon_s, &mut events),
            FaultProfile::Chaos => {
                gen_flaky(seed, horizon_s, &mut events);
                gen_stalls(seed, horizon_s, &mut events);
                gen_errors(seed, horizon_s, &mut events);
                gen_collapse(seed, horizon_s, &mut events);
                gen_crowd(seed, horizon_s, link_mbps, &mut events);
                gen_brownout(seed, horizon_s, &mut events);
                gen_slowmirror(seed, horizon_s, &mut events);
                gen_bodydrops(seed, horizon_s, &mut events);
                gen_burstloss(seed, horizon_s, &mut events);
                gen_dns(seed, horizon_s, &mut events);
                gen_bitflip(seed, horizon_s, &mut events);
            }
        }
        FaultSchedule::new(events)
    }
}

fn profile_rng(seed: u64, label: u64) -> Prng {
    Prng::new(seed ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

fn gen_flaky(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0xF1A);
    let mut t = rng.range_f64(5.0, 12.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::ConnectionReset {
                count: 1 + rng.below(2) as usize,
            },
        });
        t += rng.range_f64(10.0, 25.0);
    }
}

fn gen_stalls(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0x57A);
    let mut t = rng.range_f64(8.0, 16.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::Stall {
                frac: rng.range_f64(0.4, 0.9),
                duration_s: rng.range_f64(2.0, 6.0),
            },
        });
        t += rng.range_f64(18.0, 40.0);
    }
}

fn gen_errors(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0x5E5);
    let mut t = rng.range_f64(6.0, 14.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::ServerError {
                reject_prob: rng.range_f64(0.5, 0.9),
                duration_s: rng.range_f64(3.0, 8.0),
            },
        });
        t += rng.range_f64(20.0, 45.0);
    }
}

fn gen_collapse(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0xC01);
    let mut t = rng.range_f64(10.0, 20.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::RateCollapse {
                factor: rng.range_f64(0.1, 0.4),
                duration_s: rng.range_f64(5.0, 15.0),
            },
        });
        t += rng.range_f64(30.0, 60.0);
    }
}

fn gen_crowd(seed: u64, horizon_s: f64, link_mbps: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0xCD0);
    let mut t = rng.range_f64(10.0, 20.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::FlashCrowd {
                extra_mbps: link_mbps * rng.range_f64(0.5, 0.85),
                duration_s: rng.range_f64(5.0, 15.0),
            },
        });
        t += rng.range_f64(25.0, 55.0);
    }
}

fn gen_brownout(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0xB00);
    let mut t = rng.range_f64(12.0, 24.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::Brownout {
                duration_s: rng.range_f64(3.0, 8.0),
            },
        });
        t += rng.range_f64(35.0, 70.0);
    }
}

fn gen_bodydrops(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0xD20);
    // Windowed mid-body drops ride only in `chaos` for now: recurring
    // short windows during which responses die after a few MB.
    let mut t = rng.range_f64(15.0, 30.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::MidBodyDrop {
                after_bytes: rng.range_f64(1.0, 8.0) * 1e6,
                frac: rng.range_f64(0.4, 0.9),
                duration_s: rng.range_f64(4.0, 10.0),
            },
        });
        t += rng.range_f64(40.0, 80.0);
    }
}

fn gen_burstloss(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0x6E0);
    // Recurring Gilbert–Elliott windows: sub-two-second loss bursts
    // separated by a few quiet seconds, with a high per-second kill
    // probability inside each burst, so resets arrive clustered.
    let mut t = rng.range_f64(8.0, 18.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::BurstLoss {
                burst_s: rng.range_f64(0.5, 2.0),
                gap_s: rng.range_f64(2.0, 6.0),
                kill_prob: rng.range_f64(0.5, 0.95),
                duration_s: rng.range_f64(8.0, 20.0),
            },
        });
        t += rng.range_f64(30.0, 60.0);
    }
}

fn gen_dns(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0xD15);
    // Recurring resolver outages: a few seconds each, far enough apart
    // that established flows finish their chunks between outages.
    let mut t = rng.range_f64(10.0, 22.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::DnsOutage {
                duration_s: rng.range_f64(3.0, 9.0),
            },
        });
        t += rng.range_f64(30.0, 65.0);
    }
}

fn gen_bitflip(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0xB17);
    // Recurring silent-corruption windows with a high per-response
    // corruption probability: frequent enough that a multi-minute
    // transfer is guaranteed to cross several, so a verified session
    // must observe (and re-fetch) corrupt chunks.
    let mut t = rng.range_f64(4.0, 10.0);
    while t < horizon_s {
        out.push(FaultEvent {
            at_s: t,
            kind: FaultKind::BitFlip {
                frac: rng.range_f64(0.5, 0.9),
                duration_s: rng.range_f64(4.0, 10.0),
            },
        });
        t += rng.range_f64(20.0, 40.0);
    }
}

fn gen_slowmirror(seed: u64, horizon_s: f64, out: &mut Vec<FaultEvent>) {
    let mut rng = profile_rng(seed, 0x510);
    // The primary mirror collapses early and stays degraded for the
    // whole horizon — the canonical "one slow mirror" scenario. Healthy
    // replicas (mirror index >= 1) are untouched; single-mirror
    // workloads simply ride out a deep but survivable slowdown.
    out.push(FaultEvent {
        at_s: rng.range_f64(4.0, 8.0),
        kind: FaultKind::SlowMirror {
            mirror: 0,
            factor: rng.range_f64(0.05, 0.12),
            duration_s: horizon_s,
        },
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_sorted_and_deterministic() {
        for p in MATRIX_PROFILES.iter().chain([&FaultProfile::Chaos]) {
            let a = p.schedule(42, 600.0, 1_000.0);
            let b = p.schedule(42, 600.0, 1_000.0);
            assert_eq!(a, b, "profile {} not deterministic", p.name());
            assert!(!a.is_empty(), "profile {} generated nothing", p.name());
            a.validate().unwrap();
            for w in a.events().windows(2) {
                assert!(w[0].at_s <= w[1].at_s, "unsorted schedule");
            }
            let c = p.schedule(43, 600.0, 1_000.0);
            assert_ne!(a, c, "profile {} ignores the seed", p.name());
        }
        assert!(FaultProfile::None.schedule(1, 600.0, 1_000.0).is_empty());
    }

    #[test]
    fn chaos_contains_every_class() {
        let s = FaultProfile::Chaos.schedule(7, 600.0, 2_000.0);
        let mut names: Vec<&str> = s.events().iter().map(|e| e.kind.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 11, "chaos missing classes: {names:?}");
        assert!(
            names.contains(&"mid-body-drop"),
            "chaos should include the windowed mid-body drop: {names:?}"
        );
        assert!(
            names.contains(&"bit-flip"),
            "chaos should include silent corruption windows: {names:?}"
        );
        assert!(
            names.contains(&"burst-loss"),
            "chaos should include correlated burst losses: {names:?}"
        );
    }

    #[test]
    fn parse_roundtrips_names() {
        for p in [
            FaultProfile::None,
            FaultProfile::Flaky,
            FaultProfile::Stalls,
            FaultProfile::ServerErrors,
            FaultProfile::RateCollapse,
            FaultProfile::FlashCrowd,
            FaultProfile::Brownout,
            FaultProfile::SlowMirror,
            FaultProfile::BurstLoss,
            FaultProfile::DnsOutage,
            FaultProfile::BitFlip,
            FaultProfile::Chaos,
        ] {
            assert_eq!(FaultProfile::parse(p.name()).unwrap(), p);
        }
        assert!(FaultProfile::parse("meteor-strike").is_err());
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(FaultKind::ConnectionReset { count: 0 }.validate().is_err());
        assert!(FaultKind::Stall {
            frac: 1.5,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::BitFlip {
            frac: 1.5,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::BitFlip {
            frac: 0.5,
            duration_s: -1.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::RateCollapse {
            factor: 0.0,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::ServerError {
            reject_prob: -0.1,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::SlowMirror {
            mirror: 0,
            factor: 0.0,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::SlowMirror {
            mirror: 3,
            factor: 0.5,
            duration_s: 10.0
        }
        .validate()
        .is_ok());
        assert!(FaultKind::MidBodyDrop {
            after_bytes: -1.0,
            frac: 0.5,
            duration_s: 5.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::MidBodyDrop {
            after_bytes: 1e6,
            frac: 1.5,
            duration_s: 5.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::MidBodyDrop {
            after_bytes: 1e6,
            frac: 0.7,
            duration_s: 5.0
        }
        .validate()
        .is_ok());
        assert!(FaultKind::BurstLoss {
            burst_s: 0.0,
            gap_s: 2.0,
            kill_prob: 0.5,
            duration_s: 10.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::BurstLoss {
            burst_s: 1.0,
            gap_s: -1.0,
            kill_prob: 0.5,
            duration_s: 10.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::BurstLoss {
            burst_s: 1.0,
            gap_s: 2.0,
            kill_prob: 1.5,
            duration_s: 10.0
        }
        .validate()
        .is_err());
        assert!(FaultKind::BurstLoss {
            burst_s: 1.0,
            gap_s: 3.0,
            kill_prob: 0.8,
            duration_s: 12.0
        }
        .validate()
        .is_ok());
        let bad = FaultSchedule::new(vec![FaultEvent {
            at_s: -1.0,
            kind: FaultKind::Brownout { duration_s: 1.0 },
        }]);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn merged_schedules_stay_sorted() {
        let a = FaultProfile::Flaky.schedule(1, 300.0, 1_000.0);
        let b = FaultProfile::Brownout.schedule(1, 300.0, 1_000.0);
        let n = a.len() + b.len();
        let m = a.merged(b);
        assert_eq!(m.len(), n);
        for w in m.events().windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
    }
}
