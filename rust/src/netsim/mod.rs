//! Virtual-time network simulator.
//!
//! This is the substrate that stands in for the two testbeds of the
//! paper's evaluation — the Colab↔NCBI/ENA WAN of §5.1 and the FABRIC
//! NCSA↔SALT high-speed link of §5.2 (see DESIGN.md §2 for the
//! substitution argument). It models exactly the phenomena the paper's
//! results turn on:
//!
//! * a **shared bottleneck link** with max-min fair sharing across
//!   concurrent connections ([`link`]),
//! * **volatile available bandwidth** — an Ornstein–Uhlenbeck
//!   background-traffic process reproduces the fluctuation structure of
//!   the paper's Figure 2 ([`traffic`]),
//! * **per-connection rate caps** (server-side shaping; the quantity
//!   that makes the theoretical optimal concurrency `C* = link ÷ cap`
//!   in Figure 6) and TCP-like **slow-start ramps** ([`flow`]),
//! * **connection setup latency**, per-request **first-byte latency**
//!   (SRA cold-storage staging), and **long-request throughput decay**
//!   (the single-stream degradation of Figure 1) ([`server`]),
//! * **client-side overheads** — stream-management penalty growing with
//!   concurrency and an aggregate write ceiling, which produce the
//!   "excessive load" regime of §3 ([`client`]),
//! * **injected faults** — seeded, declarative schedules of connection
//!   resets, delivery stalls, transient 5xx windows, per-connection
//!   rate collapses, flash crowds, server brownouts, DNS/resolution
//!   outages, and per-flow asymmetric single-mirror slowdowns
//!   ([`fault`]), the substrate for testing recovery and
//!   mirror-failover behaviour under hostile networks.
//!
//! Time is virtual: [`engine::NetSim::step`] advances the world by `dt`
//! seconds of simulated time in microseconds of wall time, so the
//! benches replay multi-hundred-second transfers instantly and every
//! run is deterministic given its seed.

pub mod client;
pub mod engine;
pub mod fault;
pub mod flow;
pub mod link;
pub mod server;
pub mod traffic;

pub use client::ClientProfile;
pub use engine::{FlowEvent, NetSim, NetSimConfig, StepReport};
pub use fault::{FaultEvent, FaultKind, FaultProfile, FaultSchedule};
pub use flow::{FlowId, FlowPhase};
pub use server::ServerProfile;
pub use traffic::OuProcess;

/// Convert megabits/second × seconds to bytes.
#[inline]
pub fn mbps_to_bytes(mbps: f64, secs: f64) -> f64 {
    mbps * 1e6 / 8.0 * secs
}

/// Convert bytes / seconds to megabits/second.
#[inline]
pub fn bytes_to_mbps(bytes: f64, secs: f64) -> f64 {
    if secs <= 0.0 {
        0.0
    } else {
        bytes * 8.0 / 1e6 / secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions_roundtrip() {
        let bytes = mbps_to_bytes(800.0, 2.0);
        assert!((bytes - 200e6).abs() < 1.0);
        let mbps = bytes_to_mbps(bytes, 2.0);
        assert!((mbps - 800.0).abs() < 1e-9);
        assert_eq!(bytes_to_mbps(123.0, 0.0), 0.0);
    }
}
