//! Max-min fair bandwidth allocation over a shared bottleneck link.
//!
//! Each simulation step the engine collects every active flow's demand
//! (its per-connection cap × ramp × jitter × decay) and water-fills the
//! link's currently available capacity across them: capacity is divided
//! equally, flows whose demand is below their equal share keep their
//! demand, and the surplus is redistributed among the rest until either
//! every flow is satisfied or the link is exhausted. This is the
//! standard fluid approximation of long-lived TCP flows sharing one
//! bottleneck and is what makes "theoretical optimal concurrency =
//! link ÷ per-thread cap" hold in the Figure-6 scenarios.

/// Water-fill `capacity` across `demands`; returns per-flow allocations.
///
/// Invariants (property-tested in `rust/tests/prop_netsim.rs`):
/// * `alloc[i] <= demands[i]` for all `i`,
/// * `sum(alloc) <= capacity + eps`,
/// * if `sum(demands) <= capacity`, every flow gets exactly its demand,
/// * allocations are monotone in demand: `demands[i] <= demands[j]`
///   implies `alloc[i] <= alloc[j] + eps`.
pub fn max_min_fair(capacity: f64, demands: &[f64]) -> Vec<f64> {
    let mut alloc = Vec::new();
    let mut scratch = Vec::new();
    max_min_fair_into(capacity, demands, &mut alloc, &mut scratch);
    alloc
}

/// Allocation-free variant for the engine hot path: writes the result
/// into `alloc` and uses `order_scratch` for the index sort, both
/// reused across steps (§Perf optimization 1 — see EXPERIMENTS.md).
pub fn max_min_fair_into(
    capacity: f64,
    demands: &[f64],
    alloc: &mut Vec<f64>,
    order_scratch: &mut Vec<usize>,
) {
    let n = demands.len();
    alloc.clear();
    if n == 0 {
        return;
    }
    let capacity = capacity.max(0.0);
    let total: f64 = demands.iter().sum();
    if total <= capacity {
        alloc.extend_from_slice(demands);
        return;
    }

    // Sort indices by demand ascending; fill smallest first.
    order_scratch.clear();
    order_scratch.extend(0..n);
    order_scratch.sort_unstable_by(|&a, &b| demands[a].total_cmp(&demands[b]));

    alloc.resize(n, 0.0);
    let mut remaining = capacity;
    let mut left = n;
    for &i in order_scratch.iter() {
        let fair = remaining / left as f64;
        let got = demands[i].min(fair).max(0.0);
        alloc[i] = got;
        remaining -= got;
        left -= 1;
    }
}

/// The bottleneck link: nominal capacity minus a dynamic background
/// component gives the capacity available to foreground flows.
#[derive(Clone, Debug)]
pub struct Link {
    /// Nominal line rate (Mbps).
    pub capacity_mbps: f64,
}

impl Link {
    pub fn new(capacity_mbps: f64) -> Self {
        assert!(capacity_mbps > 0.0, "link capacity must be positive");
        Link { capacity_mbps }
    }

    /// Capacity left for foreground flows after background traffic.
    pub fn available(&self, background_mbps: f64) -> f64 {
        (self.capacity_mbps - background_mbps).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-9, "{a} != {b}");
    }

    #[test]
    fn under_subscription_gives_demands() {
        let a = max_min_fair(1000.0, &[100.0, 200.0, 300.0]);
        assert_eq!(a, vec![100.0, 200.0, 300.0]);
    }

    #[test]
    fn equal_demands_split_evenly() {
        let a = max_min_fair(900.0, &[500.0, 500.0, 500.0]);
        for x in a {
            assert_close(x, 300.0);
        }
    }

    #[test]
    fn small_flows_keep_demand_surplus_redistributed() {
        // capacity 900: flow0 wants 100 (gets it), the other two split 800.
        let a = max_min_fair(900.0, &[100.0, 600.0, 600.0]);
        assert_close(a[0], 100.0);
        assert_close(a[1], 400.0);
        assert_close(a[2], 400.0);
    }

    #[test]
    fn zero_capacity_zero_alloc() {
        let a = max_min_fair(0.0, &[10.0, 20.0]);
        assert_eq!(a, vec![0.0, 0.0]);
    }

    #[test]
    fn empty_demands() {
        assert!(max_min_fair(100.0, &[]).is_empty());
    }

    #[test]
    fn conservation_and_bounds() {
        let demands = [120.0, 45.0, 800.0, 0.0, 333.0, 500.0];
        let cap = 1000.0;
        let a = max_min_fair(cap, &demands);
        let sum: f64 = a.iter().sum();
        assert!(sum <= cap + 1e-9);
        for (x, d) in a.iter().zip(&demands) {
            assert!(*x <= *d + 1e-9);
            assert!(*x >= 0.0);
        }
    }

    #[test]
    fn link_available_saturates_at_zero() {
        let l = Link::new(1000.0);
        assert_close(l.available(200.0), 800.0);
        assert_close(l.available(2000.0), 0.0);
    }
}
