//! A single simulated connection (socket stream) and the requests
//! flowing over it.
//!
//! Lifecycle:
//!
//! ```text
//! open_flow() ──► Setup(setup_latency) ──► Idle
//!                                           │ begin_request(bytes)
//!                                           ▼
//!                        FirstByte(staging) ──► Active ──► Idle (request done)
//!                                           ▲               │
//!                                           └───────────────┘  (keep-alive reuse)
//! close_flow() at any point ──► Closed
//! ```
//!
//! With HTTP/1.1 request pipelining ([`SimFlow::pending`]), further
//! requests may be queued while one is in flight; the engine promotes
//! them FIFO when the head request completes, crediting the time the
//! pipelined request already spent waiting against its first-byte
//! staging latency (the server stages the next object while the wire
//! is busy).
//!
//! While `Active`, the flow's demand each step is
//! `per_conn_cap × slow_start_ramp × jitter × long_request_decay`; the
//! link then water-fills actual rates across all active flows. The
//! slow-start ramp doubles an initial rate fraction every RTT-scale
//! interval until it reaches 1.0, modelling TCP congestion-window
//! growth without simulating packets.

use std::collections::VecDeque;

use crate::util::prng::Prng;

/// Opaque flow identifier (index into the engine's flow table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

/// A pipelined request queued behind the one currently in flight on
/// this connection (HTTP/1.1 request pipelining). Promoted FIFO when
/// the in-flight request finishes or aborts; dropped silently if the
/// connection dies (the coordinator requeues the unanswered tail).
#[derive(Clone, Copy, Debug)]
pub struct PendingRequest {
    /// Payload size (bytes).
    pub bytes: f64,
    /// Whether the object pays cold first-byte staging.
    pub cold: bool,
    /// Coordinator tag identifying the work item.
    pub tag: u64,
    /// Absolute sim time the request was queued. The server stages a
    /// pipelined object while the wire is busy with its predecessor,
    /// so time already spent waiting is credited against the staging
    /// latency at promotion.
    pub enqueued_s: f64,
}

/// Connection lifecycle phase.
#[derive(Clone, Debug, PartialEq)]
pub enum FlowPhase {
    /// TCP/TLS handshake in progress; no requests accepted yet.
    Setup { remaining_s: f64 },
    /// Connected, no request in flight (keep-alive parking).
    Idle,
    /// Request issued, server staging the object (time to first byte).
    FirstByte { remaining_s: f64 },
    /// Payload flowing.
    Active,
    /// Closed (terminal).
    Closed,
}

/// One simulated connection.
#[derive(Debug)]
pub struct SimFlow {
    pub id: FlowId,
    pub phase: FlowPhase,
    /// Bytes left in the current request (meaningful in FirstByte/Active).
    pub request_remaining: f64,
    /// Bytes delivered for the current request so far (resets on
    /// `begin_request`/`abort_request`): the mid-body drop injection
    /// keys off this to kill a response part-way through its body.
    pub request_delivered: f64,
    /// Age of the current request (s), for long-request decay.
    pub request_age_s: f64,
    /// Total bytes this flow has delivered.
    pub delivered_bytes: f64,
    /// Slow-start ramp factor in (0, 1]; grows toward 1.
    ramp: f64,
    /// Per-flow static rate jitter (multiplicative, ~N(1, jitter)).
    jitter: f64,
    /// Opaque tag the coordinator uses to map flows to work items.
    pub tag: u64,
    /// Mirror endpoint this connection terminates at (0 = primary).
    /// Per-flow asymmetric faults (one slow mirror) key off this.
    pub mirror: usize,
    /// Injected stall: demand is zero until this simulated timestamp
    /// (absolute engine time; 0 = no stall).
    pub stalled_until_s: f64,
    /// Injected transient server error: the in-flight request will be
    /// rejected when its first-byte timer fires.
    pub reject_pending: bool,
    /// Injected resolution failure: the connection was opened inside a
    /// DNS-outage window and dies as soon as its setup timer fires
    /// (the simulated counterpart of the real connector's DNS step
    /// erroring).
    pub fail_on_setup: bool,
    /// Injected silent corruption: the current response's payload is
    /// wrong on the wire. The transfer itself proceeds normally — only
    /// hash verification can notice (see [`super::fault::FaultKind::BitFlip`]).
    pub corrupted: bool,
    /// Whether the corruption draw for the current response has been
    /// made yet (one Bernoulli trial per response per window).
    pub corrupt_checked: bool,
    /// Pipelined requests queued behind the in-flight one (HTTP/1.1
    /// request pipelining; empty at pipeline depth 1).
    pub pending: VecDeque<PendingRequest>,
}

/// Initial slow-start ramp fraction.
const RAMP_START: f64 = 0.15;
/// Ramp doubling time constant (s): reaches 1.0 from 0.15 in ~5–6 units.
const RAMP_TAU_S: f64 = 0.35;

impl SimFlow {
    pub fn new(id: FlowId, setup_latency_s: f64, jitter_frac: f64, rng: &mut Prng) -> Self {
        let jitter = (1.0 + jitter_frac * rng.normal()).clamp(0.6, 1.4);
        SimFlow {
            id,
            phase: if setup_latency_s > 0.0 {
                FlowPhase::Setup {
                    remaining_s: setup_latency_s,
                }
            } else {
                FlowPhase::Idle
            },
            request_remaining: 0.0,
            request_delivered: 0.0,
            request_age_s: 0.0,
            delivered_bytes: 0.0,
            ramp: RAMP_START,
            jitter,
            tag: 0,
            mirror: 0,
            stalled_until_s: 0.0,
            reject_pending: false,
            fail_on_setup: false,
            corrupted: false,
            corrupt_checked: false,
            pending: VecDeque::new(),
        }
    }

    /// Whether the flow can accept `begin_request`.
    pub fn is_idle(&self) -> bool {
        matches!(self.phase, FlowPhase::Idle)
    }

    /// Whether the flow is moving payload bytes this step.
    pub fn is_active(&self) -> bool {
        matches!(self.phase, FlowPhase::Active)
    }

    pub fn is_closed(&self) -> bool {
        matches!(self.phase, FlowPhase::Closed)
    }

    /// Whether the flow has a request in flight (FirstByte or Active) —
    /// the population fault injection selects reset victims from.
    pub fn is_busy(&self) -> bool {
        matches!(self.phase, FlowPhase::FirstByte { .. } | FlowPhase::Active)
    }

    /// Abort the in-flight request (injected server rejection): the
    /// connection survives and returns to Idle; the caller reschedules
    /// the work elsewhere or retries after backoff.
    pub fn abort_request(&mut self) {
        debug_assert!(self.is_busy(), "abort_request on non-busy flow");
        self.request_remaining = 0.0;
        self.request_delivered = 0.0;
        self.request_age_s = 0.0;
        self.reject_pending = false;
        self.corrupted = false;
        self.corrupt_checked = false;
        self.phase = FlowPhase::Idle;
    }

    /// Issue a request for `bytes` on this (idle) connection.
    ///
    /// `first_byte_latency_s` models server-side staging; pass 0 for a
    /// warm object. Panics if the flow is not idle — the engine
    /// enforces the lifecycle.
    pub fn begin_request(&mut self, bytes: f64, first_byte_latency_s: f64) {
        assert!(
            self.is_idle(),
            "begin_request on non-idle flow {:?} ({:?})",
            self.id,
            self.phase
        );
        assert!(bytes > 0.0, "request must move at least one byte");
        self.request_remaining = bytes;
        self.request_delivered = 0.0;
        self.request_age_s = 0.0;
        self.corrupted = false;
        self.corrupt_checked = false;
        // Keep-alive reuse keeps TCP's window mostly open: restart the
        // ramp only partially on subsequent requests.
        self.ramp = self.ramp.max(RAMP_START).min(1.0).max(0.5 * self.ramp);
        self.phase = if first_byte_latency_s > 0.0 {
            FlowPhase::FirstByte {
                remaining_s: first_byte_latency_s,
            }
        } else {
            FlowPhase::Active
        };
    }

    /// Advance non-transfer phases by `dt`. Returns true if the flow
    /// just became Active or Idle (i.e. a phase timer expired).
    pub fn tick_phase(&mut self, dt: f64) -> bool {
        match &mut self.phase {
            FlowPhase::Setup { remaining_s } => {
                *remaining_s -= dt;
                if *remaining_s <= 0.0 {
                    self.phase = FlowPhase::Idle;
                    true
                } else {
                    false
                }
            }
            FlowPhase::FirstByte { remaining_s } => {
                *remaining_s -= dt;
                if *remaining_s <= 0.0 {
                    self.phase = FlowPhase::Active;
                    true
                } else {
                    false
                }
            }
            _ => false,
        }
    }

    /// This step's demand (Mbps) given the server cap and decay.
    pub fn demand_mbps(&self, per_conn_cap: f64, decay_factor: f64) -> f64 {
        debug_assert!(self.is_active());
        per_conn_cap * self.ramp * self.jitter * decay_factor
    }

    /// Deliver `bytes` over `dt` seconds; grows the ramp, ages the
    /// request, completes it when the byte count reaches zero.
    /// Returns `true` when the current request finished this step.
    pub fn deliver(&mut self, bytes: f64, dt: f64) -> bool {
        debug_assert!(self.is_active());
        self.delivered_bytes += bytes;
        self.request_delivered += bytes;
        self.request_remaining -= bytes;
        self.request_age_s += dt;
        // Exponential approach to full rate.
        self.ramp = 1.0 - (1.0 - self.ramp) * (-dt / RAMP_TAU_S).exp();
        if self.request_remaining <= 0.5 {
            // Sub-byte residue is rounding noise.
            self.request_remaining = 0.0;
            self.phase = FlowPhase::Idle;
            true
        } else {
            false
        }
    }

    pub fn close(&mut self) {
        self.phase = FlowPhase::Closed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_flow() -> SimFlow {
        let mut rng = Prng::new(1);
        SimFlow::new(FlowId(0), 0.2, 0.0, &mut rng)
    }

    #[test]
    fn setup_counts_down_to_idle() {
        let mut f = mk_flow();
        assert!(matches!(f.phase, FlowPhase::Setup { .. }));
        assert!(!f.tick_phase(0.1));
        assert!(f.tick_phase(0.15));
        assert!(f.is_idle());
    }

    #[test]
    fn zero_setup_starts_idle() {
        let mut rng = Prng::new(2);
        let f = SimFlow::new(FlowId(1), 0.0, 0.0, &mut rng);
        assert!(f.is_idle());
    }

    #[test]
    fn request_lifecycle() {
        let mut f = mk_flow();
        f.tick_phase(1.0);
        f.begin_request(1000.0, 0.1);
        assert!(matches!(f.phase, FlowPhase::FirstByte { .. }));
        assert!(f.tick_phase(0.2));
        assert!(f.is_active());
        // Deliver in two steps.
        assert!(!f.deliver(600.0, 0.05));
        assert!(f.deliver(400.0, 0.05));
        assert!(f.is_idle());
        assert_eq!(f.delivered_bytes, 1000.0);
    }

    #[test]
    fn ramp_grows_toward_one() {
        let mut f = mk_flow();
        f.tick_phase(1.0);
        f.begin_request(1e12, 0.0);
        let d0 = f.demand_mbps(100.0, 1.0);
        for _ in 0..100 {
            f.deliver(1000.0, 0.1);
        }
        let d1 = f.demand_mbps(100.0, 1.0);
        assert!(d0 < d1);
        assert!((d1 - 100.0).abs() < 1.0, "ramp should saturate: {d1}");
    }

    #[test]
    fn abort_request_returns_to_idle_and_is_reusable() {
        let mut f = mk_flow();
        f.tick_phase(1.0);
        f.begin_request(1000.0, 0.1);
        f.reject_pending = true;
        assert!(f.is_busy());
        f.abort_request();
        assert!(f.is_idle());
        assert!(!f.reject_pending);
        assert_eq!(f.delivered_bytes, 0.0);
        f.begin_request(500.0, 0.0);
        assert!(f.is_active());
    }

    #[test]
    #[should_panic(expected = "begin_request on non-idle")]
    fn begin_request_requires_idle() {
        let mut f = mk_flow();
        f.begin_request(10.0, 0.0); // still in Setup
    }
}
