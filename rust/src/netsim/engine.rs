//! The discrete-time simulation engine.
//!
//! `NetSim` owns the virtual clock, the bottleneck link, the background
//! OU process, and the flow table. The coordinator's simulated session
//! driver calls [`NetSim::step`] in a loop; each call advances virtual
//! time by `dt`, water-fills available bandwidth across active flows
//! (after server caps, ramps, decay, and client-side efficiency), moves
//! bytes, and reports per-flow deliveries and request completions.
//!
//! Determinism: all randomness flows from the seed passed at
//! construction; two engines built with identical configs and seeds
//! produce bit-identical histories. The experiment harness exploits
//! this for the paper's 5-run round-robin (seeds `base..base+5`).

use crate::netsim::client::ClientProfile;
use crate::netsim::fault::{FaultKind, FaultSchedule};
use crate::netsim::flow::{FlowId, FlowPhase, PendingRequest, SimFlow};
use crate::netsim::link::Link;
use crate::netsim::server::ServerProfile;
use crate::netsim::traffic::OuProcess;
use crate::util::prng::Prng;
use crate::{Error, Result};

/// Full engine configuration (one per scenario; see
/// `experiments::scenario` for the paper-calibrated profiles).
#[derive(Clone, Debug)]
pub struct NetSimConfig {
    /// Bottleneck capacity (Mbps).
    pub link_capacity_mbps: f64,
    /// Background traffic process.
    pub background: BackgroundConfig,
    /// Server behaviour.
    pub server: ServerProfile,
    /// Client behaviour.
    pub client: ClientProfile,
    /// Per-flow multiplicative rate jitter (std fraction, e.g. 0.05).
    pub flow_jitter_frac: f64,
    /// Connection-failure injection: expected failures per flow-minute
    /// of active transfer (0 disables). Models mid-transfer resets on
    /// flaky WAN paths; the coordinator must requeue and reconnect.
    pub flow_failure_rate_per_min: f64,
    /// Scheduled fault injection (resets, stalls, 5xx windows, rate
    /// collapses, flash crowds, brownouts). Empty = benign network.
    pub faults: FaultSchedule,
    /// Simulation step (s). 0.05 is the calibrated default: fine enough
    /// to resolve 180 ms connection setups, coarse enough to replay a
    /// 500-second transfer in ~10k steps.
    pub dt_s: f64,
}

/// OU background parameters (serializable subset of [`OuProcess`]).
#[derive(Clone, Debug)]
pub struct BackgroundConfig {
    pub mean_mbps: f64,
    pub theta: f64,
    pub sigma: f64,
    pub max_mbps: f64,
}

impl BackgroundConfig {
    /// No background traffic at all.
    pub fn none() -> Self {
        BackgroundConfig {
            mean_mbps: 0.0,
            theta: 0.0,
            sigma: 0.0,
            max_mbps: 0.0,
        }
    }
}

impl Default for NetSimConfig {
    fn default() -> Self {
        NetSimConfig {
            link_capacity_mbps: 2_000.0,
            background: BackgroundConfig {
                mean_mbps: 400.0,
                theta: 0.25,
                sigma: 120.0,
                max_mbps: 1_500.0,
            },
            server: ServerProfile::default(),
            client: ClientProfile::default(),
            flow_jitter_frac: 0.05,
            flow_failure_rate_per_min: 0.0,
            faults: FaultSchedule::none(),
            dt_s: 0.05,
        }
    }
}

impl NetSimConfig {
    /// Validate the whole configuration.
    pub fn validate(&self) -> Result<()> {
        if self.link_capacity_mbps <= 0.0 {
            return Err(Error::Sim("link capacity must be > 0".into()));
        }
        if !(self.dt_s > 0.0 && self.dt_s <= 1.0) {
            return Err(Error::Sim(format!("dt {} out of (0, 1]", self.dt_s)));
        }
        self.server.validate().map_err(Error::Sim)?;
        self.client.validate().map_err(Error::Sim)?;
        self.faults.validate().map_err(Error::Sim)?;
        Ok(())
    }
}

/// What happened to one flow during a step.
#[derive(Clone, Debug)]
pub struct FlowEvent {
    pub id: FlowId,
    /// Payload bytes delivered this step.
    pub bytes: f64,
    /// The in-flight request completed this step.
    pub request_done: bool,
    /// The connection finished its handshake this step (now Idle).
    pub became_ready: bool,
    /// The connection was killed mid-request by failure injection; the
    /// bytes already delivered for the request stand, the rest must be
    /// rescheduled on a new connection.
    pub failed: bool,
    /// The request was rejected by a transient server error (injected
    /// 5xx). The connection survives and is Idle again; the work item
    /// must be retried, ideally after backoff.
    pub rejected: bool,
    /// The request completed but its payload was silently corrupted in
    /// flight ([`FaultKind::BitFlip`]). Only meaningful alongside
    /// `request_done`; transports with verification enabled perturb the
    /// chunk digest so the hash check fails, everything else ignores it
    /// (the bytes count — that is the point of *silent* corruption).
    pub corrupted: bool,
}

/// Aggregate step outcome.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Virtual time after the step (s).
    pub now_s: f64,
    /// Per-flow events (only flows with activity appear).
    pub events: Vec<FlowEvent>,
    /// Total payload bytes delivered this step.
    pub total_bytes: f64,
    /// Instantaneous foreground goodput (Mbps) over this step.
    pub goodput_mbps: f64,
    /// Background traffic level (Mbps) during this step.
    pub background_mbps: f64,
}

/// The simulator.
pub struct NetSim {
    cfg: NetSimConfig,
    link: Link,
    background: OuProcess,
    flows: Vec<SimFlow>,
    now_s: f64,
    next_id: u64,
    rng: Prng,
    /// Count of distinct files currently being written (set by the
    /// session driver via [`NetSim::set_open_files`]; used for the
    /// client's distinct-file penalty).
    open_files: usize,
    /// Max simultaneous open flows per mirror endpoint (0 = unlimited;
    /// set by the session driver via
    /// [`NetSim::set_per_mirror_connection_cap`]). Models per-endpoint
    /// connection limits the way `max_connections` models the global
    /// one.
    per_mirror_conn_cap: usize,
    // --- Fault-injection state (see netsim::fault). ---
    /// Next unapplied event in `cfg.faults`.
    fault_cursor: usize,
    /// Requests issued before this time are rejected with `reject_prob`.
    reject_until_s: f64,
    reject_prob: f64,
    /// Per-connection cap multiplied by `collapse_factor` until then.
    collapse_until_s: f64,
    collapse_factor: f64,
    /// Extra background traffic until then.
    crowd_until_s: f64,
    crowd_extra_mbps: f64,
    /// Server brownout: new connections queue and new requests are
    /// rejected until this time.
    brownout_until_s: f64,
    /// DNS outage ([`FaultKind::DnsOutage`]): connections opened before
    /// this time fail at setup (resolution errors only hit new
    /// connections; established flows are untouched).
    dns_outage_until_s: f64,
    /// Silent corruption window ([`FaultKind::BitFlip`]): until
    /// `bitflip_until_s`, each response delivering bytes draws once and
    /// is marked corrupted with probability `bitflip_frac`.
    bitflip_until_s: f64,
    bitflip_frac: f64,
    /// Windowed mid-body drops ([`FaultKind::MidBodyDrop`]): until
    /// `drop_until_s`, a response crossing `drop_after_bytes` delivered
    /// bytes is reset with probability `drop_frac` at the crossing.
    drop_until_s: f64,
    drop_after_bytes: f64,
    drop_frac: f64,
    /// Correlated burst losses ([`FaultKind::BurstLoss`]): until
    /// `burst_until_s` a Gilbert–Elliott two-state process alternates
    /// loss bursts (`burst_bad`, mean length `burst_burst_s`, busy
    /// flows reset at `burst_kill_prob`/s) and quiet spells (mean
    /// length `burst_gap_s`); `burst_phase_until_s` is the current
    /// phase's end.
    burst_until_s: f64,
    burst_bad: bool,
    burst_phase_until_s: f64,
    burst_kill_prob: f64,
    burst_burst_s: f64,
    burst_gap_s: f64,
    /// Per-mirror asymmetric degradation: flows to mirror `m` have
    /// their per-connection cap multiplied by `mirror_slow[m].1` until
    /// `mirror_slow[m].0` (grown lazily; unlisted mirrors are healthy).
    mirror_slow: Vec<(f64, f64)>,
    /// Flight recorder (session-shared): fault injections are recorded
    /// as they fire, stamped with the simulator's virtual now. `None`
    /// (the default) skips the hook entirely.
    tracer: Option<std::sync::Arc<crate::trace::Tracer>>,
    // §Perf: scratch buffers reused across steps so the hot loop is
    // allocation-free (see EXPERIMENTS.md §Perf, optimization 1).
    scratch_active: Vec<usize>,
    scratch_demands: Vec<f64>,
    scratch_alloc: Vec<f64>,
    scratch_order: Vec<usize>,
}

impl NetSim {
    /// Build an engine from a config and seed.
    pub fn new(cfg: NetSimConfig, seed: u64) -> Result<NetSim> {
        cfg.validate()?;
        let mut rng = Prng::new(seed);
        let bg_rng = rng.fork(0xB6);
        let background = if cfg.background.max_mbps <= 0.0 {
            OuProcess::constant(0.0)
        } else {
            OuProcess::new(
                cfg.background.mean_mbps,
                cfg.background.theta,
                cfg.background.sigma,
                0.0,
                cfg.background.max_mbps,
                bg_rng,
            )
        };
        Ok(NetSim {
            link: Link::new(cfg.link_capacity_mbps),
            background,
            flows: Vec::new(),
            now_s: 0.0,
            next_id: 0,
            rng,
            open_files: 1,
            per_mirror_conn_cap: 0,
            fault_cursor: 0,
            reject_until_s: 0.0,
            reject_prob: 0.0,
            collapse_until_s: 0.0,
            collapse_factor: 1.0,
            crowd_until_s: 0.0,
            crowd_extra_mbps: 0.0,
            brownout_until_s: 0.0,
            dns_outage_until_s: 0.0,
            bitflip_until_s: 0.0,
            bitflip_frac: 0.0,
            drop_until_s: 0.0,
            drop_after_bytes: 0.0,
            drop_frac: 0.0,
            burst_until_s: 0.0,
            burst_bad: false,
            burst_phase_until_s: 0.0,
            burst_kill_prob: 0.0,
            burst_burst_s: 0.0,
            burst_gap_s: 0.0,
            mirror_slow: Vec::new(),
            tracer: None,
            scratch_active: Vec::new(),
            scratch_demands: Vec::new(),
            scratch_alloc: Vec::new(),
            scratch_order: Vec::new(),
            cfg,
        })
    }

    /// Current virtual time (s).
    pub fn now(&self) -> f64 {
        self.now_s
    }

    /// Engine configuration (read-only).
    pub fn config(&self) -> &NetSimConfig {
        &self.cfg
    }

    /// Attach a flight recorder; scheduled fault injections are
    /// recorded as [`crate::trace::TraceEvent::Fault`] when they fire.
    pub fn set_tracer(&mut self, tracer: std::sync::Arc<crate::trace::Tracer>) {
        self.tracer = Some(tracer);
    }

    /// Open a new connection to the primary mirror; returns its id.
    /// The flow spends `server.setup_latency_s` in handshake before
    /// accepting requests.
    pub fn open_flow(&mut self) -> Result<FlowId> {
        self.open_flow_to(0)
    }

    /// Open a new connection terminating at mirror `mirror` (0 =
    /// primary). Per-flow asymmetric faults ([`FaultKind::SlowMirror`])
    /// degrade only the flows bound to the named mirror.
    pub fn open_flow_to(&mut self, mirror: usize) -> Result<FlowId> {
        let open = self.flows.iter().filter(|f| !f.is_closed()).count();
        if open >= self.cfg.server.max_connections {
            return Err(Error::Sim(format!(
                "server connection limit {} reached",
                self.cfg.server.max_connections
            )));
        }
        let mirror_cap = self.per_mirror_conn_cap;
        if mirror_cap > 0 && self.open_flows_to(mirror) >= mirror_cap {
            return Err(Error::Sim(format!(
                "mirror {mirror} connection limit {mirror_cap} reached"
            )));
        }
        let id = FlowId(self.next_id);
        self.next_id += 1;
        // A brownout queues new handshakes behind its remaining span.
        let brownout_wait = (self.brownout_until_s - self.now_s).max(0.0);
        let mut flow = SimFlow::new(
            id,
            self.cfg.server.setup_latency_s + brownout_wait,
            self.cfg.flow_jitter_frac,
            &mut self.rng,
        );
        flow.mirror = mirror;
        if self.now_s < self.dns_outage_until_s {
            // Opened during a resolver outage: the handshake will fail
            // when its setup timer fires.
            flow.fail_on_setup = true;
        }
        self.flows.push(flow);
        Ok(id)
    }

    /// Close a connection (idempotent).
    pub fn close_flow(&mut self, id: FlowId) {
        if let Some(f) = self.flow_mut(id) {
            f.close();
        }
    }

    /// Whether `id` is connected and idle (can accept a request).
    pub fn flow_ready(&self, id: FlowId) -> bool {
        self.flow(id).map(|f| f.is_idle()).unwrap_or(false)
    }

    /// Phase of a flow (diagnostics/tests).
    pub fn flow_phase(&self, id: FlowId) -> Option<FlowPhase> {
        self.flow(id).map(|f| f.phase.clone())
    }

    /// Issue a request for `bytes` on idle flow `id`.
    ///
    /// `cold` requests pay the server's first-byte staging latency;
    /// warm ones (subsequent chunks of the same object) do not.
    /// `tag` is an opaque work-item label echoed back to the caller.
    pub fn begin_request(&mut self, id: FlowId, bytes: f64, cold: bool, tag: u64) -> Result<()> {
        let mut fbl = if cold {
            self.cfg.server.first_byte_latency_s
        } else {
            // Warm chunk on a keep-alive connection: one request RTT,
            // folded into a small constant.
            self.cfg.server.first_byte_latency_s.min(0.02)
        };
        // Injected transient server errors: a request issued during a
        // 5xx window (or brownout) is doomed — it spends a short
        // "error response" latency in FirstByte, then fires a
        // `rejected` event instead of turning Active.
        let reject = self.now_s < self.brownout_until_s
            || (self.now_s < self.reject_until_s && self.rng.next_f64() < self.reject_prob);
        if reject {
            // The error response still costs at least a round trip.
            fbl = fbl.max(0.05);
        }
        let f = self
            .flow_mut(id)
            .ok_or_else(|| Error::Sim(format!("no such flow {id:?}")))?;
        if !f.is_idle() {
            return Err(Error::Sim(format!(
                "begin_request on non-idle flow {id:?} ({:?})",
                f.phase
            )));
        }
        f.tag = tag;
        f.begin_request(bytes, fbl);
        f.reject_pending = reject;
        Ok(())
    }

    /// Issue a request on flow `id`, pipelining it behind the in-flight
    /// one if the flow is busy (HTTP/1.1 request pipelining). On an
    /// idle flow this is exactly [`NetSim::begin_request`]; on a busy
    /// flow the request is queued and promoted FIFO when its
    /// predecessor completes or aborts. A connection that dies drops
    /// its queue silently — the coordinator requeues the unanswered
    /// tail, mirroring the real transport's retry contract.
    pub fn queue_request(&mut self, id: FlowId, bytes: f64, cold: bool, tag: u64) -> Result<()> {
        let busy = self
            .flow(id)
            .map(|f| f.is_busy())
            .ok_or_else(|| Error::Sim(format!("no such flow {id:?}")))?;
        if !busy {
            return self.begin_request(id, bytes, cold, tag);
        }
        assert!(bytes > 0.0, "request must move at least one byte");
        let now = self.now_s;
        let f = self.flow_mut(id).expect("flow checked above");
        f.pending.push_back(PendingRequest {
            bytes,
            cold,
            tag,
            enqueued_s: now,
        });
        Ok(())
    }

    /// Promote the next pipelined request on flow-table index `i`, if
    /// any. The flow must be Idle (its previous request just finished
    /// or aborted). Returns whether a request was promoted.
    ///
    /// A pipelined request hit the wire when it was queued, so the
    /// server has been staging its object while the wire was busy with
    /// the predecessor: only the staging time not already hidden
    /// remains, floored at the warm keep-alive constant (the response
    /// head still costs a request round-trip). This overlap is the
    /// mechanism that makes request trains amortize cold staging in
    /// campaign mode — and it is symmetric with real HTTP/1.1
    /// pipelining, where the server works on queued requests in order.
    fn promote_pending(&mut self, i: usize) -> bool {
        let Some(req) = self.flows[i].pending.pop_front() else {
            return false;
        };
        let fbl_total = if req.cold {
            self.cfg.server.first_byte_latency_s
        } else {
            self.cfg.server.first_byte_latency_s.min(0.02)
        };
        let warm_floor = self.cfg.server.first_byte_latency_s.min(0.02);
        let waited = (self.now_s - req.enqueued_s).max(0.0);
        let mut fbl = (fbl_total - waited).max(warm_floor);
        // The reject draw happens when the response is produced, same
        // as begin_request: a request promoted inside a 5xx window is
        // doomed even if it was queued before the window opened.
        let reject = self.now_s < self.brownout_until_s
            || (self.now_s < self.reject_until_s && self.rng.next_f64() < self.reject_prob);
        if reject {
            fbl = fbl.max(0.05);
        }
        let f = &mut self.flows[i];
        f.tag = req.tag;
        f.begin_request(req.bytes, fbl);
        f.reject_pending = reject;
        true
    }

    /// Tell the engine how many distinct files are currently being
    /// written (drives the client's distinct-file penalty).
    pub fn set_open_files(&mut self, n: usize) {
        self.open_files = n.max(1);
    }

    /// Number of flows currently in Active phase.
    pub fn active_flows(&self) -> usize {
        self.flows.iter().filter(|f| f.is_active()).count()
    }

    /// Number of flows that are open (not closed).
    pub fn open_flows(&self) -> usize {
        self.flows.iter().filter(|f| !f.is_closed()).count()
    }

    /// Number of open flows terminating at mirror `mirror`.
    pub fn open_flows_to(&self, mirror: usize) -> usize {
        self.flows
            .iter()
            .filter(|f| !f.is_closed() && f.mirror == mirror)
            .count()
    }

    /// Cap simultaneous open flows per mirror endpoint (0 = unlimited).
    /// [`NetSim::open_flow_to`] rejects opens beyond the cap, the way
    /// it already rejects opens beyond the server-wide
    /// `max_connections`.
    pub fn set_per_mirror_connection_cap(&mut self, cap: usize) {
        self.per_mirror_conn_cap = cap;
    }

    /// Advance the world by `dt_s` (config default if `None`).
    pub fn step(&mut self, dt_override: Option<f64>) -> StepReport {
        let mut report = StepReport::default();
        self.step_into(dt_override, &mut report);
        report
    }

    /// [`NetSim::step`] into a caller-owned report, reusing its event
    /// buffer — the per-tick path of the simulated session transport,
    /// so a steady-state control tick performs no allocation.
    pub fn step_into(&mut self, dt_override: Option<f64>, report: &mut StepReport) {
        let dt = dt_override.unwrap_or(self.cfg.dt_s);
        debug_assert!(dt > 0.0);
        self.now_s += dt;
        let mut background_mbps = self.background.step(dt);
        if self.now_s < self.crowd_until_s {
            background_mbps += self.crowd_extra_mbps;
        }

        report.events.clear();
        report.now_s = self.now_s;
        report.background_mbps = background_mbps;
        report.total_bytes = 0.0;
        report.goodput_mbps = 0.0;

        // Apply scheduled faults that have come due.
        loop {
            let kind = match self.cfg.faults.events().get(self.fault_cursor) {
                Some(ev) if ev.at_s <= self.now_s => ev.kind.clone(),
                _ => break,
            };
            self.fault_cursor += 1;
            if let Some(tr) = self.tracer.as_deref() {
                tr.record(
                    self.now_s,
                    crate::trace::TraceEvent::Fault { kind: kind.name() },
                );
            }
            self.apply_fault(kind, report);
        }

        // Phase timers (setup / first-byte). A flow whose first-byte
        // timer fires with a pending injected rejection aborts back to
        // Idle and reports `rejected` instead of going Active.
        // (Indexed loop: the rejected path promotes the next pipelined
        // request, which needs `&mut self`.)
        for i in 0..self.flows.len() {
            let f = &mut self.flows[i];
            let fired = f.tick_phase(dt);
            if fired && f.is_active() && f.reject_pending {
                let id = f.id;
                f.abort_request();
                report.events.push(FlowEvent {
                    id,
                    bytes: 0.0,
                    request_done: false,
                    became_ready: false,
                    failed: false,
                    rejected: true,
                    corrupted: false,
                });
                // The rejected head does not take its pipelined
                // successors down with it: promote the next queued
                // request on the surviving connection.
                self.promote_pending(i);
                continue;
            }
            if fired && f.is_idle() && f.fail_on_setup {
                // Opened during a DNS outage: the handshake fails.
                f.close();
                report.events.push(FlowEvent {
                    id: f.id,
                    bytes: 0.0,
                    request_done: false,
                    became_ready: false,
                    failed: true,
                    rejected: false,
                    corrupted: false,
                });
                continue;
            }
            if fired && f.is_idle() {
                report.events.push(FlowEvent {
                    id: f.id,
                    bytes: 0.0,
                    request_done: false,
                    became_ready: true,
                    failed: false,
                    rejected: false,
                    corrupted: false,
                });
            }
        }

        // Demand vector over active flows (scratch-buffer reuse keeps
        // the hot loop allocation-free).
        self.scratch_active.clear();
        self.scratch_demands.clear();
        let mut cap = self.cfg.server.per_conn_cap_mbps;
        if self.now_s < self.collapse_until_s {
            cap *= self.collapse_factor;
        }
        for (i, f) in self.flows.iter().enumerate() {
            if f.is_active() {
                self.scratch_active.push(i);
                let demand = if f.stalled_until_s > self.now_s {
                    0.0 // injected stall: connection alive, no bytes
                } else {
                    // Asymmetric per-mirror degradation on top of any
                    // global rate collapse.
                    let mut cap_f = cap;
                    if let Some(&(until, factor)) = self.mirror_slow.get(f.mirror) {
                        if self.now_s < until {
                            cap_f *= factor;
                        }
                    }
                    f.demand_mbps(cap_f, self.cfg.server.decay_factor(f.request_age_s))
                };
                self.scratch_demands.push(demand);
            }
        }
        if self.scratch_active.is_empty() {
            return;
        }
        let active_idx = &self.scratch_active;
        let demands = &self.scratch_demands;

        // Link water-fill, then client-side efficiency and write cap.
        let available = self.link.available(background_mbps);
        crate::netsim::link::max_min_fair_into(
            available,
            demands,
            &mut self.scratch_alloc,
            &mut self.scratch_order,
        );
        let alloc = &self.scratch_alloc;
        let raw_total: f64 = alloc.iter().sum();
        let eff = self
            .cfg
            .client
            .efficiency(active_idx.len(), self.open_files);
        let capped_total = self.cfg.client.apply_write_cap(raw_total * eff);
        let scale = if raw_total > 0.0 {
            capped_total / raw_total
        } else {
            0.0
        };

        // Deliver bytes. Indexed loop so the scratch buffers (borrowed
        // from self) release before the flow table is mutated.
        report.events.reserve_exact(self.scratch_active.len());
        for k in 0..self.scratch_active.len() {
            let i = self.scratch_active[k];
            let rate = self.scratch_alloc[k];
            let goodput = rate * scale;
            let bytes = goodput * 1e6 / 8.0 * dt;
            if bytes <= 0.0 {
                continue;
            }
            let f = &mut self.flows[i];
            let bytes = bytes.min(f.request_remaining);
            // Silent corruption window: one Bernoulli draw per response
            // per window, made at its first delivery step inside the
            // window. The transfer proceeds — only the digest changes.
            if self.now_s < self.bitflip_until_s && !f.corrupt_checked {
                f.corrupt_checked = true;
                if self.rng.next_f64() < self.bitflip_frac {
                    f.corrupted = true;
                }
            }
            let done = f.deliver(bytes, dt);
            report.total_bytes += bytes;
            report.events.push(FlowEvent {
                id: f.id,
                bytes,
                request_done: done,
                became_ready: false,
                failed: false,
                rejected: false,
                corrupted: done && f.corrupted,
            });
            // Windowed mid-body drop: the response just crossed the
            // drop threshold inside an active window — reset the
            // connection with the configured probability (bytes already
            // delivered stand; the engine requeues the chunk's tail).
            // A completed response escapes (every byte arrived), and
            // the `<=` on the pre-delivery side makes a 0-byte
            // threshold mean "first delivery" instead of never firing.
            if !done
                && self.now_s < self.drop_until_s
                && f.request_delivered >= self.drop_after_bytes
                && f.request_delivered - bytes <= self.drop_after_bytes
                && self.rng.next_f64() < self.drop_frac
            {
                f.close();
                report.events.push(FlowEvent {
                    id: f.id,
                    bytes: 0.0,
                    request_done: false,
                    became_ready: false,
                    failed: true,
                    rejected: false,
                    corrupted: false,
                });
            }
            if done {
                // The head of a pipelined train finished: promote its
                // successor on the spot, crediting the staging time it
                // already spent queued.
                self.promote_pending(i);
            }
        }

        // Correlated burst losses ([`FaultKind::BurstLoss`]): advance
        // the Gilbert–Elliott two-state process and, while the bad
        // state is active, reset busy flows — several in the same
        // burst, which is what distinguishes clustered losses from the
        // independent per-flow hazard below. Checked after delivery so
        // a dying step still accounts its bytes.
        if self.now_s < self.burst_until_s {
            while self.now_s >= self.burst_phase_until_s {
                self.burst_bad = !self.burst_bad;
                let mean = if self.burst_bad {
                    self.burst_burst_s
                } else {
                    self.burst_gap_s
                };
                // Phase lengths are uniform around the mean; the floor
                // keeps a zero-gap config from spinning this loop.
                let mean = mean.max(1e-3);
                self.burst_phase_until_s += self.rng.range_f64(0.5 * mean, 1.5 * mean);
            }
            if self.burst_bad && self.burst_kill_prob > 0.0 {
                let p_kill = (self.burst_kill_prob * dt).min(1.0);
                for f in &mut self.flows {
                    if f.is_busy() && self.rng.next_f64() < p_kill {
                        f.close();
                        report.events.push(FlowEvent {
                            id: f.id,
                            bytes: 0.0,
                            request_done: false,
                            became_ready: false,
                            failed: true,
                            rejected: false,
                            corrupted: false,
                        });
                    }
                }
            }
        }

        // Failure injection: active flows die with the configured
        // per-minute hazard (checked after delivery so a failing step
        // still accounts its bytes, like a real mid-stream reset).
        if self.cfg.flow_failure_rate_per_min > 0.0 {
            let p_fail = self.cfg.flow_failure_rate_per_min * dt / 60.0;
            for f in &mut self.flows {
                if f.is_active() && self.rng.next_f64() < p_fail {
                    f.close();
                    report.events.push(FlowEvent {
                        id: f.id,
                        bytes: 0.0,
                        request_done: false,
                        became_ready: false,
                        failed: true,
                        rejected: false,
                        corrupted: false,
                    });
                }
            }
        }
        report.goodput_mbps = report.total_bytes * 8.0 / 1e6 / dt;
    }

    /// Apply one scheduled fault at the current virtual time.
    fn apply_fault(&mut self, kind: FaultKind, report: &mut StepReport) {
        match kind {
            FaultKind::ConnectionReset { count } => {
                for _ in 0..count {
                    let busy: Vec<usize> = self
                        .flows
                        .iter()
                        .enumerate()
                        .filter(|(_, f)| f.is_busy())
                        .map(|(i, _)| i)
                        .collect();
                    if busy.is_empty() {
                        break;
                    }
                    let victim = busy[self.rng.below(busy.len() as u64) as usize];
                    let f = &mut self.flows[victim];
                    f.close();
                    report.events.push(FlowEvent {
                        id: f.id,
                        bytes: 0.0,
                        request_done: false,
                        became_ready: false,
                        failed: true,
                        rejected: false,
                        corrupted: false,
                    });
                }
            }
            FaultKind::Stall { frac, duration_s } => {
                let until = self.now_s + duration_s;
                for f in &mut self.flows {
                    if f.is_active() && self.rng.next_f64() < frac {
                        f.stalled_until_s = f.stalled_until_s.max(until);
                    }
                }
            }
            // Overlapping same-kind windows compose to the worst case:
            // the end times merge with max(), and the parameter keeps
            // the more severe value while a prior window is still
            // active (otherwise a mild late event would soften the
            // tail of an earlier severe one).
            FaultKind::ServerError {
                reject_prob,
                duration_s,
            } => {
                self.reject_prob = if self.now_s < self.reject_until_s {
                    self.reject_prob.max(reject_prob)
                } else {
                    reject_prob
                };
                self.reject_until_s = self.reject_until_s.max(self.now_s + duration_s);
            }
            FaultKind::RateCollapse { factor, duration_s } => {
                self.collapse_factor = if self.now_s < self.collapse_until_s {
                    self.collapse_factor.min(factor)
                } else {
                    factor
                };
                self.collapse_until_s = self.collapse_until_s.max(self.now_s + duration_s);
            }
            FaultKind::FlashCrowd {
                extra_mbps,
                duration_s,
            } => {
                self.crowd_extra_mbps = if self.now_s < self.crowd_until_s {
                    self.crowd_extra_mbps.max(extra_mbps)
                } else {
                    extra_mbps
                };
                self.crowd_until_s = self.crowd_until_s.max(self.now_s + duration_s);
            }
            FaultKind::Brownout { duration_s } => {
                self.brownout_until_s = self.brownout_until_s.max(self.now_s + duration_s);
            }
            FaultKind::SlowMirror {
                mirror,
                factor,
                duration_s,
            } => {
                if self.mirror_slow.len() <= mirror {
                    self.mirror_slow.resize(mirror + 1, (0.0, 1.0));
                }
                let entry = &mut self.mirror_slow[mirror];
                entry.1 = if self.now_s < entry.0 {
                    entry.1.min(factor)
                } else {
                    factor
                };
                entry.0 = entry.0.max(self.now_s + duration_s);
            }
            FaultKind::BurstLoss {
                burst_s,
                gap_s,
                kill_prob,
                duration_s,
            } => {
                if self.now_s < self.burst_until_s {
                    // Overlapping windows compose to the worst case:
                    // hotter bursts, shorter gaps; the running phase
                    // machine keeps its current phase.
                    self.burst_kill_prob = self.burst_kill_prob.max(kill_prob);
                    self.burst_burst_s = self.burst_burst_s.max(burst_s);
                    self.burst_gap_s = self.burst_gap_s.min(gap_s);
                } else {
                    self.burst_kill_prob = kill_prob;
                    self.burst_burst_s = burst_s;
                    self.burst_gap_s = gap_s;
                    // A burst-loss window opens in a loss burst.
                    self.burst_bad = true;
                    self.burst_phase_until_s =
                        self.now_s + self.rng.range_f64(0.5 * burst_s, 1.5 * burst_s);
                }
                self.burst_until_s = self.burst_until_s.max(self.now_s + duration_s);
            }
            FaultKind::MidBodyDrop {
                after_bytes,
                frac,
                duration_s,
            } => {
                if self.now_s < self.drop_until_s {
                    // Overlapping windows compose to the worst case.
                    self.drop_frac = self.drop_frac.max(frac);
                    self.drop_after_bytes = self.drop_after_bytes.min(after_bytes);
                } else {
                    self.drop_frac = frac;
                    self.drop_after_bytes = after_bytes;
                }
                self.drop_until_s = self.drop_until_s.max(self.now_s + duration_s);
            }
            FaultKind::DnsOutage { duration_s } => {
                self.dns_outage_until_s =
                    self.dns_outage_until_s.max(self.now_s + duration_s);
            }
            FaultKind::BitFlip { frac, duration_s } => {
                self.bitflip_frac = if self.now_s < self.bitflip_until_s {
                    self.bitflip_frac.max(frac)
                } else {
                    frac
                };
                self.bitflip_until_s = self.bitflip_until_s.max(self.now_s + duration_s);
            }
        }
    }

    /// Run until `pred` returns true or `timeout_s` of virtual time
    /// elapses; returns the elapsed time. Convenience for tests.
    pub fn run_until(
        &mut self,
        timeout_s: f64,
        mut pred: impl FnMut(&StepReport) -> bool,
    ) -> f64 {
        let start = self.now_s;
        loop {
            let rep = self.step(None);
            if pred(&rep) || self.now_s - start >= timeout_s {
                return self.now_s - start;
            }
        }
    }

    fn flow(&self, id: FlowId) -> Option<&SimFlow> {
        self.flows.iter().find(|f| f.id == id)
    }

    fn flow_mut(&mut self, id: FlowId) -> Option<&mut SimFlow> {
        self.flows.iter_mut().find(|f| f.id == id)
    }

    /// Total payload bytes delivered by a flow so far.
    pub fn flow_delivered(&self, id: FlowId) -> f64 {
        self.flow(id).map(|f| f.delivered_bytes).unwrap_or(0.0)
    }

    /// Tag of a flow (work-item label set by `begin_request`).
    pub fn flow_tag(&self, id: FlowId) -> Option<u64> {
        self.flow(id).map(|f| f.tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_cfg() -> NetSimConfig {
        NetSimConfig {
            link_capacity_mbps: 1_000.0,
            background: BackgroundConfig::none(),
            server: ServerProfile {
                setup_latency_s: 0.1,
                first_byte_latency_s: 0.0,
                per_conn_cap_mbps: 300.0,
                long_request_decay_per_min: 0.0,
                decay_floor: 1.0,
                max_connections: 16,
            },
            client: ClientProfile::ideal(),
            flow_jitter_frac: 0.0,
            flow_failure_rate_per_min: 0.0,
            faults: FaultSchedule::none(),
            dt_s: 0.05,
        }
    }

    #[test]
    fn single_flow_hits_per_conn_cap() {
        let mut sim = NetSim::new(quiet_cfg(), 1).unwrap();
        let f = sim.open_flow().unwrap();
        while !sim.flow_ready(f) {
            sim.step(None);
        }
        sim.begin_request(f, 1e12, false, 0).unwrap();
        // Let slow start settle, then measure one second.
        for _ in 0..40 {
            sim.step(None);
        }
        let mut bytes = 0.0;
        for _ in 0..20 {
            bytes += sim.step(None).total_bytes;
        }
        let mbps = bytes * 8.0 / 1e6;
        assert!(
            (mbps - 300.0).abs() < 10.0,
            "single flow should sit at cap: {mbps}"
        );
    }

    #[test]
    fn many_flows_saturate_link_not_more() {
        let mut sim = NetSim::new(quiet_cfg(), 2).unwrap();
        let ids: Vec<FlowId> = (0..8).map(|_| sim.open_flow().unwrap()).collect();
        for _ in 0..10 {
            sim.step(None);
        }
        for (i, id) in ids.iter().enumerate() {
            sim.begin_request(*id, 1e12, false, i as u64).unwrap();
        }
        for _ in 0..40 {
            sim.step(None);
        }
        let mut bytes = 0.0;
        for _ in 0..20 {
            bytes += sim.step(None).total_bytes;
        }
        let mbps = bytes * 8.0 / 1e6;
        // 8 × 300 = 2400 demanded, link is 1000.
        assert!(mbps <= 1_010.0, "goodput exceeds link: {mbps}");
        assert!(mbps > 950.0, "link underutilized with 8 flows: {mbps}");
    }

    #[test]
    fn request_completion_reported_once() {
        let mut sim = NetSim::new(quiet_cfg(), 3).unwrap();
        let f = sim.open_flow().unwrap();
        while !sim.flow_ready(f) {
            sim.step(None);
        }
        // 1 MB at ~300 Mbps -> ~0.027 s.
        sim.begin_request(f, 1e6, false, 7).unwrap();
        let mut completions = 0;
        for _ in 0..200 {
            let rep = sim.step(None);
            completions += rep
                .events
                .iter()
                .filter(|e| e.id == f && e.request_done)
                .count();
        }
        assert_eq!(completions, 1);
        assert!((sim.flow_delivered(f) - 1e6).abs() < 1.0);
        assert_eq!(sim.flow_tag(f), Some(7));
    }

    #[test]
    fn connection_limit_enforced() {
        let mut cfg = quiet_cfg();
        cfg.server.max_connections = 2;
        let mut sim = NetSim::new(cfg, 4).unwrap();
        sim.open_flow().unwrap();
        sim.open_flow().unwrap();
        assert!(sim.open_flow().is_err());
        // Closing one frees a slot.
        sim.close_flow(FlowId(0));
        assert!(sim.open_flow().is_ok());
    }

    #[test]
    fn per_mirror_connection_limit_enforced() {
        let mut sim = NetSim::new(quiet_cfg(), 21).unwrap();
        sim.set_per_mirror_connection_cap(2);
        sim.open_flow_to(0).unwrap();
        sim.open_flow_to(0).unwrap();
        assert!(sim.open_flow_to(0).is_err(), "mirror 0 is at its cap");
        // Other mirrors have their own budget.
        let b = sim.open_flow_to(1).unwrap();
        assert_eq!(sim.open_flows_to(0), 2);
        assert_eq!(sim.open_flows_to(1), 1);
        // Closing frees a slot on that mirror only.
        sim.close_flow(b);
        assert!(sim.open_flow_to(0).is_err());
        assert!(sim.open_flow_to(1).is_ok());
    }

    #[test]
    fn byte_conservation() {
        // Total delivered bytes equals sum of per-flow deliveries.
        let mut sim = NetSim::new(quiet_cfg(), 5).unwrap();
        let a = sim.open_flow().unwrap();
        let b = sim.open_flow().unwrap();
        while !(sim.flow_ready(a) && sim.flow_ready(b)) {
            sim.step(None);
        }
        sim.begin_request(a, 5e6, false, 0).unwrap();
        sim.begin_request(b, 3e6, false, 1).unwrap();
        let mut total_from_events = 0.0;
        for _ in 0..2_000 {
            let rep = sim.step(None);
            total_from_events += rep.total_bytes;
            if sim.active_flows() == 0 {
                break;
            }
        }
        let per_flow = sim.flow_delivered(a) + sim.flow_delivered(b);
        assert!((total_from_events - per_flow).abs() < 1.0);
        assert!((per_flow - 8e6).abs() < 1.0);
    }

    #[test]
    fn determinism_same_seed() {
        let run = |seed| {
            let mut sim = NetSim::new(NetSimConfig::default(), seed).unwrap();
            let f = sim.open_flow().unwrap();
            while !sim.flow_ready(f) {
                sim.step(None);
            }
            sim.begin_request(f, 1e9, true, 0).unwrap();
            let mut trace = Vec::new();
            for _ in 0..500 {
                trace.push(sim.step(None).total_bytes);
            }
            trace
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    use crate::netsim::fault::FaultEvent;

    fn faulted_cfg(events: Vec<FaultEvent>) -> NetSimConfig {
        NetSimConfig {
            faults: FaultSchedule::new(events),
            ..quiet_cfg()
        }
    }

    /// Bring one flow up and start an effectively endless request.
    fn start_big_request(sim: &mut NetSim) -> FlowId {
        let f = sim.open_flow().unwrap();
        while !sim.flow_ready(f) {
            sim.step(None);
        }
        sim.begin_request(f, 1e12, false, 0).unwrap();
        f
    }

    fn measure_mbps(sim: &mut NetSim, steps: usize) -> f64 {
        let mut bytes = 0.0;
        for _ in 0..steps {
            bytes += sim.step(None).total_bytes;
        }
        bytes * 8.0 / 1e6 / (steps as f64 * 0.05)
    }

    #[test]
    fn scheduled_reset_kills_busy_flow() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 2.0,
            kind: FaultKind::ConnectionReset { count: 1 },
        }]);
        let mut sim = NetSim::new(cfg, 7).unwrap();
        let f = start_big_request(&mut sim);
        let mut failed = 0;
        while sim.now() < 4.0 {
            let rep = sim.step(None);
            failed += rep.events.iter().filter(|e| e.failed).count();
        }
        assert_eq!(failed, 1);
        assert_eq!(sim.flow_phase(f), Some(FlowPhase::Closed));
        assert!(sim.flow_delivered(f) > 0.0, "bytes before the reset stand");
    }

    #[test]
    fn server_error_window_rejects_new_requests() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 0.5,
            kind: FaultKind::ServerError {
                reject_prob: 1.0,
                duration_s: 10.0,
            },
        }]);
        let mut sim = NetSim::new(cfg, 8).unwrap();
        let f = sim.open_flow().unwrap();
        while sim.now() < 1.0 {
            sim.step(None);
        }
        assert!(sim.flow_ready(f));
        sim.begin_request(f, 1e6, false, 3).unwrap();
        let mut rejected = 0;
        for _ in 0..40 {
            let rep = sim.step(None);
            rejected += rep.events.iter().filter(|e| e.rejected).count();
        }
        assert_eq!(rejected, 1, "request in 5xx window must be rejected");
        assert!(sim.flow_ready(f), "connection survives a 5xx");
        assert_eq!(sim.flow_delivered(f), 0.0);
    }

    #[test]
    fn rate_collapse_throttles_goodput() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 5.0,
            kind: FaultKind::RateCollapse {
                factor: 0.2,
                duration_s: 5.0,
            },
        }]);
        let mut sim = NetSim::new(cfg, 9).unwrap();
        start_big_request(&mut sim);
        for _ in 0..40 {
            sim.step(None); // ramp
        }
        let before = measure_mbps(&mut sim, 40); // t ≈ 2..4
        while sim.now() < 6.0 {
            sim.step(None);
        }
        let during = measure_mbps(&mut sim, 40); // t ≈ 6..8
        while sim.now() < 11.0 {
            sim.step(None);
        }
        let after = measure_mbps(&mut sim, 40); // t ≈ 11..13
        assert!(
            during < before * 0.35,
            "collapse should throttle: before {before} during {during}"
        );
        assert!(
            after > before * 0.8,
            "rate should recover: before {before} after {after}"
        );
    }

    #[test]
    fn flash_crowd_steals_link_capacity() {
        let mut cfg = faulted_cfg(vec![FaultEvent {
            at_s: 5.0,
            kind: FaultKind::FlashCrowd {
                extra_mbps: 900.0,
                duration_s: 5.0,
            },
        }]);
        // Let one flow demand the whole link so background matters.
        cfg.server.per_conn_cap_mbps = 1_000.0;
        let mut sim = NetSim::new(cfg, 10).unwrap();
        start_big_request(&mut sim);
        for _ in 0..40 {
            sim.step(None);
        }
        let before = measure_mbps(&mut sim, 40);
        while sim.now() < 6.0 {
            sim.step(None);
        }
        let during = measure_mbps(&mut sim, 40);
        assert!(
            during < before * 0.3,
            "crowd should squeeze goodput: before {before} during {during}"
        );
    }

    #[test]
    fn stall_freezes_delivery_then_releases() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 5.0,
            kind: FaultKind::Stall {
                frac: 1.0,
                duration_s: 2.0,
            },
        }]);
        let mut sim = NetSim::new(cfg, 11).unwrap();
        start_big_request(&mut sim);
        while sim.now() < 5.5 {
            sim.step(None);
        }
        let stalled = measure_mbps(&mut sim, 20); // t ≈ 5.5..6.5
        while sim.now() < 8.0 {
            sim.step(None);
        }
        let resumed = measure_mbps(&mut sim, 20);
        assert_eq!(stalled, 0.0, "stalled flow must deliver nothing");
        assert!(resumed > 100.0, "flow must resume after the stall");
    }

    #[test]
    fn brownout_queues_new_connections_and_rejects_requests() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 1.0,
            kind: FaultKind::Brownout { duration_s: 3.0 },
        }]);
        let mut sim = NetSim::new(cfg, 12).unwrap();
        while sim.now() < 1.5 {
            sim.step(None);
        }
        // Opened mid-brownout: handshake waits out the brownout.
        let f = sim.open_flow().unwrap();
        let mut steps = 0;
        while !sim.flow_ready(f) {
            sim.step(None);
            steps += 1;
            assert!(steps < 2_000, "flow never became ready");
        }
        assert!(
            sim.now() >= 4.0,
            "brownout should delay readiness to ~4.1s, got {}",
            sim.now()
        );
        // Requests during a brownout are rejected; afterwards they work.
        sim.begin_request(f, 1e6, false, 0).unwrap();
        let mut done = 0;
        for _ in 0..200 {
            done += sim
                .step(None)
                .events
                .iter()
                .filter(|e| e.request_done)
                .count();
        }
        assert_eq!(done, 1, "post-brownout request should complete");
    }

    #[test]
    fn slow_mirror_degrades_only_its_own_flows() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 2.0,
            kind: FaultKind::SlowMirror {
                mirror: 0,
                factor: 0.1,
                duration_s: 1_000.0,
            },
        }]);
        let mut sim = NetSim::new(cfg, 13).unwrap();
        let a = sim.open_flow_to(0).unwrap();
        let b = sim.open_flow_to(1).unwrap();
        while !(sim.flow_ready(a) && sim.flow_ready(b)) {
            sim.step(None);
        }
        sim.begin_request(a, 1e12, false, 0).unwrap();
        sim.begin_request(b, 1e12, false, 1).unwrap();
        // Past the fault onset and the slow-start ramp.
        while sim.now() < 6.0 {
            sim.step(None);
        }
        let a0 = sim.flow_delivered(a);
        let b0 = sim.flow_delivered(b);
        for _ in 0..40 {
            sim.step(None); // two seconds
        }
        let a_mbps = (sim.flow_delivered(a) - a0) * 8.0 / 1e6 / 2.0;
        let b_mbps = (sim.flow_delivered(b) - b0) * 8.0 / 1e6 / 2.0;
        assert!(
            a_mbps < 300.0 * 0.15,
            "mirror-0 flow should crawl: {a_mbps}"
        );
        assert!(
            b_mbps > 250.0,
            "mirror-1 flow should stay at cap: {b_mbps}"
        );
    }

    #[test]
    fn mid_body_drop_resets_responses_crossing_in_window_only() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 1.0,
            kind: FaultKind::MidBodyDrop {
                after_bytes: 1e6,
                frac: 1.0,
                duration_s: 4.0,
            },
        }]);
        let mut sim = NetSim::new(cfg, 14).unwrap();
        let f = sim.open_flow().unwrap();
        while !sim.flow_ready(f) {
            sim.step(None);
        }
        // Issue inside the window: the response crosses 1 MB in-window
        // and must be reset at the crossing.
        while sim.now() < 1.5 {
            sim.step(None);
        }
        sim.begin_request(f, 1e12, false, 0).unwrap();
        let mut failed = 0;
        while sim.now() < 5.0 {
            failed += sim.step(None).events.iter().filter(|e| e.failed).count();
        }
        assert_eq!(failed, 1, "in-window crossing must reset exactly once");
        assert_eq!(sim.flow_phase(f), Some(FlowPhase::Closed));
        assert!(
            sim.flow_delivered(f) >= 1e6,
            "bytes delivered before the drop stand: {}",
            sim.flow_delivered(f)
        );
        // Past the window the same pattern survives untouched.
        let g = sim.open_flow().unwrap();
        while !sim.flow_ready(g) {
            sim.step(None);
        }
        sim.begin_request(g, 5e6, false, 1).unwrap();
        let (mut failed, mut done) = (0, 0);
        for _ in 0..2_000 {
            let rep = sim.step(None);
            failed += rep.events.iter().filter(|e| e.failed).count();
            done += rep.events.iter().filter(|e| e.request_done).count();
            if done > 0 {
                break;
            }
        }
        assert_eq!(failed, 0, "drop window must not outlive its duration");
        assert_eq!(done, 1);
    }

    #[test]
    fn bitflip_corrupts_in_window_responses_silently() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 1.0,
            kind: FaultKind::BitFlip {
                frac: 1.0,
                duration_s: 4.0,
            },
        }]);
        let mut sim = NetSim::new(cfg, 17).unwrap();
        let f = sim.open_flow().unwrap();
        while !sim.flow_ready(f) {
            sim.step(None);
        }
        // Delivered inside the window: completes normally (silent!) but
        // is flagged corrupted on its completion event.
        while sim.now() < 1.5 {
            sim.step(None);
        }
        sim.begin_request(f, 1e6, false, 0).unwrap();
        let (mut done, mut corrupt, mut failed) = (0, 0, 0);
        for _ in 0..200 {
            let rep = sim.step(None);
            for e in &rep.events {
                done += e.request_done as usize;
                corrupt += e.corrupted as usize;
                failed += e.failed as usize;
            }
            if done > 0 {
                break;
            }
        }
        assert_eq!(done, 1, "corruption must not block completion");
        assert_eq!(corrupt, 1, "in-window response must be flagged corrupted");
        assert_eq!(failed, 0, "bit flips are silent: no connection failure");
        assert!((sim.flow_delivered(f) - 1e6).abs() < 1.0, "every byte arrives");
        // Past the window the same request pattern is clean.
        while sim.now() < 6.0 {
            sim.step(None);
        }
        sim.begin_request(f, 1e6, false, 1).unwrap();
        let (mut done, mut corrupt) = (0, 0);
        for _ in 0..200 {
            let rep = sim.step(None);
            for e in &rep.events {
                done += e.request_done as usize;
                corrupt += e.corrupted as usize;
            }
            if done > 0 {
                break;
            }
        }
        assert_eq!(done, 1);
        assert_eq!(corrupt, 0, "corruption window must not outlive its duration");
    }

    #[test]
    fn burst_loss_clusters_resets_inside_its_window_only() {
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 2.0,
            kind: FaultKind::BurstLoss {
                burst_s: 5.0,
                gap_s: 0.0,
                kill_prob: 1.0,
                duration_s: 10.0,
            },
        }]);
        let mut sim = NetSim::new(cfg, 16).unwrap();
        let ids: Vec<FlowId> = (0..3).map(|_| sim.open_flow().unwrap()).collect();
        while ids.iter().any(|&id| !sim.flow_ready(id)) {
            sim.step(None);
        }
        for (i, id) in ids.iter().enumerate() {
            sim.begin_request(*id, 1e12, false, i as u64).unwrap();
        }
        let mut fail_times = Vec::new();
        while sim.now() < 15.0 {
            let rep = sim.step(None);
            let t = rep.now_s;
            fail_times.extend(rep.events.iter().filter(|e| e.failed).map(|_| t));
        }
        assert!(
            fail_times.iter().all(|&t| (2.0..=12.1).contains(&t)),
            "resets outside the burst window: {fail_times:?}"
        );
        assert!(
            fail_times.len() >= 2,
            "a 10 s always-bad window should cluster several resets: {fail_times:?}"
        );
        // Past the window: a fresh flow completes untouched.
        let g = sim.open_flow().unwrap();
        while !sim.flow_ready(g) {
            sim.step(None);
        }
        sim.begin_request(g, 3e6, false, 9).unwrap();
        let (mut failed, mut done) = (0, 0);
        for _ in 0..2_000 {
            let rep = sim.step(None);
            failed += rep.events.iter().filter(|e| e.failed).count();
            done += rep.events.iter().filter(|e| e.request_done).count();
            if done > 0 {
                break;
            }
        }
        assert_eq!(failed, 0, "burst window must not outlive its duration");
        assert_eq!(done, 1);
    }

    #[test]
    fn step_into_reuses_the_report_buffer() {
        let mut sim = NetSim::new(quiet_cfg(), 15).unwrap();
        start_big_request(&mut sim);
        let mut report = StepReport::default();
        sim.step_into(None, &mut report);
        let first = report.events.capacity();
        let mut max_cap = first;
        for _ in 0..200 {
            sim.step_into(None, &mut report);
            max_cap = max_cap.max(report.events.capacity());
            assert!(report.now_s > 0.0);
        }
        // One active flow: the buffer settles after the first growth and
        // is never reallocated again.
        assert!(max_cap <= first.max(2), "event buffer kept growing: {max_cap}");
    }

    #[test]
    fn fault_schedule_preserves_determinism() {
        let run = |seed| {
            let cfg = NetSimConfig {
                faults: crate::netsim::fault::FaultProfile::Chaos.schedule(seed, 60.0, 1_000.0),
                ..quiet_cfg()
            };
            let mut sim = NetSim::new(cfg, seed).unwrap();
            start_big_request(&mut sim);
            let mut trace = Vec::new();
            for _ in 0..1_000 {
                let rep = sim.step(None);
                trace.push((rep.total_bytes, rep.events.len()));
            }
            trace
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn pipelined_requests_overlap_staging_latency() {
        let mut cfg = quiet_cfg();
        cfg.server.first_byte_latency_s = 4.0;
        let mut sim = NetSim::new(cfg, 18).unwrap();
        let f = sim.open_flow().unwrap();
        while !sim.flow_ready(f) {
            sim.step(None);
        }
        // Two cold 1 MB objects: head begun, successor pipelined.
        sim.queue_request(f, 1e6, true, 0).unwrap(); // idle → begins
        sim.queue_request(f, 1e6, true, 1).unwrap(); // busy → queued
        let start = sim.now();
        let mut done = 0;
        while done < 2 && sim.now() < 60.0 {
            done += sim
                .step(None)
                .events
                .iter()
                .filter(|e| e.request_done)
                .count();
        }
        assert_eq!(done, 2, "both pipelined requests must complete");
        let elapsed = sim.now() - start;
        // The server staged object 2 while object 1 transferred: total
        // is ~one staging latency + two short transfers, not two
        // latencies (~8 s sequential).
        assert!(
            elapsed < 6.0,
            "pipelining must overlap staging: {elapsed}"
        );
        assert!((sim.flow_delivered(f) - 2e6).abs() < 1.0);
    }

    #[test]
    fn queue_request_on_idle_flow_is_begin_request() {
        let mut sim = NetSim::new(quiet_cfg(), 19).unwrap();
        let f = sim.open_flow().unwrap();
        while !sim.flow_ready(f) {
            sim.step(None);
        }
        sim.queue_request(f, 1e6, false, 5).unwrap();
        assert_eq!(sim.flow_tag(f), Some(5));
        let mut done = 0;
        for _ in 0..200 {
            done += sim
                .step(None)
                .events
                .iter()
                .filter(|e| e.request_done)
                .count();
            if done > 0 {
                break;
            }
        }
        assert_eq!(done, 1);
    }

    #[test]
    fn rejected_head_promotes_its_pipelined_successor() {
        // 5xx window covers the head request's issue time only (it
        // closes before the error response lands): the head rejects,
        // the queued successor is promoted outside the window on the
        // surviving connection and completes.
        let cfg = faulted_cfg(vec![FaultEvent {
            at_s: 0.5,
            kind: FaultKind::ServerError {
                reject_prob: 1.0,
                duration_s: 0.52,
            },
        }]);
        let mut sim = NetSim::new(cfg, 20).unwrap();
        let f = sim.open_flow().unwrap();
        while sim.now() < 1.0 {
            sim.step(None);
        }
        assert!(sim.flow_ready(f));
        sim.queue_request(f, 1e6, false, 0).unwrap(); // in-window: doomed
        sim.queue_request(f, 1e6, false, 1).unwrap(); // queued behind it
        let (mut rejected, mut done) = (0, 0);
        for _ in 0..400 {
            let rep = sim.step(None);
            rejected += rep.events.iter().filter(|e| e.rejected).count();
            done += rep.events.iter().filter(|e| e.request_done).count();
            if done > 0 {
                break;
            }
        }
        assert_eq!(rejected, 1, "head must be rejected");
        assert_eq!(done, 1, "successor must be promoted and complete");
        assert_eq!(sim.flow_tag(f), Some(1));
    }

    #[test]
    fn pipelining_preserves_determinism() {
        let run = |seed| {
            let mut cfg = quiet_cfg();
            cfg.server.first_byte_latency_s = 1.0;
            let mut sim = NetSim::new(cfg, seed).unwrap();
            let f = sim.open_flow().unwrap();
            while !sim.flow_ready(f) {
                sim.step(None);
            }
            for t in 0..4 {
                sim.queue_request(f, 5e5, true, t).unwrap();
            }
            let mut trace = Vec::new();
            for _ in 0..500 {
                let rep = sim.step(None);
                trace.push((rep.total_bytes, rep.events.len()));
            }
            trace
        };
        assert_eq!(run(23), run(23));
        assert_ne!(run(23), run(24));
    }

    #[test]
    fn decay_slows_long_requests() {
        let mut cfg = quiet_cfg();
        cfg.server.long_request_decay_per_min = 0.8;
        cfg.server.decay_floor = 0.3;
        let mut sim = NetSim::new(cfg, 6).unwrap();
        let f = sim.open_flow().unwrap();
        while !sim.flow_ready(f) {
            sim.step(None);
        }
        sim.begin_request(f, 1e12, false, 0).unwrap();
        // Rate in the first 5 seconds (after ramp) vs around minute 2.
        for _ in 0..40 {
            sim.step(None);
        }
        let mut early = 0.0;
        for _ in 0..60 {
            early += sim.step(None).total_bytes;
        }
        for _ in 0..(115.0 / 0.05) as usize {
            sim.step(None);
        }
        let mut late = 0.0;
        for _ in 0..60 {
            late += sim.step(None).total_bytes;
        }
        assert!(
            late < early * 0.5,
            "long request should decay: early {early} late {late}"
        );
    }
}
