//! Client-side behaviour model (the researcher's machine — a 12 GB-RAM
//! Colab VM in §5.1, a well-provisioned FABRIC host in §5.2).
//!
//! The paper's utility function exists because concurrency is not free
//! *on the client*: every extra stream costs CPU (TLS decryption,
//! buffer copies), memory, and — on weak machines — disk contention
//! from interleaved writes. These effects are why pysradb's fixed 8
//! streams *lose* to FastBioDL's adaptive ≈3.4–4.9 on Colab (Table 3)
//! even though 8 > 4: raw network share grows with streams, effective
//! goodput does not.
//!
//! Three knobs model this:
//!
//! * `stream_overhead`: a multiplicative efficiency `1/(1 + α·max(0,
//!   N−N₀)²)` applied to aggregate goodput when `N` streams are active.
//!   `N₀` is the free-concurrency knee (how many streams the client
//!   handles without measurable cost), `α` the quadratic penalty.
//! * `write_cap_mbps`: aggregate sink-side ceiling (disk/page-cache
//!   writeback). Dominant for the HiFi-WGS workload (six 9.5 GB files).
//! * `file_overhead`: efficiency loss `1/(1 + β·max(0, F−F₀)²)` when
//!   `F` distinct *files* are written concurrently (seek-heavy
//!   interleaved writeback past the page-cache knee `F₀`). Chunked
//!   few-files-at-a-time schedules (FastBioDL) stay below the knee;
//!   per-file parallelism over huge files (pysradb on HiFi-WGS: six
//!   9.5 GB files against 12 GB RAM) pays it quadratically — which is
//!   how 8 nominal threads end up *slower* than prefetch's 3 on that
//!   dataset while still being faster on the cache-friendly
//!   Breast-RNA-seq files.

/// Immutable per-scenario client parameters.
#[derive(Clone, Debug)]
pub struct ClientProfile {
    /// Free-concurrency knee N₀ (streams with no measurable overhead).
    pub stream_overhead_n0: f64,
    /// Quadratic stream-overhead coefficient α.
    pub stream_overhead_alpha: f64,
    /// Aggregate write ceiling (Mbps); 0 disables.
    pub write_cap_mbps: f64,
    /// Free-concurrent-files knee F₀ (files writable without thrash).
    pub file_overhead_n0: f64,
    /// Quadratic concurrent-file overhead coefficient β (0 disables).
    pub file_overhead_beta: f64,
    /// Floor for the combined client efficiency factor.
    pub efficiency_floor: f64,
}

impl Default for ClientProfile {
    fn default() -> Self {
        ClientProfile {
            stream_overhead_n0: 6.0,
            stream_overhead_alpha: 0.004,
            write_cap_mbps: 0.0,
            file_overhead_n0: 3.0,
            file_overhead_beta: 0.0,
            efficiency_floor: 0.2,
        }
    }
}

impl ClientProfile {
    /// An ideal client with no overheads (FABRIC hosts: NVMe source and
    /// sink, ConnectX-6 NICs — §5.2 explicitly removes client effects).
    pub fn ideal() -> Self {
        ClientProfile {
            stream_overhead_n0: 64.0,
            stream_overhead_alpha: 0.0,
            write_cap_mbps: 0.0,
            file_overhead_n0: 64.0,
            file_overhead_beta: 0.0,
            efficiency_floor: 1.0,
        }
    }

    /// Combined multiplicative efficiency with `n_streams` active
    /// streams writing `n_files` distinct files.
    pub fn efficiency(&self, n_streams: usize, n_files: usize) -> f64 {
        let n = n_streams as f64;
        let over_n = (n - self.stream_overhead_n0).max(0.0);
        let stream_eff = 1.0 / (1.0 + self.stream_overhead_alpha * over_n * over_n);
        let f = n_files as f64;
        let over_f = (f - self.file_overhead_n0).max(0.0);
        let file_eff = 1.0 / (1.0 + self.file_overhead_beta * over_f * over_f);
        (stream_eff * file_eff).max(self.efficiency_floor)
    }

    /// Apply the aggregate write cap to a total goodput figure (Mbps).
    pub fn apply_write_cap(&self, total_mbps: f64) -> f64 {
        if self.write_cap_mbps > 0.0 {
            total_mbps.min(self.write_cap_mbps)
        } else {
            total_mbps
        }
    }

    /// Validate parameter sanity.
    pub fn validate(&self) -> Result<(), String> {
        if self.stream_overhead_alpha < 0.0 || self.file_overhead_beta < 0.0 {
            return Err("overhead coefficients must be >= 0".into());
        }
        if self.stream_overhead_n0 < 0.0 || self.file_overhead_n0 < 0.0 {
            return Err("overhead knees must be >= 0".into());
        }
        if !(0.0..=1.0).contains(&self.efficiency_floor) {
            return Err("efficiency_floor must be in [0, 1]".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_client_is_free() {
        let c = ClientProfile::ideal();
        assert_eq!(c.efficiency(32, 32), 1.0);
        assert_eq!(c.apply_write_cap(99_999.0), 99_999.0);
    }

    #[test]
    fn efficiency_decreases_with_streams() {
        let c = ClientProfile {
            stream_overhead_n0: 4.0,
            stream_overhead_alpha: 0.05,
            ..Default::default()
        };
        let e4 = c.efficiency(4, 1);
        let e8 = c.efficiency(8, 1);
        let e16 = c.efficiency(16, 1);
        assert_eq!(e4, 1.0);
        assert!(e8 < e4);
        assert!(e16 < e8);
        assert!(e16 >= c.efficiency_floor);
    }

    #[test]
    fn file_overhead_quadratic_past_knee() {
        let c = ClientProfile {
            file_overhead_n0: 3.0,
            file_overhead_beta: 0.115,
            efficiency_floor: 0.1,
            ..Default::default()
        };
        // At or below the knee: free.
        assert_eq!(c.efficiency(3, 3), 1.0);
        // Past it: quadratic — 6 files is the HiFi pysradb regime.
        let e6 = c.efficiency(6, 6);
        assert!((e6 - 1.0 / (1.0 + 0.115 * 9.0)).abs() < 1e-12);
        assert!(e6 < 0.55);
        // 6 files hurt far more than 4.
        assert!(c.efficiency(6, 6) < c.efficiency(4, 4) * 0.75);
    }

    #[test]
    fn write_cap_clamps() {
        let c = ClientProfile {
            write_cap_mbps: 600.0,
            ..Default::default()
        };
        assert_eq!(c.apply_write_cap(1200.0), 600.0);
        assert_eq!(c.apply_write_cap(300.0), 300.0);
    }

    #[test]
    fn validation_rejects_bad_params() {
        let mut c = ClientProfile::default();
        assert!(c.validate().is_ok());
        c.file_overhead_beta = -1.0;
        assert!(c.validate().is_err());
        let c = ClientProfile {
            efficiency_floor: 2.0,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }
}
