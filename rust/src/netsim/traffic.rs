//! Background-traffic model: mean-reverting Ornstein–Uhlenbeck process.
//!
//! The paper's Figure 2 motivates the whole system: real available
//! bandwidth between a client and a public archive fluctuates on
//! second-to-minute timescales because of cross traffic and server
//! load. An OU process is the standard stationary Gauss–Markov model
//! for such a signal — it has a well-defined mean (the long-run
//! background level), reverts toward it (congestion episodes end), and
//! has tunable variance and correlation time.
//!
//! ```text
//!     dB = θ (μ − B) dt + σ √dt · N(0, 1)
//! ```
//!
//! `fig2_volatility` replays exactly this process to regenerate the
//! paper's volatility trace.

use crate::util::prng::Prng;

/// Mean-reverting background-traffic process (Mbps).
#[derive(Clone, Debug)]
pub struct OuProcess {
    /// Long-run mean level μ (Mbps).
    pub mean: f64,
    /// Mean-reversion rate θ (1/s). Correlation time ≈ 1/θ.
    pub theta: f64,
    /// Diffusion σ (Mbps / √s).
    pub sigma: f64,
    /// Hard clamp: the process never leaves `[lo, hi]`.
    pub lo: f64,
    pub hi: f64,
    value: f64,
    rng: Prng,
}

impl OuProcess {
    /// Create the process at its mean.
    pub fn new(mean: f64, theta: f64, sigma: f64, lo: f64, hi: f64, rng: Prng) -> Self {
        assert!(lo <= hi, "OU clamp: lo > hi");
        assert!(theta >= 0.0 && sigma >= 0.0);
        let value = mean.clamp(lo, hi);
        OuProcess {
            mean,
            theta,
            sigma,
            lo,
            hi,
            value,
            rng,
        }
    }

    /// A degenerate constant process (used by scenarios without
    /// background traffic, e.g. the throttled FABRIC profiles).
    pub fn constant(level: f64) -> Self {
        OuProcess::new(level, 0.0, 0.0, level, level, Prng::new(0))
    }

    /// Current level (Mbps).
    pub fn value(&self) -> f64 {
        self.value
    }

    /// Advance by `dt` seconds and return the new level.
    pub fn step(&mut self, dt: f64) -> f64 {
        if self.sigma == 0.0 && self.theta == 0.0 {
            return self.value;
        }
        let noise = self.rng.normal();
        self.value += self.theta * (self.mean - self.value) * dt
            + self.sigma * dt.sqrt() * noise;
        self.value = self.value.clamp(self.lo, self.hi);
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_process_never_moves() {
        let mut p = OuProcess::constant(250.0);
        for _ in 0..100 {
            assert_eq!(p.step(0.1), 250.0);
        }
    }

    #[test]
    fn stays_in_clamp() {
        let mut p = OuProcess::new(400.0, 0.2, 300.0, 0.0, 900.0, Prng::new(3));
        for _ in 0..10_000 {
            let v = p.step(0.05);
            assert!((0.0..=900.0).contains(&v), "escaped clamp: {v}");
        }
    }

    #[test]
    fn long_run_mean_is_respected() {
        let mut p = OuProcess::new(400.0, 0.5, 80.0, 0.0, 800.0, Prng::new(11));
        // Burn in, then average.
        for _ in 0..2_000 {
            p.step(0.05);
        }
        let n = 40_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += p.step(0.05);
        }
        let avg = sum / n as f64;
        assert!(
            (avg - 400.0).abs() < 25.0,
            "long-run mean {avg} too far from 400"
        );
    }

    #[test]
    fn actually_fluctuates() {
        let mut p = OuProcess::new(400.0, 0.5, 80.0, 0.0, 800.0, Prng::new(12));
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for _ in 0..2_000 {
            let v = p.step(0.05);
            lo = lo.min(v);
            hi = hi.max(v);
        }
        assert!(hi - lo > 50.0, "volatility too small: range {}", hi - lo);
    }
}
