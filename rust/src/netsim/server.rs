//! Server-side behaviour model (the simulated NCBI/ENA mirror or the
//! FABRIC FTP server of §5.2).
//!
//! Four phenomena live here, each with a direct real-world counterpart
//! documented in DESIGN.md §2/§6:
//!
//! * **connection setup latency** — TCP + TLS handshakes plus HTTP
//!   session establishment (≈180 ms to a transatlantic archive);
//! * **first-byte latency per request** — public archives stage cold
//!   SRA objects out of archival storage before the first payload byte;
//!   small-file workloads (Amplicon-Digester) are dominated by this;
//! * **per-connection rate cap** — server-side shaping / per-stream TCP
//!   ceiling; this is what makes concurrency useful at all and defines
//!   `C* = link ÷ cap` in the Figure-6 scenarios;
//! * **long-request decay** — throughput of one long-lived HTTP request
//!   degrades with request age (shaper token depletion, storage read-ahead
//!   falling behind). Chunked range requests (FastBioDL) stay young and
//!   avoid it; whole-file requests (prefetch/pysradb on 9.5 GB HiFi
//!   files) ride it to the floor. This reproduces the paper's Figure 1
//!   single-stream underutilization and the HiFi-WGS ordering.

/// Immutable per-scenario server parameters.
#[derive(Clone, Debug)]
pub struct ServerProfile {
    /// TCP+TLS connection establishment time (s).
    pub setup_latency_s: f64,
    /// Per-request time to first byte (s) — cold-object staging.
    pub first_byte_latency_s: f64,
    /// Per-connection steady-state rate ceiling (Mbps).
    pub per_conn_cap_mbps: f64,
    /// Multiplicative throughput decay per minute of *request* age.
    /// 0.0 disables. Effective factor: `max(floor, 1 - decay*age/60)`.
    pub long_request_decay_per_min: f64,
    /// Lower bound of the decay factor.
    pub decay_floor: f64,
    /// Hard cap on simultaneous connections the server accepts
    /// (`open_flow` beyond this parks the flow in a reject/backoff state).
    pub max_connections: usize,
}

impl Default for ServerProfile {
    fn default() -> Self {
        ServerProfile {
            setup_latency_s: 0.18,
            first_byte_latency_s: 0.05,
            per_conn_cap_mbps: 350.0,
            long_request_decay_per_min: 0.0,
            decay_floor: 0.25,
            max_connections: 128,
        }
    }
}

impl ServerProfile {
    /// Throughput factor for a request that has been running `age_s`.
    pub fn decay_factor(&self, age_s: f64) -> f64 {
        if self.long_request_decay_per_min <= 0.0 {
            return 1.0;
        }
        (1.0 - self.long_request_decay_per_min * age_s / 60.0).max(self.decay_floor)
    }

    /// Validate parameter sanity (used by config loading).
    pub fn validate(&self) -> Result<(), String> {
        if self.per_conn_cap_mbps <= 0.0 {
            return Err("per_conn_cap_mbps must be > 0".into());
        }
        if self.setup_latency_s < 0.0 || self.first_byte_latency_s < 0.0 {
            return Err("latencies must be >= 0".into());
        }
        if !(0.0..=1.0).contains(&self.decay_floor) {
            return Err("decay_floor must be in [0, 1]".into());
        }
        if self.max_connections == 0 {
            return Err("max_connections must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_disabled_is_identity() {
        let s = ServerProfile::default();
        assert_eq!(s.decay_factor(0.0), 1.0);
        assert_eq!(s.decay_factor(600.0), 1.0);
    }

    #[test]
    fn decay_hits_floor() {
        let s = ServerProfile {
            long_request_decay_per_min: 0.5,
            decay_floor: 0.3,
            ..Default::default()
        };
        assert!((s.decay_factor(0.0) - 1.0).abs() < 1e-12);
        assert!((s.decay_factor(60.0) - 0.5).abs() < 1e-12);
        assert_eq!(s.decay_factor(600.0), 0.3);
    }

    #[test]
    fn validation_catches_bad_params() {
        let mut s = ServerProfile::default();
        assert!(s.validate().is_ok());
        s.per_conn_cap_mbps = 0.0;
        assert!(s.validate().is_err());
        s = ServerProfile {
            decay_floor: 1.5,
            ..Default::default()
        };
        assert!(s.validate().is_err());
        s = ServerProfile {
            max_connections: 0,
            ..Default::default()
        };
        assert!(s.validate().is_err());
    }
}
