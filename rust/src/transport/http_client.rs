//! Minimal blocking HTTP/1.1 client with persistent connections and
//! range requests — the real-socket worker's data path.
//!
//! Scope: exactly what the download workers need. `GET` with `Range`,
//! status + header parsing, content-length-delimited bodies streamed
//! through a caller callback (which feeds the throughput recorder),
//! keep-alive reuse. No TLS (loopback test server), no chunked
//! transfer-encoding (the server always sends Content-Length), no
//! redirects (the resolver produces final URLs).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::{Error, Result};

/// Parsed response head.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub content_length: u64,
    /// `Content-Range` start byte (for 206 responses).
    pub range_start: Option<u64>,
}

/// A persistent connection to one host.
pub struct HttpConnection {
    host: String,
    port: u16,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Requests issued over this connection (diagnostics).
    pub requests: u64,
}

impl HttpConnection {
    /// Connect to `host:port` (no TLS).
    pub fn connect(host: &str, port: u16, timeout: Duration) -> Result<HttpConnection> {
        let addr = format!("{host}:{port}");
        let sock_addr: std::net::SocketAddr = addr
            .parse()
            .map_err(|e| Error::Transport(format!("bad address {addr}: {e}")))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)
            .map_err(|e| Error::Transport(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        Ok(HttpConnection {
            host: host.to_string(),
            port,
            reader: BufReader::with_capacity(256 * 1024, stream.try_clone()?),
            writer: stream,
            requests: 0,
        })
    }

    /// Parse `http://127.0.0.1:8080/path` into (host, port, path).
    pub fn split_url(url: &str) -> Result<(String, u16, String)> {
        let rest = url
            .strip_prefix("http://")
            .ok_or_else(|| Error::Transport(format!("only http:// URLs supported: {url}")))?;
        let (hostport, path) = match rest.find('/') {
            Some(i) => (&rest[..i], &rest[i..]),
            None => (rest, "/"),
        };
        let (host, port) = match hostport.rsplit_once(':') {
            Some((h, p)) => (
                h.to_string(),
                p.parse::<u16>()
                    .map_err(|_| Error::Transport(format!("bad port in {url}")))?,
            ),
            None => (hostport.to_string(), 80),
        };
        Ok((host, port, path.to_string()))
    }

    /// Issue a GET for `path` with an optional byte range
    /// (`offset..offset+len`), streaming the body in blocks to
    /// `on_block(&bytes)`. Returns the response head.
    pub fn get_range(
        &mut self,
        path: &str,
        range: Option<(u64, u64)>,
        mut on_block: impl FnMut(&[u8]),
    ) -> Result<HttpResponse> {
        let mut req = format!("GET {path} HTTP/1.1\r\nHost: {}:{}\r\n", self.host, self.port);
        if let Some((offset, len)) = range {
            debug_assert!(len > 0);
            req.push_str(&format!(
                "Range: bytes={}-{}\r\n",
                offset,
                offset + len - 1
            ));
        }
        req.push_str("Connection: keep-alive\r\n\r\n");
        self.writer
            .write_all(req.as_bytes())
            .map_err(|e| Error::Transport(format!("send request: {e}")))?;
        self.requests += 1;

        // --- Status line. ---
        let mut line = String::new();
        self.reader
            .read_line(&mut line)
            .map_err(|e| Error::Transport(format!("read status: {e}")))?;
        if line.is_empty() {
            return Err(Error::Transport("server closed connection".into()));
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Transport(format!("bad status line {line:?}")))?;

        // --- Headers. ---
        let mut content_length: Option<u64> = None;
        let mut range_start = None;
        loop {
            let mut h = String::new();
            self.reader
                .read_line(&mut h)
                .map_err(|e| Error::Transport(format!("read header: {e}")))?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                let k = k.trim().to_ascii_lowercase();
                let v = v.trim();
                if k == "content-length" {
                    content_length = v.parse().ok();
                } else if k == "content-range" {
                    // bytes START-END/TOTAL
                    range_start = v
                        .strip_prefix("bytes ")
                        .and_then(|s| s.split('-').next())
                        .and_then(|s| s.parse().ok());
                }
            }
        }
        let content_length = content_length
            .ok_or_else(|| Error::Transport("response without Content-Length".into()))?;

        if !(status == 200 || status == 206) {
            // Drain the error body so the connection stays usable.
            let mut remaining = content_length;
            let mut sink = [0u8; 4096];
            while remaining > 0 {
                let take = (sink.len() as u64).min(remaining) as usize;
                self.reader
                    .read_exact(&mut sink[..take])
                    .map_err(|e| Error::Transport(format!("drain error body: {e}")))?;
                remaining -= take as u64;
            }
            return Ok(HttpResponse {
                status,
                content_length,
                range_start,
            });
        }

        // --- Body. ---
        let mut remaining = content_length;
        let mut buf = vec![0u8; 256 * 1024];
        while remaining > 0 {
            let want = (buf.len() as u64).min(remaining) as usize;
            let got = self
                .reader
                .read(&mut buf[..want])
                .map_err(|e| Error::Transport(format!("read body: {e}")))?;
            if got == 0 {
                return Err(Error::Transport(format!(
                    "connection closed mid-body ({remaining} bytes left)"
                )));
            }
            on_block(&buf[..got]);
            remaining -= got as u64;
        }
        Ok(HttpResponse {
            status,
            content_length,
            range_start,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn url_splitting() {
        let (h, p, path) = HttpConnection::split_url("http://127.0.0.1:8080/a/b").unwrap();
        assert_eq!((h.as_str(), p, path.as_str()), ("127.0.0.1", 8080, "/a/b"));
        let (h, p, path) = HttpConnection::split_url("http://127.0.0.1").unwrap();
        assert_eq!((h.as_str(), p, path.as_str()), ("127.0.0.1", 80, "/"));
        assert!(HttpConnection::split_url("https://x/").is_err());
        assert!(HttpConnection::split_url("http://h:notaport/").is_err());
    }
}
