//! Real-socket transport: an event-driven HTTP/1.1 reactor, a blocking
//! range client, a throttled local test server, and a token-bucket
//! rate limiter.
//!
//! The paper's system downloads over "standard HTTP or FTP"; this
//! module is the standard-HTTP half, implemented directly on
//! `std::net::TcpStream` (tokio is unavailable offline; a hand-rolled
//! `poll(2)` reactor keeps the dependency surface at zero while still
//! scaling to thousands of concurrent streams).
//!
//! * [`reactor`] — the real session driver's scale-out engine: a small
//!   fixed pool of reactor threads drives all slot sockets through
//!   non-blocking connect/read state machines, with DNS + TCP setup on
//!   a separate connector pool and a whole-chunk progress deadline so
//!   dribbling servers cannot pin a chunk open forever. Payload bytes
//!   are handed to the [`sink`] rather than written on the poll loop.
//! * [`sink`] — the write-behind disk stage: dedicated writer threads
//!   drain pooled payload buffers with coalesced positional writes
//!   against per-file handles opened once per session, acking chunk
//!   completion only after the bytes land; a dry buffer pool parks the
//!   feeding connection (bounded memory) instead of queuing unbounded.
//! * [`http_client`] — minimal blocking HTTP/1.1 client: persistent
//!   connections, `Range: bytes=…` GETs, status/headers parsing,
//!   chunked reads with byte-count callbacks. Still used by the simple
//!   one-connection paths and as the URL-parsing authority
//!   ([`HttpConnection::split_url`]).
//! * [`http_server`] — the local stand-in for an ENA/NCBI mirror:
//!   serves deterministic synthetic payloads for registered paths,
//!   honors range requests and keep-alive, throttles per-connection
//!   and globally through token buckets, and can replay scheduled
//!   fault windows (errors, stalls, byte-dribbling) so the end-to-end
//!   tests can reproduce a misbehaving archive on loopback.
//! * [`fetcher`] — the blocking chunk data path (persistent
//!   connection + sink writing + failure classification); the reactor
//!   reimplements the same classification non-blockingly, and parity
//!   between the two is pinned by the fetcher's tests.
//! * [`token_bucket`] — the shared rate limiter.
//!
//! The real session driver ([`crate::session::real`]) adapts this
//! module to the unified engine ([`crate::session::engine`]), which
//! runs the same scheduler/status-array/controller machinery over the
//! simulator and over these sockets.

pub mod fetcher;
pub mod http_client;
pub mod http_server;
pub mod reactor;
pub mod sink;
pub mod token_bucket;

pub use fetcher::ChunkFetcher;
pub use http_client::{HttpConnection, HttpResponse};
pub use http_server::{ServedFile, ServerFaultWindow, ThrottledHttpServer, ThrottleConfig};
pub use reactor::{FetchSpec, KillSwitch, ProgressPolicy, Reactor};
pub use sink::{SinkConfig, SinkFile};
pub use token_bucket::TokenBucket;
