//! Real-socket transport: an HTTP/1.1 range client, a throttled local
//! test server, and a token-bucket rate limiter.
//!
//! The paper's system downloads over "standard HTTP or FTP"; this
//! module is the standard-HTTP half, implemented directly on
//! `std::net::TcpStream` (tokio is unavailable offline, and a
//! thread-per-connection blocking design matches the paper's
//! socket-per-worker architecture anyway).
//!
//! * [`http_client`] — minimal HTTP/1.1 client: persistent connections,
//!   `Range: bytes=…` GETs, status/headers parsing, chunked reads with
//!   byte-count callbacks (the worker feeds the throughput recorder
//!   from that callback).
//! * [`http_server`] — the local stand-in for an ENA/NCBI mirror:
//!   serves deterministic synthetic payloads for registered paths,
//!   honors range requests and keep-alive, and throttles per-connection
//!   and globally through token buckets so the end-to-end example can
//!   reproduce a bandwidth-limited archive on loopback.
//! * [`fetcher`] — one worker's chunk data path (persistent
//!   connection + sink writing + failure classification), the
//!   real-socket implementation detail behind the unified session
//!   engine's `Transport`.
//! * [`token_bucket`] — the shared rate limiter.
//!
//! The real session driver ([`crate::session::real`]) adapts this
//! module to the unified engine ([`crate::session::engine`]), which
//! runs the same scheduler/status-array/controller machinery over the
//! simulator and over these sockets.

pub mod fetcher;
pub mod http_client;
pub mod http_server;
pub mod token_bucket;

pub use fetcher::ChunkFetcher;
pub use http_client::{HttpConnection, HttpResponse};
pub use http_server::{ServedFile, ServerFaultWindow, ThrottledHttpServer, ThrottleConfig};
pub use token_bucket::TokenBucket;
