//! Write-behind disk sink: the reactor's asynchronous byte-landing
//! stage.
//!
//! Before this module existed, every payload read on a reactor thread
//! was followed by a blocking `write_all` into the output file, and
//! every chunk re-opened and re-seeked that file — one slow disk write
//! stalled every connection multiplexed on the reactor, exactly in the
//! high-speed regime the adaptive controller is supposed to exploit.
//! The sink decouples the two halves of the pipeline:
//!
//! * **Pooled buffers, no allocation on the poll loop** — reactor
//!   threads copy socket payloads into recycled [`SINK_BUF_BYTES`]
//!   buffers from a bounded [`BufferPool`] and hand them off; a
//!   [`PooledBuf`] returns itself to the pool on drop, so every
//!   teardown path recycles.
//! * **Dedicated writer threads, positional writes** — a small pool of
//!   `dl-sink-N` threads drains [`WriteJob`]s with
//!   `FileExt::write_all_at` against per-file handles opened **once
//!   per session** ([`SinkFile`]), killing the old per-chunk
//!   open/seek/close triple. No disk syscall ever runs on a reactor
//!   thread (unless `threads == 0` selects the inline legacy mode).
//! * **Adjacent-range coalescing** — each drained batch is sorted by
//!   `(file, offset)` and contiguous runs are merged into one
//!   positional write (up to [`SinkConfig::coalesce_bytes`]), so many
//!   small adaptive chunks become few large sequential writes.
//! * **Explicit backpressure** — the pool *is* the queue bound: when no
//!   buffer is free the reactor parks the connection in its `Blocked`
//!   state instead of ballooning memory, and resumes when the writers
//!   recycle buffers. [`SinkStats`] tracks the queue-depth high-water
//!   mark and the total parked time.
//! * **Durability-ordered acks** — a chunk's `Completed` event is sent
//!   by the writer only after the chunk's **final** job (`last ==
//!   true`) hits the page cache, and its bytes are credited to the
//!   shared [`ThroughputRecorder`] write-side, so engine byte
//!   accounting sees exactly what the disk holds. Write errors
//!   (ENOSPC, permissions) surface as [`FailureClass::Fatal`] and
//!   poison the chunk's remaining queued jobs so at most one terminal
//!   event per chunk generation ever reaches the engine.

use std::collections::{HashMap, HashSet};
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, SendError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::DownloadConfig;
use crate::metrics::gauge::PeakGauge;
use crate::metrics::recorder::ThroughputRecorder;
use crate::session::engine::{FailureClass, TransportEvent, TransportIoStats};
use crate::trace::{TraceEvent, WallTracer};
use crate::transport::reactor::KillSwitch;
use crate::util::sha256::Sha256;
use crate::{Error, Result};

/// Size of one pooled payload buffer. Matches the reactor's scratch
/// size so a full socket read always fits in one buffer.
pub const SINK_BUF_BYTES: usize = 256 * 1024;

/// Most jobs a writer drains per wakeup (bounds the coalescing sort).
const MAX_BATCH_JOBS: usize = 64;

/// Writer-pool tuning, resolved from [`DownloadConfig`] (or built by
/// hand in tests).
#[derive(Clone, Copy, Debug)]
pub struct SinkConfig {
    /// Dedicated writer threads. `0` selects the inline legacy mode:
    /// the reactor writes synchronously through [`Sink::write_inline`]
    /// (kept selectable as the measured pre-sink reference path).
    pub threads: usize,
    /// Total pooled-buffer budget in bytes — the bound on sink memory
    /// and therefore the backpressure threshold (floored at four
    /// buffers).
    pub queue_bytes: usize,
    /// Maximum bytes merged into one positional write.
    pub coalesce_bytes: usize,
    /// Artificial per-write latency — the slow-disk test shim used by
    /// the backpressure and goodput suites. Zero (the default and the
    /// only value reachable from user config) is free.
    pub write_latency: Duration,
    /// Stream each chunk's payload through SHA-256 on the writer
    /// threads (`--verify`): the `Completed` ack then carries the
    /// chunk digest for the engine's manifest check. Off by default —
    /// unverified sessions skip the hashing work entirely.
    pub hash: bool,
}

impl Default for SinkConfig {
    fn default() -> SinkConfig {
        SinkConfig {
            threads: 2,
            queue_bytes: 64 * 1024 * 1024,
            coalesce_bytes: 1024 * 1024,
            write_latency: Duration::ZERO,
            hash: false,
        }
    }
}

impl SinkConfig {
    /// Resolve the user-facing knobs (`sink_threads`, `sink_queue_mb`,
    /// `coalesce_kb`, `integrity.verify`).
    pub fn from_download(cfg: &DownloadConfig) -> SinkConfig {
        SinkConfig {
            threads: cfg.sink_threads,
            queue_bytes: cfg.sink_queue_mb * 1024 * 1024,
            coalesce_bytes: cfg.coalesce_kb * 1024,
            write_latency: Duration::ZERO,
            hash: cfg.integrity.verify,
        }
    }
}

/// A per-session output handle: the file opened (and pre-sized) once
/// by the session driver, shared by every chunk written into it.
#[derive(Clone)]
pub struct SinkFile {
    /// Shared handle; all writes are positional, so no seeking and no
    /// coordination between writers.
    pub file: Arc<File>,
    /// Destination path (error messages only).
    pub path: Arc<PathBuf>,
}

/// A recycled payload buffer checked out of the [`BufferPool`].
/// Returns its storage to the pool on drop — covering ack, error, and
/// teardown paths alike.
pub struct PooledBuf {
    pool: Arc<Mutex<Vec<Vec<u8>>>>,
    buf: Vec<u8>,
}

impl PooledBuf {
    /// Copy as much of `data` as fits; returns the number of bytes
    /// taken (never reallocates).
    pub fn push(&mut self, data: &[u8]) -> usize {
        let room = self.buf.capacity() - self.buf.len();
        let n = room.min(data.len());
        self.buf.extend_from_slice(&data[..n]);
        n
    }

    /// Buffer is at capacity.
    pub fn is_full(&self) -> bool {
        self.buf.len() >= self.buf.capacity()
    }

    /// Bytes currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// No bytes held.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The held bytes.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        let mut buf = std::mem::take(&mut self.buf);
        buf.clear();
        if let Ok(mut free) = self.pool.lock() {
            free.push(buf);
        }
    }
}

/// Fixed set of [`SINK_BUF_BYTES`] buffers. Exhaustion is the
/// backpressure signal: [`BufferPool::try_acquire`] never blocks and
/// never allocates past the budget.
#[derive(Clone)]
pub struct BufferPool {
    free: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl BufferPool {
    /// A pool holding `total_bytes / SINK_BUF_BYTES` buffers (at least
    /// four, so tiny budgets still make progress).
    pub fn new(total_bytes: usize) -> BufferPool {
        let count = (total_bytes / SINK_BUF_BYTES).max(4);
        let free = (0..count)
            .map(|_| Vec::with_capacity(SINK_BUF_BYTES))
            .collect();
        BufferPool {
            free: Arc::new(Mutex::new(free)),
        }
    }

    /// Check a buffer out, or `None` when the pool is dry.
    pub fn try_acquire(&self) -> Option<PooledBuf> {
        let buf = self.free.lock().ok()?.pop()?;
        Some(PooledBuf {
            pool: self.free.clone(),
            buf,
        })
    }
}

/// One handed-off write: a pooled buffer bound for `file[offset..]`.
pub struct WriteJob {
    /// Engine worker slot (routes the job and keys terminal events).
    pub slot: usize,
    /// Chunk generation (distinguishes stale jobs of a failed fetch
    /// from the slot's current chunk).
    pub gen: u64,
    /// Destination handle.
    pub file: SinkFile,
    /// Absolute file offset of the buffer's first byte.
    pub offset: u64,
    /// The payload.
    pub buf: PooledBuf,
    /// Final job of its chunk: the writer acks `Completed` after it
    /// lands.
    pub last: bool,
}

/// Shared sink counters (all wait-free).
#[derive(Debug, Default)]
pub struct SinkStats {
    /// Positional writes issued (one per coalesced run).
    pub write_syscalls: AtomicU64,
    /// Total nanoseconds connections spent parked on backpressure.
    pub stall_ns: AtomicU64,
    /// Bytes queued in the sink right now / at peak.
    pub queued: PeakGauge,
}

/// The writer pool plus its buffer pool — one per [`super::reactor::Reactor`].
pub struct Sink {
    txs: Vec<Sender<WriteJob>>,
    pool: BufferPool,
    stats: Arc<SinkStats>,
    next_gen: AtomicU64,
    write_latency: Duration,
}

struct WriterCtx {
    /// Writer index (`dl-sink-N`), stamped on trace batch events.
    writer: u32,
    job_rx: Receiver<WriteJob>,
    events_tx: Sender<TransportEvent>,
    recorder: Arc<ThroughputRecorder>,
    stats: Arc<SinkStats>,
    kill: KillSwitch,
    coalesce_bytes: usize,
    write_latency: Duration,
    hash: bool,
    /// Flight recorder for batch drains and queue depth (`--trace-out`).
    trace: Option<WallTracer>,
}

impl Sink {
    /// Spawn `cfg.threads` writer threads (`dl-sink-N`), appending
    /// their join handles to `joins` — the reactor owns thread
    /// lifetime and joins them on shutdown. With `threads == 0` no
    /// thread spawns and the reactor must use [`Sink::write_inline`].
    pub fn spawn(
        cfg: SinkConfig,
        events_tx: Sender<TransportEvent>,
        recorder: Arc<ThroughputRecorder>,
        kill: KillSwitch,
        trace: Option<WallTracer>,
        joins: &mut Vec<std::thread::JoinHandle<()>>,
    ) -> Result<Sink> {
        let stats: Arc<SinkStats> = Arc::default();
        let pool = BufferPool::new(cfg.queue_bytes);
        let mut txs = Vec::with_capacity(cfg.threads);
        for i in 0..cfg.threads {
            let (tx, rx) = channel::<WriteJob>();
            txs.push(tx);
            let ctx = WriterCtx {
                writer: i as u32,
                job_rx: rx,
                events_tx: events_tx.clone(),
                recorder: recorder.clone(),
                stats: stats.clone(),
                kill: kill.clone(),
                coalesce_bytes: cfg.coalesce_bytes,
                write_latency: cfg.write_latency,
                hash: cfg.hash,
                trace: trace.clone(),
            };
            joins.push(
                std::thread::Builder::new()
                    .name(format!("dl-sink-{i}"))
                    .spawn(move || writer_loop(ctx))
                    .map_err(|e| Error::Session(format!("spawn sink writer {i}: {e}")))?,
            );
        }
        Ok(Sink {
            txs,
            pool,
            stats,
            next_gen: AtomicU64::new(0),
            write_latency: cfg.write_latency,
        })
    }

    /// Whether writes happen inline on the reactor (`threads == 0`).
    pub fn is_inline(&self) -> bool {
        self.txs.is_empty()
    }

    /// A fresh chunk generation (assigned per armed fetch).
    pub fn next_gen(&self) -> u64 {
        self.next_gen.fetch_add(1, Ordering::SeqCst)
    }

    /// Check a payload buffer out of the pool. `None` is the
    /// backpressure signal: park the connection, retry after the
    /// writers recycle.
    pub fn try_buffer(&self) -> Option<PooledBuf> {
        self.pool.try_acquire()
    }

    /// Queue a job on a writer. Jobs route by slot, so one chunk's
    /// jobs stay ordered on one writer.
    pub fn submit(&self, job: WriteJob) {
        self.stats.queued.add(job.buf.len() as u64);
        let dest = job.slot % self.txs.len();
        if let Err(SendError(job)) = self.txs[dest].send(job) {
            // Writer already gone (teardown): keep the gauge honest;
            // the buffer recycles on drop and the engine sees the dead
            // event channel.
            self.stats.queued.sub(job.buf.len() as u64);
        }
    }

    /// Inline legacy path (`threads == 0`): synchronous positional
    /// write on the calling reactor thread — the measured pre-sink
    /// reference the perf suites compare against.
    pub fn write_inline(&self, file: &SinkFile, data: &[u8], offset: u64) -> std::io::Result<()> {
        if !self.write_latency.is_zero() {
            std::thread::sleep(self.write_latency);
        }
        self.stats.write_syscalls.fetch_add(1, Ordering::SeqCst);
        file.file.write_all_at(data, offset)
    }

    /// Record time a connection spent parked on backpressure.
    pub fn note_stall(&self, parked: Duration) {
        self.stats
            .stall_ns
            .fetch_add(parked.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Snapshot of the disk-path counters.
    pub fn io_stats(&self) -> TransportIoStats {
        TransportIoStats {
            write_syscalls: self.stats.write_syscalls.load(Ordering::SeqCst),
            sink_queue_peak: self.stats.queued.peak(),
            reactor_stall_ns: self.stats.stall_ns.load(Ordering::SeqCst),
        }
    }
}

/// Per-writer streaming-hash state (`SinkConfig::hash`): one running
/// [`Sha256`] per in-flight chunk generation, fed in arrival order,
/// finalized on the chunk's last job.
#[derive(Default)]
struct HashState {
    /// Running hashers keyed by `(slot, gen)`.
    hashers: HashMap<(usize, u64), Sha256>,
    /// Finalized digests awaiting their last job's flush ack.
    digests: HashMap<(usize, u64), [u8; 32]>,
}

fn writer_loop(ctx: WriterCtx) {
    let mut batch: Vec<WriteJob> = Vec::with_capacity(MAX_BATCH_JOBS);
    let mut merged: Vec<u8> = Vec::with_capacity(ctx.coalesce_bytes);
    let mut poisoned: HashSet<(usize, u64)> = HashSet::new();
    let mut hashes = HashState::default();
    loop {
        if ctx.kill.is_killed() {
            return;
        }
        match ctx.job_rx.recv_timeout(Duration::from_millis(100)) {
            Ok(j) => batch.push(j),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return,
        }
        while batch.len() < MAX_BATCH_JOBS {
            match ctx.job_rx.try_recv() {
                Ok(j) => batch.push(j),
                Err(_) => break,
            }
        }
        process_batch(&ctx, &mut batch, &mut merged, &mut poisoned, &mut hashes);
        batch.clear(); // drops the jobs → buffers recycle into the pool
    }
}

/// Drain one batch: sort by `(file, offset)`, merge contiguous runs
/// into single positional writes, credit + ack per job, poison chunks
/// whose write failed.
fn process_batch(
    ctx: &WriterCtx,
    batch: &mut Vec<WriteJob>,
    merged: &mut Vec<u8>,
    poisoned: &mut HashSet<(usize, u64)>,
    hashes: &mut HashState,
) {
    let queued: u64 = batch.iter().map(|j| j.buf.len() as u64).sum();
    let jobs = batch.len() as u32;
    // Feed the streaming hashers in *arrival* order, before the
    // coalescing sort below reorders the batch: one chunk's jobs route
    // to one writer in submit order, so arrival order is offset order
    // within a (slot, gen) — exactly the byte order of the payload.
    if ctx.hash {
        for j in batch.iter() {
            let key = (j.slot, j.gen);
            if poisoned.contains(&key) {
                continue;
            }
            if !hashes.hashers.contains_key(&key) {
                // A slot carries one chunk at a time, so any older
                // generation on this slot is dead — drop its state
                // instead of leaking it (abandoned fetches never send
                // a `last` job).
                hashes.hashers.retain(|&(s, g), _| s != j.slot || g == j.gen);
                hashes.digests.retain(|&(s, g), _| s != j.slot || g == j.gen);
                hashes.hashers.insert(key, Sha256::new());
            }
            let h = hashes.hashers.get_mut(&key).expect("hasher just ensured");
            h.update(j.buf.as_slice());
            if j.last {
                let h = hashes.hashers.remove(&key).expect("hasher present");
                hashes.digests.insert(key, h.finalize());
            }
        }
    }
    batch.retain(|j| !poisoned.contains(&(j.slot, j.gen)));
    batch.sort_by_key(|j| (Arc::as_ptr(&j.file.file) as usize, j.offset));
    let mut i = 0;
    let mut writes = 0u32;
    while i < batch.len() {
        let n = run_len(batch, i, ctx.coalesce_bytes);
        flush_run(ctx, merged, &batch[i..i + n], poisoned, hashes);
        writes += 1;
        i += n;
    }
    ctx.stats.queued.sub(queued);
    if let Some(tr) = ctx.trace.as_ref() {
        tr.record(TraceEvent::SinkBatch {
            writer: ctx.writer,
            jobs,
            bytes: queued,
            writes,
        });
        tr.record(TraceEvent::SinkQueue {
            queued_bytes: ctx.stats.queued.current(),
        });
    }
}

/// Length of the contiguous run starting at `start`: same file,
/// back-to-back offsets, merged size within the coalescing cap.
fn run_len(batch: &[WriteJob], start: usize, coalesce_bytes: usize) -> usize {
    let head = &batch[start];
    let mut bytes = head.buf.len();
    let mut n = 1;
    while start + n < batch.len() {
        let j = &batch[start + n];
        if !Arc::ptr_eq(&j.file.file, &head.file.file)
            || j.offset != head.offset + bytes as u64
            || bytes + j.buf.len() > coalesce_bytes
        {
            break;
        }
        bytes += j.buf.len();
        n += 1;
    }
    n
}

/// One coalesced positional write plus its per-job accounting.
fn flush_run(
    ctx: &WriterCtx,
    merged: &mut Vec<u8>,
    run: &[WriteJob],
    poisoned: &mut HashSet<(usize, u64)>,
    hashes: &mut HashState,
) {
    let head = &run[0];
    if !ctx.write_latency.is_zero() {
        std::thread::sleep(ctx.write_latency);
    }
    ctx.stats.write_syscalls.fetch_add(1, Ordering::SeqCst);
    let result = if run.len() == 1 {
        head.file.file.write_all_at(head.buf.as_slice(), head.offset)
    } else {
        merged.clear();
        for j in run {
            merged.extend_from_slice(j.buf.as_slice());
        }
        head.file.file.write_all_at(merged, head.offset)
    };
    match result {
        Ok(()) => {
            let total: u64 = run.iter().map(|j| j.buf.len() as u64).sum();
            ctx.recorder.add_bytes(total);
            for j in run {
                if j.last {
                    let digest = hashes.digests.remove(&(j.slot, j.gen));
                    let _ = ctx
                        .events_tx
                        .send(TransportEvent::Completed { slot: j.slot, digest });
                }
            }
        }
        Err(e) => {
            // The whole run failed: fail every chunk it carried bytes
            // for, once each, and drop that chunk's still-queued jobs
            // (and any streaming-hash state — the re-fetch rehashes
            // from scratch under a fresh generation).
            for j in run {
                hashes.hashers.remove(&(j.slot, j.gen));
                hashes.digests.remove(&(j.slot, j.gen));
                if poisoned.insert((j.slot, j.gen)) {
                    let _ = ctx.events_tx.send(TransportEvent::Failed {
                        slot: j.slot,
                        class: FailureClass::Fatal,
                        error: format!("write {}: {e}", j.file.path.display()),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fastbiodl-sink-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn writer_ctx(latency: Duration) -> (WriterCtx, Receiver<TransportEvent>) {
        writer_ctx_hashing(latency, false)
    }

    fn writer_ctx_hashing(
        latency: Duration,
        hash: bool,
    ) -> (WriterCtx, Receiver<TransportEvent>) {
        let (_job_tx, job_rx) = channel::<WriteJob>();
        let (events_tx, events_rx) = channel::<TransportEvent>();
        let ctx = WriterCtx {
            writer: 0,
            job_rx,
            events_tx,
            recorder: Arc::new(ThroughputRecorder::new()),
            stats: Arc::default(),
            kill: KillSwitch::default(),
            coalesce_bytes: 1024 * 1024,
            write_latency: latency,
            hash,
            trace: None,
        };
        (ctx, events_rx)
    }

    fn job(
        pool: &BufferPool,
        file: &SinkFile,
        slot: usize,
        gen: u64,
        offset: u64,
        data: &[u8],
        last: bool,
    ) -> WriteJob {
        let mut buf = pool.try_acquire().expect("pool dry in test");
        assert_eq!(buf.push(data), data.len());
        WriteJob {
            slot,
            gen,
            file: file.clone(),
            offset,
            buf,
            last,
        }
    }

    #[test]
    fn pool_bounds_and_recycles_buffers() {
        let pool = BufferPool::new(2 * SINK_BUF_BYTES); // floored at 4
        let held: Vec<PooledBuf> = (0..4).map(|_| pool.try_acquire().unwrap()).collect();
        assert!(pool.try_acquire().is_none(), "budget must be hard");
        drop(held);
        assert!(pool.try_acquire().is_some(), "drop must recycle");
    }

    #[test]
    fn adjacent_jobs_coalesce_into_one_write() {
        let path = tmp("coalesce.bin");
        let file = SinkFile {
            file: Arc::new(File::create(&path).unwrap()),
            path: Arc::new(path.clone()),
        };
        let pool = BufferPool::new(0);
        let (ctx, events_rx) = writer_ctx(Duration::ZERO);
        let mut batch = vec![
            job(&pool, &file, 3, 7, 0, b"aaaa", false),
            job(&pool, &file, 3, 7, 4, b"bbbb", false),
            job(&pool, &file, 3, 7, 8, b"cc", true),
        ];
        let mut merged = Vec::new();
        let mut poisoned = HashSet::new();
        let mut hashes = HashState::default();
        process_batch(&ctx, &mut batch, &mut merged, &mut poisoned, &mut hashes);
        assert_eq!(ctx.stats.write_syscalls.load(Ordering::SeqCst), 1);
        assert_eq!(std::fs::read(&path).unwrap(), b"aaaabbbbcc");
        match events_rx.try_recv().unwrap() {
            TransportEvent::Completed { slot, digest } => {
                assert_eq!(slot, 3);
                assert!(digest.is_none(), "no digest with hashing off");
            }
            other => panic!("expected Completed, got {other:?}"),
        }
        assert!(events_rx.try_recv().is_err(), "exactly one ack per chunk");
    }

    #[test]
    fn hashing_writer_acks_with_the_chunk_digest() {
        let path = tmp("hashed.bin");
        let file = SinkFile {
            file: Arc::new(File::create(&path).unwrap()),
            path: Arc::new(path.clone()),
        };
        let pool = BufferPool::new(0);
        let (ctx, events_rx) = writer_ctx_hashing(Duration::ZERO, true);
        let mut merged = Vec::new();
        let mut poisoned = HashSet::new();
        let mut hashes = HashState::default();
        // The chunk's jobs arrive across two batches; the digest must
        // cover the whole payload in arrival (= offset) order.
        let mut batch = vec![job(&pool, &file, 2, 11, 0, b"hello ", false)];
        process_batch(&ctx, &mut batch, &mut merged, &mut poisoned, &mut hashes);
        assert!(events_rx.try_recv().is_err(), "no ack before the last job");
        let mut batch = vec![job(&pool, &file, 2, 11, 6, b"world", true)];
        process_batch(&ctx, &mut batch, &mut merged, &mut poisoned, &mut hashes);
        match events_rx.try_recv().unwrap() {
            TransportEvent::Completed { slot, digest } => {
                assert_eq!(slot, 2);
                assert_eq!(
                    digest,
                    Some(crate::util::sha256::sha256(b"hello world")),
                    "digest must cover the streamed payload"
                );
            }
            other => panic!("expected Completed, got {other:?}"),
        }
        assert!(hashes.hashers.is_empty() && hashes.digests.is_empty());
    }

    #[test]
    fn gapped_offsets_split_the_run() {
        let path = tmp("gap.bin");
        let file = SinkFile {
            file: Arc::new(File::create(&path).unwrap()),
            path: Arc::new(path.clone()),
        };
        let pool = BufferPool::new(0);
        let (ctx, _events_rx) = writer_ctx(Duration::ZERO);
        let mut batch = vec![
            job(&pool, &file, 0, 1, 0, b"xx", true),
            job(&pool, &file, 1, 2, 6, b"yy", true),
        ];
        let mut merged = Vec::new();
        let mut poisoned = HashSet::new();
        let mut hashes = HashState::default();
        process_batch(&ctx, &mut batch, &mut merged, &mut poisoned, &mut hashes);
        assert_eq!(ctx.stats.write_syscalls.load(Ordering::SeqCst), 2);
        let got = std::fs::read(&path).unwrap();
        assert_eq!(&got[0..2], b"xx");
        assert_eq!(&got[6..8], b"yy");
    }

    #[test]
    fn write_failure_is_fatal_and_poisons_the_chunk() {
        // A read-only handle makes every positional write fail the way
        // a full or read-only filesystem would.
        let path = tmp("readonly.bin");
        std::fs::write(&path, b"seed").unwrap();
        let file = SinkFile {
            file: Arc::new(File::open(&path).unwrap()),
            path: Arc::new(path.clone()),
        };
        let pool = BufferPool::new(0);
        let (ctx, events_rx) = writer_ctx(Duration::ZERO);
        let mut merged = Vec::new();
        let mut poisoned = HashSet::new();
        let mut hashes = HashState::default();
        let mut batch = vec![job(&pool, &file, 5, 9, 0, b"zz", false)];
        process_batch(&ctx, &mut batch, &mut merged, &mut poisoned, &mut hashes);
        match events_rx.try_recv().unwrap() {
            TransportEvent::Failed { slot, class, error } => {
                assert_eq!(slot, 5);
                assert_eq!(class, FailureClass::Fatal);
                assert!(error.contains("write"), "got {error:?}");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        // The chunk's later jobs (same slot+gen) are dropped silently:
        // no second terminal event, no Completed from the last job.
        let mut batch = vec![job(&pool, &file, 5, 9, 2, b"zz", true)];
        process_batch(&ctx, &mut batch, &mut merged, &mut poisoned, &mut hashes);
        assert!(events_rx.try_recv().is_err());
        assert_eq!(ctx.stats.write_syscalls.load(Ordering::SeqCst), 1);
        // A fresh generation on the same slot writes normally again.
        assert!(poisoned.contains(&(5, 9)));
    }
}
