//! Event-driven non-blocking socket reactor — the real transport's
//! scale-out engine.
//!
//! The old real driver spawned one blocking OS thread per engine slot,
//! which capped `c_max` at 512 (thread stacks) while the simulated path
//! scaled to thousands of slots. This module replaces that pool with a
//! small **fixed** reactor-thread pool that drives *all* slot sockets
//! through non-blocking state machines over `poll(2)` — dependency-light
//! (a single libc FFI declaration, no tokio/mio), so thousands of
//! concurrent HTTP streams cost thousands of file descriptors, not
//! thousands of stacks.
//!
//! ## Threads
//!
//! * **Reactor threads** (`dl-reactor-N`, `available_parallelism`
//!   clamped to 2..=8): each owns the connections of the slots hashed
//!   to it (`slot % n_reactors`), polls their sockets, and runs the
//!   per-connection HTTP state machine. The poll loop never touches
//!   the disk or the allocator: payload bytes are copied into pooled
//!   buffers and handed to the write-behind sink
//!   ([`crate::transport::sink`]); discard-mode bytes go straight into
//!   the shared [`ThroughputRecorder`].
//! * **Sink writer threads** (`dl-sink-N`): drain the pooled buffers
//!   with coalesced positional writes and ack chunk completion once
//!   the bytes have landed. With `sink_threads = 0` the reactor falls
//!   back to inline synchronous writes (the measured legacy path).
//! * **Connector threads** (`dl-connect-N`, fixed small pool): perform
//!   the *blocking* steps of connection setup — DNS resolution (now an
//!   explicit step, mirrored by the simulator's DNS-outage fault class)
//!   and `connect_timeout` — then hand the socket, flipped to
//!   non-blocking, to the owning reactor thread for adoption.
//!
//! ## Per-connection state machine
//!
//! ```text
//!             Cmd::Fetch (no conn)                Adopt(Ok)
//! (absent) ───────────────────────► Connecting ─────────────► Sending
//!                                       │                        │ request
//!                                       │ Adopt(Err)             ▼ written
//!   Idle ◄──────────────┐               ▼                     Headers
//!    │ ▲                │        Failed{Transport}               │ blank line
//!    │ │ Completed      │                                        ▼
//!    │ └────────────────┼──────────────────────── Body ◄── 200/206, length ok
//!    │ Cmd::Fetch       │ Failed{Reject|Fatal}     ▲  │
//!    └─ (reuse) ────────┴──────── Drain ◄──────────┘  │ sink pool dry
//!                                                     ▼
//!                                  (deregistered) Blocked ── buffer freed ──► Body
//! ```
//!
//! `Blocked` is sink backpressure: the buffer pool ran dry mid-body,
//! so the connection parks (its socket drops out of the poll set —
//! TCP flow control pushes back on the server) and carries the
//! unhanded bytes until the writers recycle a buffer. Parked time is
//! reported as `reactor_stall_ns`.
//!
//! Every transition that fails classifies into the engine's
//! [`FailureClass`] taxonomy exactly as the blocking
//! [`crate::transport::fetcher::ChunkFetcher`] did, so
//! `tests/engine_parity.rs` byte accounting is untouched.
//!
//! ## Progress deadline
//!
//! Non-blocking sockets have no per-`read()` timeout, so the reactor
//! enforces a stronger guarantee the blocking path never had: every
//! non-idle connection must move at least [`ProgressPolicy::min_bytes`]
//! per [`ProgressPolicy::window_s`] window or it is failed as
//! [`FailureClass::Transport`] and the chunk retried — a server
//! dribbling one byte every few seconds can no longer pin a chunk
//! forever.
//!
//! ## Per-mirror cap and slot generations
//!
//! The per-mirror connection gauge ([`Reactor::reserve`] /
//! [`Reactor::release`] / [`Reactor::mirror_open`]) counts
//! *reservations*: the engine thread is the only incrementer, sockets
//! only exist under a reservation, and every teardown path decrements
//! exactly once, so open sockets to a mirror never exceed the cap —
//! strictly, not "momentarily softly" as the old thread-per-slot
//! binding check did. Per-slot generation counters cancel in-flight
//! connects that raced a release.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::scheduler::Chunk;
use crate::metrics::recorder::ThroughputRecorder;
use crate::session::engine::{FailureClass, TransportEvent, TransportIoStats};
use crate::transport::fetcher::CONNECT_TIMEOUT;
use crate::trace::{TraceEvent, WallTracer};
use crate::transport::sink::{PooledBuf, Sink, SinkConfig, SinkFile, WriteJob};
use crate::util::sha256::Sha256;
use crate::{Error, Result};

/// Raw `poll(2)` — the only system interface the reactor needs beyond
/// `std::net`. Declared by hand to stay dependency-free.
mod sys {
    /// `nfds_t` on Linux.
    pub type NfdsT = u64;

    /// `struct pollfd`.
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
    }
}

/// Per-reactor-thread read buffer. Shared across that thread's
/// connections (4096 conns × a per-conn buffer would be gigabytes).
const SCRATCH_BYTES: usize = 256 * 1024;

/// Response heads larger than this are a protocol error.
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// `poll(2)` timeout: bounds command-pickup latency while sockets are
/// registered.
const POLL_TIMEOUT_MS: i32 = 1;

/// Default idle keep-alive deadline: a parked `Idle` connection that
/// has not been re-armed within this window is closed by the reactor's
/// reap sweep, so a long campaign does not hoard file descriptors
/// against capped mirrors. The engine never notices — the next fetch
/// on the slot simply redials under the same reservation, exactly like
/// a server-side keep-alive drop.
pub const IDLE_REAP_DEFAULT_S: f64 = 60.0;

/// Cooperative shutdown flag shared by every reactor/connector thread.
/// Tests use a clone to simulate the whole event loop dying mid-session
/// (the dead-worker-hang regression).
#[derive(Clone, Default)]
pub struct KillSwitch(Arc<AtomicBool>);

impl KillSwitch {
    /// Ask every reactor and connector thread to exit.
    pub fn kill(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Whether [`KillSwitch::kill`] has been called.
    pub fn is_killed(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Whole-chunk progress deadline (see the module docs). `window_s <= 0`
/// disables the check.
#[derive(Clone, Copy, Debug)]
pub struct ProgressPolicy {
    /// Measurement window length, seconds.
    pub window_s: f64,
    /// Minimum bytes (headers + payload) per window.
    pub min_bytes: u64,
}

/// One fetch assignment: everything a reactor thread needs to issue the
/// request and land the bytes.
pub struct FetchSpec {
    /// Engine worker slot.
    pub slot: usize,
    /// Server host (name or IP; resolution happens on a connector).
    pub host: String,
    /// Server port.
    pub port: u16,
    /// Request path.
    pub path: String,
    /// Preopened output handle (`None` = count and discard). Opened
    /// once per session by the driver — the reactor never opens files.
    pub out: Option<SinkFile>,
    /// Byte range to fetch.
    pub chunk: Chunk,
    /// Total object size (a chunk covering it all skips the `Range`
    /// header, exactly like the blocking fetcher).
    pub total_bytes: u64,
    /// Mirror index the slot is bound to (reservation bookkeeping).
    pub mirror: usize,
}

impl FetchSpec {
    fn range(&self) -> Option<(u64, u64)> {
        if self.chunk.offset == 0 && self.chunk.len == self.total_bytes {
            None
        } else {
            Some((self.chunk.offset, self.chunk.len))
        }
    }
}

/// Commands a reactor thread processes between polls.
enum Cmd {
    /// Start fetching (reusing the slot's idle connection if it matches
    /// the target endpoint, dialing a fresh one otherwise).
    Fetch(Box<FetchSpec>),
    /// The engine released the slot: close its socket and settle its
    /// mirror reservation.
    Release { slot: usize, mirror: usize },
    /// A connector finished (or abandoned) a dial for this slot.
    Adopt {
        slot: usize,
        gen: u64,
        spec: Box<FetchSpec>,
        result: std::result::Result<TcpStream, (FailureClass, String)>,
    },
}

/// A dial request handed to a connector thread.
struct ConnectJob {
    slot: usize,
    gen: u64,
    spec: Box<FetchSpec>,
}

/// HTTP/1.1 request state over one non-blocking socket.
enum HttpState {
    /// Connected, no request in flight (keep-alive parking).
    Idle,
    /// Writing the request line + headers (bytes live in the
    /// connection's reused `req_buf`).
    Sending { sent: usize },
    /// Accumulating the response head up to the blank line.
    Headers { head: Vec<u8> },
    /// Streaming a `Content-Length`-delimited payload.
    Body { remaining: u64 },
    /// Sink backpressure: the buffer pool ran dry mid-body. The socket
    /// is deregistered from poll (TCP flow control pushes back on the
    /// server); `carry` holds the already-read bytes that could not be
    /// handed off, retried every loop iteration until a buffer frees.
    Blocked {
        remaining: u64,
        carry: Vec<u8>,
        since: Instant,
    },
    /// Consuming an error body so the connection stays usable, then
    /// reporting the stored failure.
    Drain {
        remaining: u64,
        class: FailureClass,
        error: String,
    },
}

/// One live connection owned by a reactor thread.
struct Conn {
    stream: TcpStream,
    host: String,
    port: u16,
    st: HttpState,
    /// The fetch in flight (None while Idle).
    spec: Option<Box<FetchSpec>>,
    /// Preopened output handle for the fetch in flight (None = discard).
    out: Option<SinkFile>,
    /// Absolute file offset of the next payload byte.
    write_off: u64,
    /// Partially filled pooled buffer awaiting hand-off to the sink.
    pending: Option<PooledBuf>,
    /// Chunk generation stamped on this fetch's sink jobs (lets the
    /// writers poison the remains of a failed chunk).
    sink_gen: u64,
    /// Reused request-build scratch: `arm_fetch` rewrites it in place,
    /// so re-arming a keep-alive connection allocates nothing.
    req_buf: Vec<u8>,
    /// HTTP/1.1 pipelining: fetches queued behind the in-flight one on
    /// this connection. Their request bytes are already serialized into
    /// `pipe_buf`; their responses resolve FIFO — each head completion
    /// (or drained error) binds the front of the queue as the next
    /// expected response. Always empty at `--pipeline-depth 1`.
    queue: VecDeque<Box<FetchSpec>>,
    /// Serialized request bytes for `queue` not yet fully written to
    /// the socket (`pipe_sent` marks the flushed prefix). Never
    /// interleaved with `req_buf`: the flush only runs outside the
    /// `Sending` state, after the head request is fully on the wire.
    pipe_buf: Vec<u8>,
    pipe_sent: usize,
    /// When the connection last went `Idle` (keep-alive parking); the
    /// reap sweep closes it after the idle deadline.
    idle_since: Instant,
    /// Progress-deadline window start.
    window_start: Instant,
    /// Bytes (head + payload) received since `window_start`.
    window_bytes: u64,
    /// Streaming chunk hasher (`--verify`) for the discard and inline
    /// write modes, where the reactor itself sends the `Completed` ack.
    /// Sink-mode chunks are hashed on the writer threads instead, so
    /// this stays `None` there and the reactor hot path does no
    /// hashing.
    hasher: Option<Sha256>,
}

/// What a reactor thread tracks per slot.
enum SlotState {
    /// A connector is dialing for this slot; `gen` cancels the adopt if
    /// the engine released the slot meanwhile.
    Connecting { gen: u64 },
    /// A live socket.
    Conn(Conn),
}

/// Outcome of driving one connection's state machine.
enum Fate {
    /// Nothing to report; keep the connection.
    Keep,
    /// Chunk fully delivered (carrying its digest when the reactor
    /// hashed it); connection back to Idle.
    Completed(Option<[u8; 32]>),
    /// Failure reported, connection survives (drained error body).
    FailKeep(FailureClass, String),
    /// Failure reported, connection closed.
    FailClose(FailureClass, String),
    /// Connection closed quietly (server dropped an idle keep-alive).
    CloseSilent,
}

struct ReactorCtx {
    cmd_rx: Receiver<Cmd>,
    connector_tx: Vec<Sender<ConnectJob>>,
    events_tx: Sender<TransportEvent>,
    kill: KillSwitch,
    gens: Arc<Vec<AtomicU64>>,
    mirror_open: Arc<Vec<AtomicUsize>>,
    recorder: Arc<ThroughputRecorder>,
    progress: ProgressPolicy,
    sink: Arc<Sink>,
    /// Per-chunk SHA-256 verification is on (`--verify`).
    hash: bool,
    /// Max requests on the wire per connection (1 = no pipelining; the
    /// enqueue route in `handle_fetch` is dead code at depth 1, so the
    /// default is byte-identical to the pre-pipelining reactor).
    pipeline_depth: usize,
    /// Idle keep-alive deadline for the reap sweep (<= 0 disables).
    idle_reap: Duration,
    /// Flight recorder for connection state transitions (`--trace-out`).
    trace: Option<WallTracer>,
}

/// Record a connection state transition for the slot whose fetch is in
/// flight. No-op when tracing is off or the connection carries no spec.
fn trace_conn(ctx: &ReactorCtx, spec: Option<&FetchSpec>, state: &'static str) {
    if let (Some(tr), Some(spec)) = (ctx.trace.as_ref(), spec) {
        tr.record(TraceEvent::ConnState {
            slot: spec.slot as u32,
            state,
        });
    }
}

struct ConnectorCtx {
    job_rx: Receiver<ConnectJob>,
    reactor_tx: Vec<Sender<Cmd>>,
    kill: KillSwitch,
    gens: Arc<Vec<AtomicU64>>,
}

/// The reactor: fixed thread pool + channels. One instance serves all
/// `capacity` engine slots.
pub struct Reactor {
    cmd_tx: Vec<Sender<Cmd>>,
    connector_tx: Vec<Sender<ConnectJob>>,
    events_rx: Receiver<TransportEvent>,
    joins: Vec<std::thread::JoinHandle<()>>,
    kill: KillSwitch,
    /// Per-slot generation counters; bumped on release to cancel
    /// in-flight dials.
    gens: Arc<Vec<AtomicU64>>,
    /// Per-mirror open-reservation gauges.
    mirror_open: Arc<Vec<AtomicUsize>>,
    /// Write-behind disk sink shared by the reactor threads.
    sink: Arc<Sink>,
}

impl Reactor {
    /// Spawn the reactor + connector + sink-writer pools for `capacity`
    /// slots across `mirror_count` mirrors, feeding payload bytes into
    /// `recorder`. `sink_cfg` shapes the write-behind disk stage
    /// (`threads == 0` keeps writes inline on the reactor threads).
    /// `pipeline_depth` caps requests on the wire per connection
    /// (1 = no pipelining); `idle_reap_s` closes keep-alive connections
    /// parked longer than that many seconds (<= 0 disables the sweep).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        capacity: usize,
        mirror_count: usize,
        recorder: Arc<ThroughputRecorder>,
        progress: ProgressPolicy,
        sink_cfg: SinkConfig,
        pipeline_depth: usize,
        idle_reap_s: f64,
        trace: Option<WallTracer>,
    ) -> Result<Reactor> {
        let n_reactors = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .clamp(2, 8);
        let n_connectors = 4;
        let kill = KillSwitch::default();
        let gens: Arc<Vec<AtomicU64>> =
            Arc::new((0..capacity).map(|_| AtomicU64::new(0)).collect());
        let mirror_open: Arc<Vec<AtomicUsize>> =
            Arc::new((0..mirror_count.max(1)).map(|_| AtomicUsize::new(0)).collect());
        let (events_tx, events_rx) = channel::<TransportEvent>();

        // The sink writers hold event senders too (they ack completed
        // chunks), and they obey the same kill switch — so a dead
        // reactor pool still disconnects the engine's event channel.
        let mut joins = Vec::with_capacity(n_reactors + n_connectors + sink_cfg.threads);
        let sink = Arc::new(Sink::spawn(
            sink_cfg,
            events_tx.clone(),
            recorder.clone(),
            kill.clone(),
            trace.clone(),
            &mut joins,
        )?);

        let mut cmd_tx = Vec::with_capacity(n_reactors);
        let mut cmd_rx = Vec::with_capacity(n_reactors);
        for _ in 0..n_reactors {
            let (tx, rx) = channel::<Cmd>();
            cmd_tx.push(tx);
            cmd_rx.push(rx);
        }
        let mut connector_tx = Vec::with_capacity(n_connectors);
        let mut connector_rx = Vec::with_capacity(n_connectors);
        for _ in 0..n_connectors {
            let (tx, rx) = channel::<ConnectJob>();
            connector_tx.push(tx);
            connector_rx.push(rx);
        }

        for (i, rx) in cmd_rx.into_iter().enumerate() {
            let ctx = ReactorCtx {
                cmd_rx: rx,
                connector_tx: connector_tx.clone(),
                events_tx: events_tx.clone(),
                kill: kill.clone(),
                gens: gens.clone(),
                mirror_open: mirror_open.clone(),
                recorder: recorder.clone(),
                progress,
                sink: sink.clone(),
                hash: sink_cfg.hash,
                pipeline_depth: pipeline_depth.max(1),
                idle_reap: if idle_reap_s > 0.0 {
                    Duration::from_secs_f64(idle_reap_s)
                } else {
                    Duration::ZERO
                },
                trace: trace.clone(),
            };
            joins.push(
                std::thread::Builder::new()
                    .name(format!("dl-reactor-{i}"))
                    .spawn(move || reactor_loop(ctx))
                    .map_err(|e| Error::Session(format!("spawn reactor {i}: {e}")))?,
            );
        }
        // Only reactor and sink-writer threads hold event senders (all
        // bound to the same kill switch): when they have exited, the
        // engine's poll sees a disconnect and fails the session instead
        // of spinning forever.
        drop(events_tx);
        for (i, rx) in connector_rx.into_iter().enumerate() {
            let ctx = ConnectorCtx {
                job_rx: rx,
                reactor_tx: cmd_tx.clone(),
                kill: kill.clone(),
                gens: gens.clone(),
            };
            joins.push(
                std::thread::Builder::new()
                    .name(format!("dl-connect-{i}"))
                    .spawn(move || connector_loop(ctx))
                    .map_err(|e| Error::Session(format!("spawn connector {i}: {e}")))?,
            );
        }
        Ok(Reactor {
            cmd_tx,
            connector_tx,
            events_rx,
            joins,
            kill,
            gens,
            mirror_open,
            sink,
        })
    }

    /// Disk-path counters (write syscalls after coalescing, sink queue
    /// high-water mark, backpressure stall time).
    pub fn io_stats(&self) -> TransportIoStats {
        self.sink.io_stats()
    }

    /// A handle that can simulate the whole event loop dying.
    pub fn kill_switch(&self) -> KillSwitch {
        self.kill.clone()
    }

    /// Current open reservations against `mirror`.
    pub fn mirror_open(&self, mirror: usize) -> usize {
        self.mirror_open[gauge_idx(&self.mirror_open, mirror)].load(Ordering::SeqCst)
    }

    /// Take one reservation against `mirror`. The engine thread is the
    /// only caller, so check-then-reserve via [`Reactor::mirror_open`]
    /// is race-free (reactor threads only ever decrement).
    pub fn reserve(&self, mirror: usize) {
        self.mirror_open[gauge_idx(&self.mirror_open, mirror)].fetch_add(1, Ordering::SeqCst);
    }

    /// Release slot `slot`'s reservation against `mirror`: cancels any
    /// in-flight dial, closes the slot's socket, and decrements the
    /// gauge once the socket is actually gone.
    pub fn release(&self, slot: usize, mirror: usize) {
        self.gens[slot].fetch_add(1, Ordering::SeqCst);
        let dest = slot % self.cmd_tx.len();
        if self.cmd_tx[dest].send(Cmd::Release { slot, mirror }).is_err() {
            // Reactor thread already gone (teardown): settle here.
            dec_gauge(&self.mirror_open, mirror);
        }
    }

    /// Queue a fetch on the slot's owning reactor thread.
    pub fn fetch(&self, spec: FetchSpec) -> Result<()> {
        let dest = spec.slot % self.cmd_tx.len();
        self.cmd_tx[dest]
            .send(Cmd::Fetch(Box::new(spec)))
            .map_err(|_| Error::Session("real transport reactor is gone".into()))
    }

    /// Drain pending transport events. Errors when every reactor thread
    /// has exited — the engine must fail the session rather than wait
    /// for events that can never arrive.
    pub fn drain_events(&self, out: &mut Vec<TransportEvent>) -> Result<()> {
        loop {
            match self.events_rx.try_recv() {
                Ok(ev) => out.push(ev),
                Err(TryRecvError::Empty) => return Ok(()),
                Err(TryRecvError::Disconnected) => {
                    return Err(Error::Session(
                        "real transport event loop died: all reactor threads exited".into(),
                    ))
                }
            }
        }
    }

    /// Stop and join every thread (idempotent).
    pub fn shutdown(&mut self) {
        self.kill.kill();
        self.cmd_tx.clear();
        self.connector_tx.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn gauge_idx(gauges: &[AtomicUsize], mirror: usize) -> usize {
    mirror.min(gauges.len() - 1)
}

fn dec_gauge(gauges: &[AtomicUsize], mirror: usize) {
    let _ = gauges[gauge_idx(gauges, mirror)].fetch_update(
        Ordering::SeqCst,
        Ordering::SeqCst,
        |v| v.checked_sub(1),
    );
}

// ---------------------------------------------------------------------
// Connector threads: the blocking half of connection setup.
// ---------------------------------------------------------------------

fn connector_loop(ctx: ConnectorCtx) {
    loop {
        if ctx.kill.is_killed() {
            return;
        }
        let ConnectJob { slot, gen, spec } =
            match ctx.job_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(j) => j,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return,
            };
        // Skip the dial when the slot was released meanwhile — but
        // always send the adopt back so the reservation settles.
        let result = if ctx.gens[slot].load(Ordering::SeqCst) != gen {
            Err((FailureClass::Transport, "connect cancelled".to_string()))
        } else {
            dial(&spec)
        };
        let dest = slot % ctx.reactor_tx.len();
        let _ = ctx.reactor_tx[dest].send(Cmd::Adopt {
            slot,
            gen,
            spec,
            result,
        });
    }
}

/// Resolve + connect + flip non-blocking. Resolution is the explicit
/// blocking DNS step; its failures classify as retryable `Transport`
/// errors (a resolution outage heals).
fn dial(spec: &FetchSpec) -> std::result::Result<TcpStream, (FailureClass, String)> {
    let mut addrs = (spec.host.as_str(), spec.port)
        .to_socket_addrs()
        .map_err(|e| (FailureClass::Transport, format!("resolve {}: {e}", spec.host)))?;
    let addr = addrs.next().ok_or_else(|| {
        (
            FailureClass::Transport,
            format!("resolve {}: no addresses", spec.host),
        )
    })?;
    let stream = TcpStream::connect_timeout(&addr, CONNECT_TIMEOUT).map_err(|e| {
        (
            FailureClass::Transport,
            format!("connect {}:{}: {e}", spec.host, spec.port),
        )
    })?;
    stream
        .set_nodelay(true)
        .and_then(|_| stream.set_nonblocking(true))
        .map_err(|e| (FailureClass::Transport, format!("socket setup: {e}")))?;
    Ok(stream)
}

// ---------------------------------------------------------------------
// Reactor threads: poll loop + per-connection state machines.
// ---------------------------------------------------------------------

fn reactor_loop(ctx: ReactorCtx) {
    let mut conns: HashMap<usize, SlotState> = HashMap::new();
    let mut scratch = vec![0u8; SCRATCH_BYTES];
    let mut pollfds: Vec<sys::PollFd> = Vec::new();
    let mut poll_slots: Vec<usize> = Vec::new();
    let mut stalled: Vec<(usize, u64)> = Vec::new();
    let mut blocked: Vec<usize> = Vec::new();
    let mut stale_idle: Vec<usize> = Vec::new();
    loop {
        if ctx.kill.is_killed() {
            return;
        }
        loop {
            match ctx.cmd_rx.try_recv() {
                Ok(cmd) => handle_cmd(&mut conns, &ctx, cmd),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return,
            }
        }

        // Sink backpressure resume: connections parked in `Blocked`
        // retry their carried payload before the poll set is built, so
        // a round where *every* connection is parked still drains (the
        // empty-poll branch below `continue`s past the rest of the
        // loop).
        blocked.clear();
        for (&slot, st) in conns.iter() {
            if let SlotState::Conn(c) = st {
                if matches!(c.st, HttpState::Blocked { .. }) {
                    blocked.push(slot);
                }
            }
        }
        for slot in blocked.drain(..) {
            let fate = match conns.get_mut(&slot) {
                Some(SlotState::Conn(c)) => resume_blocked(c, &ctx),
                _ => continue,
            };
            settle(&mut conns, &ctx, slot, fate);
        }

        pollfds.clear();
        poll_slots.clear();
        for (&slot, st) in conns.iter() {
            if let SlotState::Conn(c) = st {
                if matches!(c.st, HttpState::Blocked { .. }) {
                    continue; // parked: let TCP flow control back off
                }
                let events = if matches!(c.st, HttpState::Sending { .. }) {
                    sys::POLLOUT
                } else {
                    sys::POLLIN
                };
                pollfds.push(sys::PollFd {
                    fd: c.stream.as_raw_fd(),
                    events,
                    revents: 0,
                });
                poll_slots.push(slot);
            }
        }

        if pollfds.is_empty() {
            // Nothing to poll: block briefly on the command channel.
            match ctx.cmd_rx.recv_timeout(Duration::from_millis(5)) {
                Ok(cmd) => handle_cmd(&mut conns, &ctx, cmd),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return,
            }
            continue;
        }

        // SAFETY: pollfds is a live, correctly sized `struct pollfd`
        // array; poll(2) writes only `revents`. A failure (-1, e.g.
        // EINTR) is treated as "no events this round".
        let n = unsafe {
            sys::poll(pollfds.as_mut_ptr(), pollfds.len() as sys::NfdsT, POLL_TIMEOUT_MS)
        };
        if n > 0 {
            for (pfd, &slot) in pollfds.iter().zip(poll_slots.iter()) {
                if pfd.revents == 0 {
                    continue;
                }
                let fate = match conns.get_mut(&slot) {
                    Some(SlotState::Conn(c)) => drive_conn(c, &mut scratch, &ctx),
                    _ => continue,
                };
                settle(&mut conns, &ctx, slot, fate);
            }
        }

        // Progress deadline: every non-idle connection must move bytes.
        if ctx.progress.window_s > 0.0 {
            stalled.clear();
            for (&slot, st) in conns.iter_mut() {
                if let SlotState::Conn(c) = st {
                    // Blocked is *local* backpressure (our disk, not
                    // the server) — it must not trip the deadline.
                    if matches!(c.st, HttpState::Idle | HttpState::Blocked { .. }) {
                        continue;
                    }
                    if c.window_start.elapsed().as_secs_f64() >= ctx.progress.window_s {
                        if c.window_bytes < ctx.progress.min_bytes {
                            stalled.push((slot, c.window_bytes));
                        } else {
                            c.window_start = Instant::now();
                            c.window_bytes = 0;
                        }
                    }
                }
            }
            for (slot, bytes) in stalled.drain(..) {
                conns.remove(&slot);
                let _ = ctx.events_tx.send(TransportEvent::Failed {
                    slot,
                    class: FailureClass::Transport,
                    error: format!(
                        "stalled: {bytes} bytes in {:.1}s (progress deadline)",
                        ctx.progress.window_s
                    ),
                });
            }
        }

        // Idle reap: keep-alive connections parked past the deadline
        // are closed silently — same semantics as the server dropping
        // an idle keep-alive, so the slot's next fetch just redials
        // under its existing reservation. Bounds the fds a long
        // campaign parks against capped mirrors.
        if ctx.idle_reap > Duration::ZERO {
            stale_idle.clear();
            for (&slot, st) in conns.iter() {
                if let SlotState::Conn(c) = st {
                    if matches!(c.st, HttpState::Idle) && c.idle_since.elapsed() >= ctx.idle_reap {
                        stale_idle.push(slot);
                    }
                }
            }
            for slot in stale_idle.drain(..) {
                conns.remove(&slot);
            }
        }
    }
}

fn handle_cmd(conns: &mut HashMap<usize, SlotState>, ctx: &ReactorCtx, cmd: Cmd) {
    match cmd {
        Cmd::Fetch(spec) => handle_fetch(conns, ctx, spec),
        Cmd::Release { slot, mirror } => match conns.get(&slot) {
            // Dial still in flight: the (now stale) adopt settles the
            // reservation when it lands.
            Some(SlotState::Connecting { .. }) => {}
            Some(SlotState::Conn(_)) => {
                conns.remove(&slot); // closes the socket
                dec_gauge(&ctx.mirror_open, mirror);
            }
            None => dec_gauge(&ctx.mirror_open, mirror),
        },
        Cmd::Adopt {
            slot,
            gen,
            spec,
            result,
        } => {
            if ctx.gens[slot].load(Ordering::SeqCst) != gen {
                // The engine released this slot while the dial ran: the
                // reservation the dial belonged to settles here.
                if matches!(conns.get(&slot), Some(SlotState::Connecting { gen: g }) if *g == gen) {
                    conns.remove(&slot);
                }
                dec_gauge(&ctx.mirror_open, spec.mirror);
                return; // any fresh socket drops (closes) with `result`
            }
            conns.remove(&slot); // the Connecting placeholder
            match result {
                Ok(stream) => {
                    let mut c = Conn {
                        stream,
                        host: spec.host.clone(),
                        port: spec.port,
                        st: HttpState::Idle,
                        spec: None,
                        out: None,
                        write_off: 0,
                        pending: None,
                        sink_gen: 0,
                        req_buf: Vec::new(),
                        queue: VecDeque::new(),
                        pipe_buf: Vec::new(),
                        pipe_sent: 0,
                        idle_since: Instant::now(),
                        window_start: Instant::now(),
                        window_bytes: 0,
                        hasher: None,
                    };
                    arm_fetch(&mut c, spec, ctx);
                    conns.insert(slot, SlotState::Conn(c));
                }
                Err((class, error)) => {
                    let _ = ctx
                        .events_tx
                        .send(TransportEvent::Failed { slot, class, error });
                }
            }
        }
    }
}

fn handle_fetch(conns: &mut HashMap<usize, SlotState>, ctx: &ReactorCtx, spec: Box<FetchSpec>) {
    let slot = spec.slot;
    enum Route {
        Reuse,
        Enqueue,
        CloseAndDial,
        Dial,
        WhileConnecting,
    }
    let route = match conns.get(&slot) {
        Some(SlotState::Conn(c))
            if matches!(c.st, HttpState::Idle) && c.host == spec.host && c.port == spec.port =>
        {
            Route::Reuse
        }
        // Pipelining: a fetch for the endpoint a busy connection is
        // already talking to rides the same socket — its request goes
        // on the wire now, its response is matched FIFO behind the
        // in-flight one. Checked before CloseAndDial so a train
        // extension can never tear down the connection carrying its
        // own head. Dead route at depth 1 (the engine never issues a
        // second fetch on an in-flight slot without pipelining).
        Some(SlotState::Conn(c))
            if ctx.pipeline_depth > 1
                && c.spec.is_some()
                && c.host == spec.host
                && c.port == spec.port =>
        {
            Route::Enqueue
        }
        Some(SlotState::Conn(_)) => Route::CloseAndDial,
        Some(SlotState::Connecting { .. }) => Route::WhileConnecting,
        None => Route::Dial,
    };
    match route {
        Route::Reuse => {
            if let Some(SlotState::Conn(c)) = conns.get_mut(&slot) {
                arm_fetch(c, spec, ctx);
            }
        }
        Route::Enqueue => {
            if let Some(SlotState::Conn(c)) = conns.get_mut(&slot) {
                enqueue_pipelined(c, spec, ctx);
            }
        }
        Route::CloseAndDial => {
            // Endpoint changed (mirror rebind) or the conn is in a bad
            // phase: drop the old socket — the slot's reservation
            // continues with the fresh dial.
            conns.remove(&slot);
            start_connect(conns, ctx, spec);
        }
        Route::Dial => start_connect(conns, ctx, spec),
        Route::WhileConnecting => {
            debug_assert!(false, "fetch on slot {slot} while a dial is in flight");
            let _ = ctx.events_tx.send(TransportEvent::Failed {
                slot,
                class: FailureClass::Transport,
                error: "fetch issued while the slot was still connecting".into(),
            });
        }
    }
}

fn start_connect(conns: &mut HashMap<usize, SlotState>, ctx: &ReactorCtx, spec: Box<FetchSpec>) {
    let slot = spec.slot;
    let gen = ctx.gens[slot].load(Ordering::SeqCst);
    conns.insert(slot, SlotState::Connecting { gen });
    let dest = slot % ctx.connector_tx.len();
    if ctx.connector_tx[dest].send(ConnectJob { slot, gen, spec }).is_err() {
        conns.remove(&slot);
        let _ = ctx.events_tx.send(TransportEvent::Failed {
            slot,
            class: FailureClass::Transport,
            error: "connector pool is gone".into(),
        });
    }
}

fn settle(conns: &mut HashMap<usize, SlotState>, ctx: &ReactorCtx, slot: usize, fate: Fate) {
    match fate {
        Fate::Keep => {}
        Fate::Completed(digest) => {
            let _ = ctx.events_tx.send(TransportEvent::Completed { slot, digest });
        }
        Fate::FailKeep(class, error) => {
            let _ = ctx
                .events_tx
                .send(TransportEvent::Failed { slot, class, error });
        }
        Fate::FailClose(class, error) => {
            conns.remove(&slot);
            let _ = ctx
                .events_tx
                .send(TransportEvent::Failed { slot, class, error });
        }
        Fate::CloseSilent => {
            conns.remove(&slot);
        }
    }
}

/// Prepare `c` (an idle connection) for a fetch: bind the preopened
/// output handle and rebuild the request bytes in the connection's
/// reused scratch — no file open, no allocation on the re-arm path.
fn arm_fetch(c: &mut Conn, spec: Box<FetchSpec>, ctx: &ReactorCtx) {
    c.req_buf.clear();
    build_request(&mut c.req_buf, &spec);
    bind_response(c, spec, ctx);
    c.st = HttpState::Sending { sent: 0 };
    trace_conn(ctx, c.spec.as_deref(), "sending");
}

/// Serialize `spec`'s request line + headers onto `buf`.
fn build_request(buf: &mut Vec<u8>, spec: &FetchSpec) {
    buf.extend_from_slice(b"GET ");
    buf.extend_from_slice(spec.path.as_bytes());
    buf.extend_from_slice(b" HTTP/1.1\r\nHost: ");
    buf.extend_from_slice(spec.host.as_bytes());
    buf.push(b':');
    write_decimal(buf, u64::from(spec.port));
    buf.extend_from_slice(b"\r\n");
    if let Some((offset, len)) = spec.range() {
        buf.extend_from_slice(b"Range: bytes=");
        write_decimal(buf, offset);
        buf.push(b'-');
        write_decimal(buf, offset + len - 1);
        buf.extend_from_slice(b"\r\n");
    }
    buf.extend_from_slice(b"Connection: keep-alive\r\n\r\n");
}

/// Bind `spec` as the response the connection expects next: output
/// handle, write cursor, sink generation, hasher, progress window. The
/// caller sets the HTTP state (`Sending` for a fresh request,
/// `Headers` when the request is already on the wire).
fn bind_response(c: &mut Conn, spec: Box<FetchSpec>, ctx: &ReactorCtx) {
    c.out = spec.out.clone();
    c.write_off = spec.chunk.offset;
    c.pending = None;
    c.sink_gen = ctx.sink.next_gen();
    // Reactor-side hashing only where the reactor also acks: discard
    // mode (no output handle) and the inline legacy mode. Sink-mode
    // chunks are hashed by the writer that acks them.
    c.hasher = if ctx.hash && (spec.out.is_none() || ctx.sink.is_inline()) {
        Some(Sha256::new())
    } else {
        None
    };
    c.spec = Some(spec);
    c.window_start = Instant::now();
    c.window_bytes = 0;
}

/// Pipeline a fetch behind the connection's in-flight request: its
/// request bytes are serialized and (opportunistically) written now,
/// its spec queued for FIFO response matching.
fn enqueue_pipelined(c: &mut Conn, spec: Box<FetchSpec>, ctx: &ReactorCtx) {
    build_request(&mut c.pipe_buf, &spec);
    trace_conn(ctx, Some(&spec), "pipelined");
    c.queue.push_back(spec);
    // Never interleave with the head request still being written.
    if !matches!(c.st, HttpState::Sending { .. }) {
        flush_pipelined(c);
    }
}

/// Write as much of the queued pipelined request bytes as the socket
/// accepts. Hard write errors are left for the read path to surface
/// (the state machine classifies them against the in-flight fetch).
fn flush_pipelined(c: &mut Conn) {
    while c.pipe_sent < c.pipe_buf.len() {
        match c.stream.write(&c.pipe_buf[c.pipe_sent..]) {
            Ok(0) => break,
            Ok(n) => c.pipe_sent += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    if c.pipe_sent == c.pipe_buf.len() {
        c.pipe_buf.clear();
        c.pipe_sent = 0;
    }
}

/// Append `v` in decimal ASCII without allocating.
fn write_decimal(buf: &mut Vec<u8>, mut v: u64) {
    let mut tmp = [0u8; 20];
    let mut i = tmp.len();
    loop {
        i -= 1;
        tmp[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    buf.extend_from_slice(&tmp[i..]);
}

/// Outcome of handing payload bytes toward the disk path.
enum Push {
    /// Every byte accepted. `deferred` = the chunk's `Completed` ack
    /// will come from a sink writer once the bytes land, not from the
    /// reactor.
    Done { deferred: bool },
    /// Buffer pool dry after accepting `taken` bytes: backpressure —
    /// park the connection in `Blocked` with the rest.
    Full { taken: usize },
}

/// Hand payload bytes toward the disk path. Discard mode credits the
/// recorder directly; inline mode (`sink_threads = 0`) writes
/// synchronously on this reactor thread (the measured legacy path);
/// sink mode copies into pooled buffers and hands full ones to the
/// writers, which credit the recorder and ack after the write lands.
/// `finish` marks the chunk's final bytes: the pending buffer is
/// flushed with `last = true` so the writer sends the completion.
fn push_payload(
    c: &mut Conn,
    data: &[u8],
    finish: bool,
    ctx: &ReactorCtx,
) -> std::result::Result<Push, Fate> {
    let Some(out) = c.out.clone() else {
        if let Some(h) = c.hasher.as_mut() {
            h.update(data);
        }
        ctx.recorder.add_bytes(data.len() as u64);
        return Ok(Push::Done { deferred: false });
    };
    if ctx.sink.is_inline() {
        if let Err(e) = ctx.sink.write_inline(&out, data, c.write_off) {
            return Err(Fate::FailClose(
                FailureClass::Fatal,
                format!("write {}: {e}", out.path.display()),
            ));
        }
        if let Some(h) = c.hasher.as_mut() {
            h.update(data);
        }
        c.write_off += data.len() as u64;
        ctx.recorder.add_bytes(data.len() as u64);
        return Ok(Push::Done { deferred: false });
    }
    let mut taken = 0;
    while taken < data.len() {
        if c.pending.as_ref().is_some_and(|b| b.is_full()) {
            flush_pending(c, ctx, false);
        }
        if c.pending.is_none() {
            match ctx.sink.try_buffer() {
                Some(b) => c.pending = Some(b),
                None => return Ok(Push::Full { taken }),
            }
        }
        taken += c.pending.as_mut().expect("buffer just ensured").push(&data[taken..]);
    }
    if finish && c.pending.is_some() {
        flush_pending(c, ctx, true);
        return Ok(Push::Done { deferred: true });
    }
    // `finish` with nothing pending can only mean a zero-length tail:
    // nothing was queued, so the reactor acks directly.
    Ok(Push::Done { deferred: false })
}

/// Hand `c`'s pending buffer to the sink writers. `last` marks the
/// chunk's final job (the writer acks `Completed` once it lands).
fn flush_pending(c: &mut Conn, ctx: &ReactorCtx, last: bool) {
    let Some(buf) = c.pending.take() else { return };
    if buf.is_empty() && !last {
        c.pending = Some(buf);
        return;
    }
    let len = buf.len() as u64;
    let slot = match c.spec.as_ref() {
        Some(s) => s.slot,
        None => return, // unreachable: a body in flight implies a spec
    };
    let Some(out) = c.out.clone() else { return };
    ctx.sink.submit(WriteJob {
        slot,
        gen: c.sink_gen,
        file: out,
        offset: c.write_off,
        buf,
        last,
    });
    c.write_off += len;
}

/// Chunk fully received (and, on the sink path, fully handed off):
/// park the connection Idle for keep-alive reuse. `deferred` means a
/// sink writer sends the `Completed` ack after the final write lands;
/// otherwise the reactor acks now.
fn finish_chunk(c: &mut Conn, deferred: bool, ctx: &ReactorCtx) -> Fate {
    let fate = if deferred {
        // The sink writer acks (and carries the digest it streamed).
        c.hasher = None;
        Fate::Keep
    } else {
        Fate::Completed(c.hasher.take().map(|h| h.finalize()))
    };
    c.out = None;
    if let Some(next) = c.queue.pop_front() {
        // The next pipelined request is already on the wire: its
        // response head is what this socket delivers next.
        c.spec = None;
        bind_response(c, next, ctx);
        c.st = HttpState::Headers { head: Vec::new() };
        trace_conn(ctx, c.spec.as_deref(), "headers");
    } else {
        trace_conn(ctx, c.spec.as_deref(), "idle");
        c.spec = None;
        c.st = HttpState::Idle;
        c.idle_since = Instant::now();
    }
    fate
}

/// Retry a `Blocked` connection's carried payload. Progress means a
/// buffer freed up: record the parked time as reactor stall, reset the
/// progress window (the pause was our disk, not the server), and
/// return to `Body` — or finish the chunk if the carry was its tail.
fn resume_blocked(c: &mut Conn, ctx: &ReactorCtx) -> Fate {
    let st = std::mem::replace(&mut c.st, HttpState::Idle);
    let HttpState::Blocked {
        remaining,
        mut carry,
        since,
    } = st
    else {
        c.st = st;
        return Fate::Keep;
    };
    let finish = remaining == 0;
    match push_payload(c, &carry, finish, ctx) {
        Ok(Push::Done { deferred }) => {
            ctx.sink.note_stall(since.elapsed());
            c.window_start = Instant::now();
            c.window_bytes = 0;
            if finish {
                finish_chunk(c, deferred, ctx)
            } else {
                c.st = HttpState::Body { remaining };
                trace_conn(ctx, c.spec.as_deref(), "body");
                Fate::Keep
            }
        }
        Ok(Push::Full { taken }) => {
            carry.drain(..taken);
            c.st = HttpState::Blocked {
                remaining,
                carry,
                since,
            };
            Fate::Keep
        }
        Err(fate) => fate,
    }
}

/// Parse a response head (status line + headers, no trailing blank
/// line) into `(status, content_length)` — byte-level, so the hot path
/// allocates only when building an error message.
fn parse_head(head: &[u8]) -> std::result::Result<(u16, u64), String> {
    let (status_line, mut rest) = split_line(head);
    let status = parse_status(status_line).ok_or_else(|| {
        format!("bad status line {:?}", String::from_utf8_lossy(status_line))
    })?;
    let mut content_length: Option<u64> = None;
    while !rest.is_empty() {
        let (line, tail) = split_line(rest);
        rest = tail;
        if let Some(pos) = line.iter().position(|&b| b == b':') {
            if trim_ascii(&line[..pos]).eq_ignore_ascii_case(b"content-length") {
                content_length = parse_u64(trim_ascii(&line[pos + 1..]));
            }
        }
    }
    let content_length =
        content_length.ok_or_else(|| "response without Content-Length".to_string())?;
    Ok((status, content_length))
}

/// Split at the first CRLF: `(line, rest-after-crlf)`. Without a CRLF
/// the whole slice is the line.
fn split_line(buf: &[u8]) -> (&[u8], &[u8]) {
    match buf.windows(2).position(|w| w == b"\r\n") {
        Some(pos) => (&buf[..pos], &buf[pos + 2..]),
        None => (buf, &[]),
    }
}

/// Second whitespace-separated token of the status line, as the HTTP
/// status code.
fn parse_status(line: &[u8]) -> Option<u16> {
    let code = line
        .split(|&b| b == b' ' || b == b'\t')
        .filter(|f| !f.is_empty())
        .nth(1)
        .and_then(parse_u64)?;
    u16::try_from(code).ok()
}

/// Decimal ASCII → `u64`; the whole slice must be digits.
fn parse_u64(digits: &[u8]) -> Option<u64> {
    if digits.is_empty() {
        return None;
    }
    let mut v: u64 = 0;
    for &b in digits {
        if !b.is_ascii_digit() {
            return None;
        }
        v = v.checked_mul(10)?.checked_add(u64::from(b - b'0'))?;
    }
    Some(v)
}

/// Strip leading/trailing ASCII whitespace without allocating.
fn trim_ascii(mut s: &[u8]) -> &[u8] {
    while let [b, rest @ ..] = s {
        if !b.is_ascii_whitespace() {
            break;
        }
        s = rest;
    }
    while let [rest @ .., b] = s {
        if !b.is_ascii_whitespace() {
            break;
        }
        s = rest;
    }
    s
}

/// Classify the parsed response head and move the connection into
/// `Body`/`Drain`, feeding any bytes that arrived glued to the head.
/// `None` means the state advanced and the drive loop continues.
fn begin_body(c: &mut Conn, head: &[u8], leftover: &[u8], ctx: &ReactorCtx) -> Option<Fate> {
    let (status, content_length) = match parse_head(head) {
        Ok(v) => v,
        Err(msg) => return Some(Fate::FailClose(FailureClass::Transport, msg)),
    };
    let (chunk_len, path, range) = match c.spec.as_ref() {
        Some(s) => (s.chunk.len, s.path.clone(), s.range()),
        None => {
            return Some(Fate::FailClose(
                FailureClass::Transport,
                "response without a fetch in flight".into(),
            ))
        }
    };
    if (leftover.len() as u64) > content_length {
        return Some(Fate::FailClose(
            FailureClass::Transport,
            "server sent more bytes than advertised".into(),
        ));
    }
    if status == 200 || status == 206 {
        if content_length != chunk_len {
            return Some(Fate::FailClose(
                FailureClass::Transport,
                format!("GET {path}: short body {content_length} of {chunk_len} bytes"),
            ));
        }
        let mut remaining = content_length;
        if !leftover.is_empty() {
            remaining -= leftover.len() as u64;
            let finish = remaining == 0;
            match push_payload(c, leftover, finish, ctx) {
                Ok(Push::Done { deferred }) => {
                    if finish {
                        return Some(finish_chunk(c, deferred, ctx));
                    }
                }
                Ok(Push::Full { taken }) => {
                    c.st = HttpState::Blocked {
                        remaining,
                        carry: leftover[taken..].to_vec(),
                        since: Instant::now(),
                    };
                    trace_conn(ctx, c.spec.as_deref(), "blocked");
                    return Some(Fate::Keep);
                }
                Err(fate) => return Some(fate),
            }
        }
        if remaining == 0 {
            return Some(finish_chunk(c, false, ctx));
        }
        c.st = HttpState::Body { remaining };
        trace_conn(ctx, c.spec.as_deref(), "body");
        None
    } else {
        let class = if status >= 500 {
            // Transient server error: retryable, connection survives.
            FailureClass::Reject
        } else {
            // 4xx and friends are deterministic: retrying cannot help.
            FailureClass::Fatal
        };
        let error = format!("GET {path} range {range:?}: HTTP {status}");
        c.out = None;
        c.pending = None;
        c.st = HttpState::Drain {
            remaining: content_length - leftover.len() as u64,
            class,
            error,
        };
        trace_conn(ctx, c.spec.as_deref(), "drain");
        None
    }
}

/// Advance one connection's state machine until it would block.
fn drive_conn(c: &mut Conn, scratch: &mut [u8], ctx: &ReactorCtx) -> Fate {
    loop {
        // Push any queued pipelined request bytes that did not fit at
        // enqueue time — but never before the head request is fully
        // written, or the streams would interleave.
        if !c.pipe_buf.is_empty() && !matches!(c.st, HttpState::Sending { .. }) {
            flush_pipelined(c);
        }
        let st = std::mem::replace(&mut c.st, HttpState::Idle);
        match st {
            HttpState::Idle => {
                // Data or close on a parked keep-alive connection: the
                // server is done with us; drop quietly (the next fetch
                // redials under the same reservation).
                return match c.stream.read(scratch) {
                    Ok(_) => Fate::CloseSilent,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => Fate::Keep,
                    Err(_) => Fate::CloseSilent,
                };
            }
            HttpState::Sending { mut sent } => match c.stream.write(&c.req_buf[sent..]) {
                Ok(0) => {
                    return Fate::FailClose(
                        FailureClass::Transport,
                        "send request: connection closed".into(),
                    )
                }
                Ok(n) => {
                    sent += n;
                    if sent == c.req_buf.len() {
                        c.st = HttpState::Headers { head: Vec::new() };
                    } else {
                        c.st = HttpState::Sending { sent };
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    c.st = HttpState::Sending { sent };
                    return Fate::Keep;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {
                    c.st = HttpState::Sending { sent };
                }
                Err(e) => {
                    return Fate::FailClose(FailureClass::Transport, format!("send request: {e}"))
                }
            },
            HttpState::Headers { mut head } => match c.stream.read(scratch) {
                Ok(0) => {
                    return Fate::FailClose(
                        FailureClass::Transport,
                        "server closed connection".into(),
                    )
                }
                Ok(n) => {
                    c.window_bytes += n as u64;
                    head.extend_from_slice(&scratch[..n]);
                    if let Some(pos) = head.windows(4).position(|w| w == b"\r\n\r\n") {
                        let leftover = head.split_off(pos + 4);
                        if let Some(fate) = begin_body(c, &head[..pos], &leftover, ctx) {
                            return fate;
                        }
                        // State advanced to Body/Drain: keep driving.
                    } else if head.len() > MAX_HEAD_BYTES {
                        return Fate::FailClose(
                            FailureClass::Transport,
                            "response head too large".into(),
                        );
                    } else {
                        c.st = HttpState::Headers { head };
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    c.st = HttpState::Headers { head };
                    return Fate::Keep;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {
                    c.st = HttpState::Headers { head };
                }
                Err(e) => {
                    return Fate::FailClose(FailureClass::Transport, format!("read head: {e}"))
                }
            },
            HttpState::Body { mut remaining } => {
                let want = scratch.len().min(remaining as usize);
                match c.stream.read(&mut scratch[..want]) {
                    Ok(0) => {
                        return Fate::FailClose(
                            FailureClass::Transport,
                            format!("connection closed mid-body ({remaining} bytes left)"),
                        )
                    }
                    Ok(n) => {
                        c.window_bytes += n as u64;
                        remaining -= n as u64;
                        let finish = remaining == 0;
                        match push_payload(c, &scratch[..n], finish, ctx) {
                            Ok(Push::Done { deferred }) => {
                                if finish {
                                    return finish_chunk(c, deferred, ctx);
                                }
                                c.st = HttpState::Body { remaining };
                            }
                            Ok(Push::Full { taken }) => {
                                c.st = HttpState::Blocked {
                                    remaining,
                                    carry: scratch[taken..n].to_vec(),
                                    since: Instant::now(),
                                };
                                trace_conn(ctx, c.spec.as_deref(), "blocked");
                                return Fate::Keep;
                            }
                            Err(fate) => return fate,
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        c.st = HttpState::Body { remaining };
                        return Fate::Keep;
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {
                        c.st = HttpState::Body { remaining };
                    }
                    Err(e) => {
                        return Fate::FailClose(
                            FailureClass::Transport,
                            format!("read body: {e}"),
                        )
                    }
                }
            }
            HttpState::Blocked {
                remaining,
                carry,
                since,
            } => {
                // Parked connections are excluded from the poll set;
                // the resume sweep (not the poll path) drives them.
                c.st = HttpState::Blocked {
                    remaining,
                    carry,
                    since,
                };
                return Fate::Keep;
            }
            HttpState::Drain {
                mut remaining,
                class,
                error,
            } => {
                if remaining == 0 {
                    c.out = None;
                    c.pending = None;
                    if let Some(next) = c.queue.pop_front() {
                        // The drained error consumed one FIFO response;
                        // the next pipelined request's response follows
                        // on the same socket.
                        c.spec = None;
                        bind_response(c, next, ctx);
                        c.st = HttpState::Headers { head: Vec::new() };
                        trace_conn(ctx, c.spec.as_deref(), "headers");
                    } else {
                        trace_conn(ctx, c.spec.as_deref(), "idle");
                        c.spec = None;
                        c.st = HttpState::Idle;
                        c.idle_since = Instant::now();
                    }
                    return Fate::FailKeep(class, error);
                }
                let want = scratch.len().min(remaining as usize);
                match c.stream.read(&mut scratch[..want]) {
                    Ok(0) => return Fate::FailClose(class, error),
                    Ok(n) => {
                        // Deliberately *not* counted toward the
                        // progress window: a server dribbling an error
                        // body must still trip the ProgressPolicy
                        // deadline instead of pinning the slot.
                        remaining -= n as u64;
                        c.st = HttpState::Drain {
                            remaining,
                            class,
                            error,
                        };
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        c.st = HttpState::Drain {
                            remaining,
                            class,
                            error,
                        };
                        return Fate::Keep;
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {
                        c.st = HttpState::Drain {
                            remaining,
                            class,
                            error,
                        };
                    }
                    Err(_) => return Fate::FailClose(class, error),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_parsing() {
        let head = b"HTTP/1.1 206 Partial\r\nContent-Length: 42\r\nContent-Range: bytes 0-41/84";
        assert_eq!(parse_head(head).unwrap(), (206, 42));
        let head = b"HTTP/1.1 503 Unavailable\r\ncontent-length: 9";
        assert_eq!(parse_head(head).unwrap(), (503, 9));
        assert!(parse_head(b"garbage").is_err());
        assert!(parse_head(b"HTTP/1.1 200 OK\r\nX: y").is_err());
    }

    #[test]
    fn range_header_skipped_for_whole_file() {
        let chunk = Chunk {
            file: 0,
            index: 0,
            offset: 0,
            len: 100,
            cold: true,
            train: false,
        };
        let spec = FetchSpec {
            slot: 0,
            host: "h".into(),
            port: 80,
            path: "/x".into(),
            out: None,
            chunk,
            total_bytes: 100,
            mirror: 0,
        };
        assert_eq!(spec.range(), None);
        let spec = FetchSpec {
            chunk: Chunk {
                file: 0,
                index: 1,
                offset: 50,
                len: 50,
                cold: false,
                train: false,
            },
            ..spec
        };
        assert_eq!(spec.range(), Some((50, 50)));
    }

    #[test]
    fn kill_switch_flips_once() {
        let k = KillSwitch::default();
        assert!(!k.is_killed());
        let k2 = k.clone();
        k2.kill();
        assert!(k.is_killed());
    }

    #[test]
    fn decimal_formatting_matches_display() {
        for v in [0u64, 7, 10, 80, 65535, 123_456_789, u64::MAX] {
            let mut buf = Vec::new();
            write_decimal(&mut buf, v);
            assert_eq!(buf, v.to_string().into_bytes());
        }
    }

    #[test]
    fn drain_reads_do_not_count_as_progress() {
        // A dribbling error body must not feed the progress window —
        // otherwise a slow Drain pins the slot past every deadline.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut peer = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_nonblocking(true).unwrap();

        let (_cmd_tx, cmd_rx) = channel::<Cmd>();
        let (events_tx, _events_rx) = channel::<TransportEvent>();
        let mut joins = Vec::new();
        let sink = Sink::spawn(
            SinkConfig {
                threads: 0,
                ..SinkConfig::default()
            },
            events_tx.clone(),
            Arc::new(ThroughputRecorder::new()),
            KillSwitch::default(),
            None,
            &mut joins,
        )
        .unwrap();
        let ctx = ReactorCtx {
            cmd_rx,
            connector_tx: Vec::new(),
            events_tx,
            kill: KillSwitch::default(),
            gens: Arc::new(Vec::new()),
            mirror_open: Arc::new(vec![AtomicUsize::new(0)]),
            recorder: Arc::new(ThroughputRecorder::new()),
            progress: ProgressPolicy {
                window_s: 30.0,
                min_bytes: 1,
            },
            sink: Arc::new(sink),
            hash: false,
            pipeline_depth: 1,
            idle_reap: Duration::from_secs_f64(IDLE_REAP_DEFAULT_S),
            trace: None,
        };
        let mut c = Conn {
            stream,
            host: "127.0.0.1".into(),
            port: addr.port(),
            st: HttpState::Drain {
                remaining: 1 << 20,
                class: FailureClass::Reject,
                error: "HTTP 503".into(),
            },
            spec: None,
            out: None,
            write_off: 0,
            pending: None,
            sink_gen: 0,
            req_buf: Vec::new(),
            queue: VecDeque::new(),
            pipe_buf: Vec::new(),
            pipe_sent: 0,
            idle_since: Instant::now(),
            window_start: Instant::now(),
            window_bytes: 0,
            hasher: None,
        };
        peer.write_all(&[0u8; 4096]).unwrap();
        peer.flush().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let mut scratch = vec![0u8; SCRATCH_BYTES];
        let fate = drive_conn(&mut c, &mut scratch, &ctx);
        assert!(matches!(fate, Fate::Keep));
        assert!(matches!(c.st, HttpState::Drain { .. }));
        assert_eq!(c.window_bytes, 0, "drain bytes must not count as progress");
    }

    #[test]
    fn completed_head_binds_next_pipelined_response() {
        // A pipelined connection whose head finishes must flip straight
        // to `Headers` for the queued spec — the next response on the
        // socket belongs to it, not to an idle keep-alive.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut peer = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_nonblocking(true).unwrap();

        let (_cmd_tx, cmd_rx) = channel::<Cmd>();
        let (events_tx, _events_rx) = channel::<TransportEvent>();
        let mut joins = Vec::new();
        let sink = Sink::spawn(
            SinkConfig {
                threads: 0,
                ..SinkConfig::default()
            },
            events_tx.clone(),
            Arc::new(ThroughputRecorder::new()),
            KillSwitch::default(),
            None,
            &mut joins,
        )
        .unwrap();
        let ctx = ReactorCtx {
            cmd_rx,
            connector_tx: Vec::new(),
            events_tx,
            kill: KillSwitch::default(),
            gens: Arc::new(Vec::new()),
            mirror_open: Arc::new(vec![AtomicUsize::new(0)]),
            recorder: Arc::new(ThroughputRecorder::new()),
            progress: ProgressPolicy {
                window_s: 30.0,
                min_bytes: 1,
            },
            sink: Arc::new(sink),
            hash: false,
            pipeline_depth: 4,
            idle_reap: Duration::from_secs_f64(IDLE_REAP_DEFAULT_S),
            trace: None,
        };
        let head = FetchSpec {
            slot: 0,
            host: "127.0.0.1".into(),
            port: addr.port(),
            path: "/a".into(),
            out: None,
            chunk: Chunk {
                file: 0,
                index: 0,
                offset: 0,
                len: 4,
                cold: true,
                train: true,
            },
            total_bytes: 4,
            mirror: 0,
        };
        let next = FetchSpec {
            slot: 0,
            host: "127.0.0.1".into(),
            port: addr.port(),
            path: "/b".into(),
            out: None,
            chunk: Chunk {
                file: 1,
                index: 0,
                offset: 0,
                len: 8,
                cold: true,
                train: true,
            },
            total_bytes: 8,
            mirror: 0,
        };
        let mut c = Conn {
            stream,
            host: "127.0.0.1".into(),
            port: addr.port(),
            st: HttpState::Body { remaining: 4 },
            spec: Some(Box::new(head)),
            out: None,
            write_off: 0,
            pending: None,
            sink_gen: 0,
            req_buf: Vec::new(),
            queue: VecDeque::from([Box::new(next)]),
            pipe_buf: Vec::new(),
            pipe_sent: 0,
            idle_since: Instant::now(),
            window_start: Instant::now(),
            window_bytes: 0,
            hasher: None,
        };
        peer.write_all(b"DATA").unwrap();
        peer.flush().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let mut scratch = vec![0u8; SCRATCH_BYTES];
        let fate = drive_conn(&mut c, &mut scratch, &ctx);
        assert!(matches!(fate, Fate::Completed(None)));
        assert!(matches!(c.st, HttpState::Headers { .. }));
        assert_eq!(c.spec.as_ref().unwrap().path, "/b");
        assert!(c.queue.is_empty());
    }
}
