//! The blocking chunk-fetch data path: a persistent HTTP connection,
//! range requests, sink writing, and failure classification.
//!
//! The live real-session driver now runs on the event-driven
//! [`crate::transport::reactor`]; this blocking fetcher remains as the
//! simple one-connection path and as the reference implementation of
//! the failure taxonomy the reactor's non-blocking state machine
//! mirrors. The engine decides *what* to fetch and from *which* mirror
//! (striping slot bindings across healthy mirrors under per-mirror
//! connection caps — see [`crate::session::real::RealTransport`]);
//! [`ChunkFetcher`] moves the bytes and sorts every failure into the
//! engine's [`FailureClass`] taxonomy —
//! connection-level errors reconnect and retry, transient 5xx responses
//! retry after backoff, deterministic errors (bad URL, 4xx, local I/O)
//! fail the session immediately. Because the connection is keyed by
//! `(host, port)`, a mirror switch on the next assignment transparently
//! reconnects to the new endpoint.

use std::io::{Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::scheduler::Chunk;
use crate::metrics::recorder::ThroughputRecorder;
use crate::session::engine::FailureClass;
use crate::transport::http_client::HttpConnection;

/// Connect timeout for outbound connections (shared with the
/// event-driven reactor's connector pool).
pub(crate) const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// A classified fetch failure.
pub type FetchError = (FailureClass, String);

/// A worker's reusable fetch state: at most one open connection, keyed
/// by `(host, port)` so mirror switches transparently reconnect.
pub struct ChunkFetcher {
    conn: Option<(String, u16, HttpConnection)>,
    recorder: Arc<ThroughputRecorder>,
}

impl ChunkFetcher {
    pub fn new(recorder: Arc<ThroughputRecorder>) -> ChunkFetcher {
        ChunkFetcher {
            conn: None,
            recorder,
        }
    }

    /// Drop the connection (parking, mirror switch, failure recovery).
    pub fn disconnect(&mut self) {
        self.conn = None;
    }

    /// Fetch `chunk` of the `total_bytes`-sized object at `url`,
    /// feeding delivered bytes into the shared recorder and, when `out`
    /// is given, writing them at the chunk's offset in that file.
    pub fn fetch(
        &mut self,
        url: &str,
        out: Option<&Path>,
        chunk: &Chunk,
        total_bytes: u64,
    ) -> std::result::Result<(), FetchError> {
        // A URL that doesn't parse can never succeed: fatal, not retried.
        let (host, port, path) = HttpConnection::split_url(url)
            .map_err(|e| (FailureClass::Fatal, e.to_string()))?;

        let reuse = matches!(&self.conn, Some((h, p, _)) if *h == host && *p == port);
        if !reuse {
            let c = HttpConnection::connect(&host, port, CONNECT_TIMEOUT)
                .map_err(|e| (FailureClass::Transport, e.to_string()))?;
            self.conn = Some((host.clone(), port, c));
        }
        let c = &mut self.conn.as_mut().expect("connection just ensured").2;

        // Output plumbing. Local I/O failures are deterministic: fatal.
        let mut file = match out {
            None => None,
            Some(path) => {
                let open = || -> std::io::Result<std::fs::File> {
                    let mut f = std::fs::OpenOptions::new().write(true).open(path)?;
                    f.seek(SeekFrom::Start(chunk.offset))?;
                    Ok(f)
                };
                Some(open().map_err(|e| {
                    (FailureClass::Fatal, format!("open {}: {e}", path.display()))
                })?)
            }
        };

        let range = if chunk.offset == 0 && chunk.len == total_bytes {
            None // whole file
        } else {
            Some((chunk.offset, chunk.len))
        };
        let recorder = self.recorder.clone();
        let mut written: u64 = 0;
        let resp = c
            .get_range(&path, range, |block| {
                recorder.add_bytes(block.len() as u64);
                written += block.len() as u64;
                if let Some(f) = &mut file {
                    // Errors surface through the length check below.
                    let _ = f.write_all(block);
                }
            })
            .map_err(|e| (FailureClass::Transport, e.to_string()))?;
        if resp.status >= 500 {
            // Transient server error: retryable, counted separately.
            return Err((
                FailureClass::Reject,
                format!("GET {path} range {range:?}: HTTP {}", resp.status),
            ));
        }
        if !(resp.status == 200 || resp.status == 206) {
            // 4xx and friends are deterministic: retrying cannot help.
            return Err((
                FailureClass::Fatal,
                format!("GET {path} range {range:?}: HTTP {}", resp.status),
            ));
        }
        if written != chunk.len {
            return Err((
                FailureClass::Transport,
                format!("GET {path}: short body {written} of {} bytes", chunk.len),
            ));
        }
        Ok(())
    }
}
