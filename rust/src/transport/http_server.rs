//! Throttled local HTTP/1.1 server — the loopback stand-in for an
//! ENA/NCBI mirror.
//!
//! Serves deterministic synthetic payloads (seeded xoshiro bytes, so
//! the client can verify content integrity without storing gigabytes),
//! honors `Range` requests and keep-alive, and throttles through token
//! buckets: one per connection (the per-stream server cap) and one
//! global (the bottleneck link). Optional artificial first-byte latency
//! models cold-object staging.
//!
//! Thread-per-connection; connections are bounded. This is test/bench
//! infrastructure — it prioritizes predictability over raw speed, but
//! still saturates several Gbps on loopback (see EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::transport::token_bucket::TokenBucket;
use crate::util::prng::Prng;
use crate::{Error, Result};

/// One file the server knows how to serve.
#[derive(Clone, Debug)]
pub struct ServedFile {
    /// URL path (`/vol1/srr/SRR000001`).
    pub path: String,
    /// Payload size (bytes).
    pub bytes: u64,
    /// Content seed — byte `i` of the payload is
    /// `seeded_byte(seed, i)`, so any range is generated on the fly.
    pub seed: u64,
}

/// One scheduled server-side fault window, expressed over server
/// uptime. Requests arriving inside `[from_s, until_s)` are rejected
/// with HTTP 503 with probability `reject_prob` (deterministic in the
/// request counter given `ThrottleConfig::fault_seed`) and/or delayed
/// by `added_latency_s` before the response starts — the real-transport
/// replay of the simulator's 5xx/brownout/stall fault classes.
///
/// A window is **per-mirror** when `path_prefix` is set: it then only
/// applies to requests whose URL path starts with that prefix, so one
/// loopback server can stand in for several mirrors (by convention,
/// mirror `m` serves under `/m{m}/...`) and degrade one of them while
/// the others stay healthy. `None` keeps the PR 2 behaviour: the
/// window applies to every request.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServerFaultWindow {
    pub from_s: f64,
    pub until_s: f64,
    /// Probability a request inside the window is answered 503.
    pub reject_prob: f64,
    /// Extra first-byte latency for requests inside the window (s).
    pub added_latency_s: f64,
    /// Restrict the window to request paths starting with this prefix
    /// (`None` = all paths — a global, every-mirror window).
    pub path_prefix: Option<String>,
    /// Dribble mode: while the window is active, responses trickle
    /// payload at this rate (bytes/s) instead of streaming normally —
    /// the connection stays alive and technically moves bytes, but far
    /// too slowly to matter. `0` (the default) disables dribbling.
    /// This is the loopback reproduction of the pathological stall the
    /// client's whole-chunk progress deadline exists to catch.
    pub dribble_bytes_per_s: u64,
    /// Silent corruption: probability a response starting inside the
    /// window carries a flipped payload byte. The transfer itself
    /// succeeds — correct status, correct length — so only client-side
    /// hash verification can notice. The loopback counterpart of the
    /// simulator's [`crate::netsim::FaultKind`] `BitFlip`.
    pub corrupt_prob: f64,
}

/// Server throttling knobs.
#[derive(Clone, Debug)]
pub struct ThrottleConfig {
    /// Per-connection ceiling (bytes/s); 0 = unlimited.
    pub per_conn_bytes_per_s: f64,
    /// Global ceiling across connections (bytes/s); 0 = unlimited.
    pub global_bytes_per_s: f64,
    /// Artificial time-to-first-byte per request (s).
    pub first_byte_latency_s: f64,
    /// Max simultaneous connections.
    pub max_connections: usize,
    /// Fault injection: abort the TCP connection once a single response
    /// has streamed this many payload bytes (0 = disabled). The client
    /// sees a short body / reset mid-transfer and must retry.
    pub fault_drop_after_bytes: u64,
    /// Budget of mid-body drops to inject server-wide before the fault
    /// "heals" (with `fault_drop_after_bytes > 0`).
    pub fault_drop_count: usize,
    /// Optional active window for the `fault_drop_*` knobs, in seconds
    /// of server uptime: with `fault_drop_window_s > 0`, mid-body drops
    /// are only injected while
    /// `uptime ∈ [fault_drop_window_start_s, start + window_s)` — the
    /// real-socket counterpart of the simulator's time-windowed
    /// [`crate::netsim::FaultKind`] `MidBodyDrop`. The budget still
    /// applies inside the window. `0` (the default) keeps the original
    /// budget-only behaviour: drops can fire at any time.
    pub fault_drop_window_start_s: f64,
    /// Window length (s); see `fault_drop_window_start_s`.
    pub fault_drop_window_s: f64,
    /// Scheduled 5xx / added-latency windows over server uptime.
    pub fault_windows: Vec<ServerFaultWindow>,
    /// Seed for the per-request 503 draws inside `fault_windows`.
    pub fault_seed: u64,
}

impl Default for ThrottleConfig {
    fn default() -> Self {
        ThrottleConfig {
            per_conn_bytes_per_s: 0.0,
            global_bytes_per_s: 0.0,
            first_byte_latency_s: 0.0,
            max_connections: 64,
            fault_drop_after_bytes: 0,
            fault_drop_count: 0,
            fault_drop_window_start_s: 0.0,
            fault_drop_window_s: 0.0,
            fault_windows: Vec::new(),
            fault_seed: 0,
        }
    }
}

impl ThrottleConfig {
    /// Overlay a named simulator fault profile onto the server: the
    /// profile's schedule is expanded deterministically (same expansion
    /// the `--faults` flag uses for simulated downloads) and its
    /// server-side classes are mapped onto loopback knobs —
    /// `ServerError` → 503 windows, `Brownout` → reject-everything
    /// windows, `Stall` → added first-byte latency. Connection-level
    /// classes (resets, rate collapses, flash crowds) have no HTTP
    /// analogue here; mid-body resets remain available through the
    /// `fault_drop_*` knobs.
    pub fn with_fault_profile(
        mut self,
        profile: crate::netsim::FaultProfile,
        seed: u64,
        horizon_s: f64,
    ) -> ThrottleConfig {
        self.fault_windows =
            fault_windows_from_schedule(&profile.schedule(seed, horizon_s, 1_000.0));
        self.fault_seed = seed;
        self
    }
}

/// Map a simulator [`crate::netsim::FaultSchedule`] onto server-side
/// fault windows (see [`ThrottleConfig::with_fault_profile`]).
///
/// `ServerError`/`Brownout`/`Stall` map to global windows exactly as
/// before; the per-flow asymmetric [`crate::netsim::FaultKind`]
/// `SlowMirror` maps to a **per-mirror** window scoped to the
/// `/m{mirror}/` path prefix (the convention multi-mirror loopback
/// tests register their files under): the degraded mirror answers each
/// request only after an added latency that scales with the severity
/// (`1/factor`), while every other path stays healthy.
pub fn fault_windows_from_schedule(
    schedule: &crate::netsim::FaultSchedule,
) -> Vec<ServerFaultWindow> {
    use crate::netsim::FaultKind;
    let mut out = Vec::new();
    for ev in schedule.events() {
        match &ev.kind {
            FaultKind::ServerError {
                reject_prob,
                duration_s,
            } => out.push(ServerFaultWindow {
                from_s: ev.at_s,
                until_s: ev.at_s + duration_s,
                reject_prob: *reject_prob,
                ..ServerFaultWindow::default()
            }),
            FaultKind::Brownout { duration_s } => out.push(ServerFaultWindow {
                from_s: ev.at_s,
                until_s: ev.at_s + duration_s,
                reject_prob: 1.0,
                ..ServerFaultWindow::default()
            }),
            FaultKind::Stall { frac, duration_s } => out.push(ServerFaultWindow {
                from_s: ev.at_s,
                until_s: ev.at_s + duration_s,
                // A head-of-line stall shows up as first-byte delay on
                // loopback; cap it so tests stay fast.
                added_latency_s: (frac * duration_s).min(2.0),
                ..ServerFaultWindow::default()
            }),
            FaultKind::SlowMirror {
                mirror,
                factor,
                duration_s,
            } => out.push(ServerFaultWindow {
                from_s: ev.at_s,
                until_s: ev.at_s + duration_s,
                // Per-request staging delay as the loopback analogue
                // of a rate collapse; capped so tests stay fast.
                added_latency_s: (0.1 / factor.max(1e-3)).min(2.0),
                path_prefix: Some(format!("/m{mirror}/")),
                ..ServerFaultWindow::default()
            }),
            FaultKind::BitFlip { frac, duration_s } => out.push(ServerFaultWindow {
                from_s: ev.at_s,
                until_s: ev.at_s + duration_s,
                corrupt_prob: *frac,
                ..ServerFaultWindow::default()
            }),
            _ => {} // connection-level classes: see fault_drop_* knobs
        }
    }
    out
}

/// Deterministic payload byte at offset `i` for content seed `seed`.
///
/// Each 8-byte lane comes from one xoshiro draw seeded by
/// `(seed, i/8)`; cheap enough to generate ranges on the fly at
/// multi-Gbps and reproducible for client-side verification.
pub fn payload_byte(seed: u64, i: u64) -> u8 {
    let lane = i / 8;
    let mut p = Prng::new(seed ^ lane.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let word = p.next_u64();
    word.to_le_bytes()[(i % 8) as usize]
}

/// Fill `buf` with payload bytes starting at `offset`.
pub fn fill_payload(seed: u64, offset: u64, buf: &mut [u8]) {
    // Generate lane-aligned 8-byte words, slicing edges.
    let mut i = 0usize;
    while i < buf.len() {
        let pos = offset + i as u64;
        let lane = pos / 8;
        let in_lane = (pos % 8) as usize;
        let mut p = Prng::new(seed ^ lane.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let word = p.next_u64().to_le_bytes();
        let take = (8 - in_lane).min(buf.len() - i);
        buf[i..i + take].copy_from_slice(&word[in_lane..in_lane + take]);
        i += take;
    }
}

/// The running server. Dropping it stops the accept loop.
pub struct ThrottledHttpServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    shared: Arc<Shared>,
}

struct Shared {
    files: Mutex<BTreeMap<String, ServedFile>>,
    throttle: ThrottleConfig,
    global_bucket: Option<TokenBucket>,
    active_connections: AtomicUsize,
    /// High-water mark of `active_connections` over the server's life —
    /// the per-mirror connection-cap tests assert on this.
    peak_connections: AtomicUsize,
    total_requests: AtomicUsize,
    /// Mid-body drops injected so far (see `fault_drop_count`).
    faults_injected: AtomicUsize,
    /// Server start time — `fault_windows` spans are uptime-relative.
    started: std::time::Instant,
}

impl ThrottledHttpServer {
    /// Bind on 127.0.0.1:0 and start accepting.
    pub fn start(files: Vec<ServedFile>, throttle: ThrottleConfig) -> Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(Shared {
            files: Mutex::new(
                files
                    .into_iter()
                    .map(|f| (f.path.clone(), f))
                    .collect::<BTreeMap<_, _>>(),
            ),
            global_bucket: if throttle.global_bytes_per_s > 0.0 {
                Some(TokenBucket::new(throttle.global_bytes_per_s))
            } else {
                None
            },
            throttle,
            active_connections: AtomicUsize::new(0),
            peak_connections: AtomicUsize::new(0),
            total_requests: AtomicUsize::new(0),
            faults_injected: AtomicUsize::new(0),
            started: std::time::Instant::now(),
        });

        let accept_shared = shared.clone();
        let accept_shutdown = shutdown.clone();
        let accept_handle = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                accept_loop(listener, accept_shared, accept_shutdown);
            })
            .map_err(|e| Error::Transport(format!("spawn accept thread: {e}")))?;

        Ok(ThrottledHttpServer {
            addr,
            shutdown,
            accept_handle: Some(accept_handle),
            shared,
        })
    }

    /// `http://127.0.0.1:<port>`.
    pub fn base_url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Register another file after startup.
    pub fn add_file(&self, f: ServedFile) {
        self.shared.files.lock().unwrap().insert(f.path.clone(), f);
    }

    /// High-water mark of simultaneously open connections over the
    /// server's lifetime. The strict per-mirror cap tests assert the
    /// client never opened more sockets to this server than
    /// `per_mirror_conns` allows.
    pub fn peak_connections(&self) -> usize {
        self.shared.peak_connections.load(Ordering::Relaxed)
    }

    /// Requests served so far (diagnostics).
    pub fn total_requests(&self) -> usize {
        self.shared.total_requests.load(Ordering::Relaxed)
    }

    /// Mid-body connection drops injected so far (fault injection).
    pub fn faults_injected(&self) -> usize {
        self.shared
            .faults_injected
            .load(Ordering::Relaxed)
            .min(self.shared.throttle.fault_drop_count)
    }
}

impl Drop for ThrottledHttpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, shutdown: Arc<AtomicBool>) {
    while !shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if shared.active_connections.load(Ordering::Relaxed)
                    >= shared.throttle.max_connections
                {
                    // Reject over-limit connections outright.
                    drop(stream);
                    continue;
                }
                let now = shared.active_connections.fetch_add(1, Ordering::Relaxed) + 1;
                shared.peak_connections.fetch_max(now, Ordering::Relaxed);
                let conn_shared = shared.clone();
                let conn_shutdown = shutdown.clone();
                let _ = std::thread::Builder::new()
                    .name("http-conn".into())
                    .spawn(move || {
                        let _ = serve_connection(stream, &conn_shared, &conn_shutdown);
                        conn_shared
                            .active_connections
                            .fetch_sub(1, Ordering::Relaxed);
                    });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
}

fn serve_connection(
    stream: TcpStream,
    shared: &Shared,
    shutdown: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let per_conn_bucket = if shared.throttle.per_conn_bytes_per_s > 0.0 {
        Some(TokenBucket::new(shared.throttle.per_conn_bytes_per_s))
    } else {
        None
    };

    loop {
        if shutdown.load(Ordering::Acquire) {
            return Ok(());
        }
        // --- Request line + headers. ---
        let mut request_line = String::new();
        if reader.read_line(&mut request_line)? == 0 {
            return Ok(()); // client closed
        }
        let mut headers = BTreeMap::new();
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                return Ok(());
            }
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some((k, v)) = line.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }

        let mut parts = request_line.split_whitespace();
        let method = parts.next().unwrap_or("");
        let path = parts.next().unwrap_or("/");
        let req_no = shared.total_requests.fetch_add(1, Ordering::Relaxed);

        if method != "GET" && method != "HEAD" {
            write_simple(&mut writer, 405, "method not allowed")?;
            continue;
        }

        // Scheduled fault windows (5xx rejection / added latency),
        // keyed on server uptime; the 503 draw is deterministic in
        // (fault_seed, request ordinal). Windows carrying a
        // `path_prefix` only hit the matching mirror's paths.
        if !shared.throttle.fault_windows.is_empty() {
            let up_s = shared.started.elapsed().as_secs_f64();
            let mut reject = false;
            let mut added_latency_s: f64 = 0.0;
            for (wi, w) in shared.throttle.fault_windows.iter().enumerate() {
                let applies = match &w.path_prefix {
                    Some(prefix) => path.starts_with(prefix.as_str()),
                    None => true,
                };
                if applies && up_s >= w.from_s && up_s < w.until_s {
                    added_latency_s = added_latency_s.max(w.added_latency_s);
                    if w.reject_prob >= 1.0 {
                        reject = true;
                    } else if w.reject_prob > 0.0 {
                        // Seed mixes the window index so overlapping
                        // windows draw independently (rejection
                        // probability composes as the union, matching
                        // the simulator's per-request draws).
                        let mut draw = Prng::new(
                            shared
                                .throttle
                                .fault_seed
                                .wrapping_add(1 + wi as u64)
                                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                                ^ req_no as u64,
                        );
                        if draw.next_f64() < w.reject_prob {
                            reject = true;
                        }
                    }
                }
            }
            if added_latency_s > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(added_latency_s));
            }
            if reject {
                write_simple(&mut writer, 503, "service unavailable")?;
                continue;
            }
        }

        let file = shared.files.lock().unwrap().get(path).cloned();
        let Some(file) = file else {
            write_simple(&mut writer, 404, "not found")?;
            continue;
        };

        // --- Range handling. ---
        let (start, end, partial) = match headers.get("range") {
            Some(r) => match parse_range(r, file.bytes) {
                Some((s, e)) => (s, e, true),
                None => {
                    write_simple(&mut writer, 416, "bad range")?;
                    continue;
                }
            },
            None => (0, file.bytes.saturating_sub(1), false),
        };
        let len = if file.bytes == 0 { 0 } else { end - start + 1 };

        if shared.throttle.first_byte_latency_s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(
                shared.throttle.first_byte_latency_s,
            ));
        }

        // --- Response headers. ---
        let status = if partial { "206 Partial Content" } else { "200 OK" };
        let mut head = format!(
            "HTTP/1.1 {status}\r\nContent-Length: {len}\r\nAccept-Ranges: bytes\r\nContent-Type: application/octet-stream\r\n"
        );
        if partial {
            head.push_str(&format!(
                "Content-Range: bytes {start}-{end}/{}\r\n",
                file.bytes
            ));
        }
        head.push_str("\r\n");
        writer.write_all(head.as_bytes())?;

        if method == "HEAD" {
            continue;
        }

        // Silent-corruption windows: decide once per response whether
        // this body carries a flipped byte. The draw is deterministic
        // in (fault_seed, window index, request ordinal) and seeded
        // differently from the 503 draws so the two compose
        // independently, matching the simulator's BitFlip semantics.
        let mut corrupt_this_response = false;
        if !shared.throttle.fault_windows.is_empty() {
            let up_s = shared.started.elapsed().as_secs_f64();
            for (wi, w) in shared.throttle.fault_windows.iter().enumerate() {
                let applies = match &w.path_prefix {
                    Some(prefix) => path.starts_with(prefix.as_str()),
                    None => true,
                };
                if applies && w.corrupt_prob > 0.0 && up_s >= w.from_s && up_s < w.until_s {
                    if w.corrupt_prob >= 1.0 {
                        corrupt_this_response = true;
                    } else {
                        let mut draw = Prng::new(
                            shared
                                .throttle
                                .fault_seed
                                .wrapping_add(0xC0DE + wi as u64)
                                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                                ^ req_no as u64,
                        );
                        if draw.next_f64() < w.corrupt_prob {
                            corrupt_this_response = true;
                        }
                    }
                }
            }
        }

        // --- Throttled body. ---
        let mut offset = start;
        let mut remaining = len;
        let mut sent_this_response: u64 = 0;
        let mut buf = vec![0u8; 256 * 1024];
        while remaining > 0 {
            if shutdown.load(Ordering::Acquire) {
                return Ok(());
            }
            // Fault injection: abort the connection mid-body while the
            // drop budget lasts (the client observes a short body) and,
            // when a drop window is configured, only inside it.
            let drop_window_open = if shared.throttle.fault_drop_window_s <= 0.0 {
                true
            } else {
                let start = shared.throttle.fault_drop_window_start_s;
                let uptime = shared.started.elapsed().as_secs_f64();
                uptime >= start && uptime < start + shared.throttle.fault_drop_window_s
            };
            if drop_window_open
                && shared.throttle.fault_drop_after_bytes > 0
                && sent_this_response >= shared.throttle.fault_drop_after_bytes
                && shared.faults_injected.load(Ordering::Relaxed)
                    < shared.throttle.fault_drop_count
            {
                let n = shared.faults_injected.fetch_add(1, Ordering::Relaxed);
                if n < shared.throttle.fault_drop_count {
                    return Ok(()); // abrupt close, no more bytes
                }
            }
            // Dribble windows: while one applies to this path, trickle
            // the payload in tiny pieces at the window's configured
            // rate instead of streaming normally. The connection stays
            // alive and bytes do move — just far below any useful rate
            // — which is exactly the failure mode the client's
            // whole-chunk progress deadline has to catch.
            let mut dribble_rate: u64 = 0;
            if !shared.throttle.fault_windows.is_empty() {
                let up_s = shared.started.elapsed().as_secs_f64();
                for w in &shared.throttle.fault_windows {
                    let applies = match &w.path_prefix {
                        Some(prefix) => path.starts_with(prefix.as_str()),
                        None => true,
                    };
                    if applies && up_s >= w.from_s && up_s < w.until_s {
                        dribble_rate = dribble_rate.max(w.dribble_bytes_per_s);
                    }
                }
            }
            if dribble_rate > 0 {
                let piece = remaining.min(64) as usize;
                fill_payload(file.seed, offset, &mut buf[..piece]);
                if corrupt_this_response && sent_this_response == 0 && piece > 0 {
                    buf[0] ^= 0xFF;
                }
                writer.write_all(&buf[..piece])?;
                writer.flush()?;
                offset += piece as u64;
                remaining -= piece as u64;
                sent_this_response += piece as u64;
                std::thread::sleep(Duration::from_secs_f64(
                    piece as f64 / dribble_rate as f64,
                ));
                continue;
            }
            let want = (buf.len() as u64).min(remaining) as usize;
            if let Some(b) = &per_conn_bucket {
                b.take_blocking(want);
            }
            if let Some(g) = &shared.global_bucket {
                g.take_blocking(want);
            }
            fill_payload(file.seed, offset, &mut buf[..want]);
            if corrupt_this_response && sent_this_response == 0 && want > 0 {
                buf[0] ^= 0xFF;
            }
            writer.write_all(&buf[..want])?;
            offset += want as u64;
            remaining -= want as u64;
            sent_this_response += want as u64;
        }
        writer.flush()?;
        // Keep-alive: loop for the next request unless told otherwise.
        if headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
        {
            return Ok(());
        }
    }
}

fn write_simple(w: &mut TcpStream, code: u16, msg: &str) -> std::io::Result<()> {
    let body = format!("{msg}\n");
    let head = format!(
        "HTTP/1.1 {code} {msg}\r\nContent-Length: {}\r\nContent-Type: text/plain\r\n\r\n",
        body.len()
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body.as_bytes())
}

/// Parse `bytes=start-end` (suffix/open forms included) against `size`.
fn parse_range(header: &str, size: u64) -> Option<(u64, u64)> {
    let spec = header.trim().strip_prefix("bytes=")?;
    let (a, b) = spec.split_once('-')?;
    if size == 0 {
        return None;
    }
    match (a.is_empty(), b.is_empty()) {
        (false, false) => {
            let start: u64 = a.parse().ok()?;
            let end: u64 = b.parse().ok()?;
            if start > end || end >= size {
                None
            } else {
                Some((start, end))
            }
        }
        (false, true) => {
            let start: u64 = a.parse().ok()?;
            if start >= size {
                None
            } else {
                Some((start, size - 1))
            }
        }
        (true, false) => {
            let suffix: u64 = b.parse().ok()?;
            if suffix == 0 {
                None
            } else {
                Some((size.saturating_sub(suffix), size - 1))
            }
        }
        (true, true) => None,
    }
}

// `Read` is used via BufReader::read_line; silence the unused-import lint
// on platforms where read_line suffices.
#[allow(unused)]
fn _assert_read_used<R: Read>(_r: R) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_is_deterministic_and_offset_consistent() {
        let mut whole = vec![0u8; 64];
        fill_payload(42, 0, &mut whole);
        // Arbitrary sub-range must match the whole buffer.
        let mut part = vec![0u8; 16];
        fill_payload(42, 13, &mut part);
        assert_eq!(&whole[13..29], &part[..]);
        // Byte-wise accessor agrees.
        for (i, &b) in whole.iter().enumerate() {
            assert_eq!(payload_byte(42, i as u64), b);
        }
        // Different seeds differ.
        let mut other = vec![0u8; 64];
        fill_payload(43, 0, &mut other);
        assert_ne!(whole, other);
    }

    #[test]
    fn fault_window_mapping_from_profiles() {
        use crate::netsim::{FaultEvent, FaultKind, FaultProfile, FaultSchedule};
        let schedule = FaultSchedule::new(vec![
            FaultEvent {
                at_s: 1.0,
                kind: FaultKind::ServerError {
                    reject_prob: 0.7,
                    duration_s: 4.0,
                },
            },
            FaultEvent {
                at_s: 10.0,
                kind: FaultKind::Brownout { duration_s: 3.0 },
            },
            FaultEvent {
                at_s: 20.0,
                kind: FaultKind::Stall {
                    frac: 0.5,
                    duration_s: 2.0,
                },
            },
            FaultEvent {
                at_s: 30.0,
                kind: FaultKind::ConnectionReset { count: 1 },
            },
            FaultEvent {
                at_s: 40.0,
                kind: FaultKind::SlowMirror {
                    mirror: 1,
                    factor: 0.1,
                    duration_s: 5.0,
                },
            },
            FaultEvent {
                at_s: 50.0,
                kind: FaultKind::BitFlip {
                    frac: 0.8,
                    duration_s: 6.0,
                },
            },
        ]);
        let windows = fault_windows_from_schedule(&schedule);
        assert_eq!(windows.len(), 5, "resets have no HTTP window analogue");
        assert_eq!(windows[0].reject_prob, 0.7);
        assert_eq!((windows[0].from_s, windows[0].until_s), (1.0, 5.0));
        assert_eq!(windows[1].reject_prob, 1.0);
        assert!((windows[2].added_latency_s - 1.0).abs() < 1e-9);
        assert!(windows[..3].iter().all(|w| w.path_prefix.is_none()));
        // SlowMirror maps to a per-mirror window scoped to /m1/.
        assert_eq!(windows[3].path_prefix.as_deref(), Some("/m1/"));
        assert!((windows[3].added_latency_s - 1.0).abs() < 1e-9);
        assert_eq!(windows[3].reject_prob, 0.0);
        // BitFlip maps to a silent-corruption window — no rejection,
        // no latency, just corrupt_prob.
        assert_eq!(windows[4].corrupt_prob, 0.8);
        assert_eq!((windows[4].from_s, windows[4].until_s), (50.0, 56.0));
        assert_eq!(windows[4].reject_prob, 0.0);
        // Profile overlay is deterministic and non-empty for 5xx-heavy
        // profiles.
        let a = ThrottleConfig::default().with_fault_profile(FaultProfile::ServerErrors, 9, 60.0);
        let b = ThrottleConfig::default().with_fault_profile(FaultProfile::ServerErrors, 9, 60.0);
        assert_eq!(a.fault_windows, b.fault_windows);
        assert!(!a.fault_windows.is_empty());
        assert_eq!(a.fault_seed, 9);
    }

    #[test]
    fn range_parsing() {
        assert_eq!(parse_range("bytes=0-99", 1000), Some((0, 99)));
        assert_eq!(parse_range("bytes=900-", 1000), Some((900, 999)));
        assert_eq!(parse_range("bytes=-100", 1000), Some((900, 999)));
        assert_eq!(parse_range("bytes=5-4", 1000), None);
        assert_eq!(parse_range("bytes=0-1000", 1000), None);
        assert_eq!(parse_range("bytes=1000-", 1000), None);
        assert_eq!(parse_range("bogus", 1000), None);
        assert_eq!(parse_range("bytes=0-0", 0), None);
    }
}
