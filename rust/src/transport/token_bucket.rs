//! Token-bucket rate limiter (bytes/second) for the throttled server.
//!
//! Thread-safe; one bucket per connection plus an optional shared
//! global bucket reproduces "per-connection cap + bottleneck link" on
//! loopback — the same two quantities the simulator models, so the
//! real-transport example can validate the adaptive controller against
//! a known `C* = global ÷ per-conn`.

use std::sync::Mutex;
use std::time::Instant;

/// Byte-rate limiter with burst capacity.
pub struct TokenBucket {
    state: Mutex<State>,
    rate_bytes_per_s: f64,
    burst_bytes: f64,
}

struct State {
    tokens: f64,
    last_refill: Instant,
}

impl TokenBucket {
    /// `rate` in bytes/second; burst defaults to 100 ms worth.
    pub fn new(rate_bytes_per_s: f64) -> TokenBucket {
        assert!(rate_bytes_per_s > 0.0);
        let burst_bytes = (rate_bytes_per_s * 0.1).max(64.0 * 1024.0);
        TokenBucket {
            state: Mutex::new(State {
                tokens: burst_bytes,
                last_refill: Instant::now(),
            }),
            rate_bytes_per_s,
            burst_bytes,
        }
    }

    /// Configured rate (bytes/s).
    pub fn rate(&self) -> f64 {
        self.rate_bytes_per_s
    }

    /// Take up to `want` tokens; returns how many were granted
    /// (possibly 0 — caller sleeps and retries).
    pub fn take(&self, want: usize) -> usize {
        let mut s = self.state.lock().unwrap();
        let now = Instant::now();
        let dt = now.duration_since(s.last_refill).as_secs_f64();
        s.last_refill = now;
        s.tokens = (s.tokens + dt * self.rate_bytes_per_s).min(self.burst_bytes);
        let granted = (s.tokens as usize).min(want);
        s.tokens -= granted as f64;
        granted
    }

    /// Block until `want` bytes have been granted (sleeping in small
    /// increments). Used by the server's send loop.
    pub fn take_blocking(&self, want: usize) {
        let mut remaining = want;
        while remaining > 0 {
            let got = self.take(remaining);
            remaining -= got;
            if remaining > 0 {
                // Sleep roughly the time to accrue the deficit, capped
                // for responsiveness.
                let wait_s = (remaining as f64 / self.rate_bytes_per_s).min(0.02);
                std::thread::sleep(std::time::Duration::from_secs_f64(wait_s.max(0.0005)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn grants_up_to_burst_immediately() {
        let b = TokenBucket::new(1_000_000.0);
        let got = b.take(50_000);
        assert!(got > 0);
        assert!(got <= 100_000 + 1);
    }

    #[test]
    fn sustained_rate_is_enforced() {
        let rate = 2_000_000.0; // 2 MB/s
        let b = TokenBucket::new(rate);
        // Drain the burst.
        b.take(usize::MAX / 2);
        let start = std::time::Instant::now();
        let mut total = 0usize;
        while start.elapsed() < Duration::from_millis(300) {
            total += b.take(64 * 1024);
            std::thread::sleep(Duration::from_millis(2));
        }
        let elapsed = start.elapsed().as_secs_f64();
        let measured = total as f64 / elapsed;
        assert!(
            measured < rate * 1.3,
            "measured {measured} B/s exceeds configured {rate}"
        );
        assert!(
            measured > rate * 0.5,
            "measured {measured} B/s far below configured {rate}"
        );
    }

    #[test]
    fn take_blocking_completes() {
        let b = TokenBucket::new(10_000_000.0);
        b.take_blocking(500_000); // should return in ~<100ms
    }
}
