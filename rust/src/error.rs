//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. Variants
//! are grouped by subsystem so callers (CLI, experiment harness, tests)
//! can match on the failure class without string-parsing.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error enum.
#[derive(Debug)]
pub enum Error {
    /// XLA/PJRT runtime failures (artifact load, compile, execute).
    Xla(String),
    /// Artifact directory problems: missing files, manifest mismatch.
    Artifact(String),
    /// Configuration parse/validation errors.
    Config(String),
    /// Accession / catalog resolution failures.
    Accession(String),
    /// Network-simulator invariant violations.
    Sim(String),
    /// Real-transport (HTTP/TCP) failures.
    Transport(String),
    /// Coordinator/session state machine errors.
    Session(String),
    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Accession(m) => write!(f, "accession error: {m}"),
            Error::Sim(m) => write!(f, "netsim error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Session(m) => write!(f, "session error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Short machine-readable class tag (used in logs and metrics).
    pub fn class(&self) -> &'static str {
        match self {
            Error::Xla(_) => "xla",
            Error::Artifact(_) => "artifact",
            Error::Config(_) => "config",
            Error::Accession(_) => "accession",
            Error::Sim(_) => "sim",
            Error::Transport(_) => "transport",
            Error::Session(_) => "session",
            Error::Io(_) => "io",
        }
    }
}
