//! Minimal streaming SHA-256 (FIPS 180-4).
//!
//! The build environment is fully offline, so the hash lives here
//! instead of coming from `sha2`. The implementation is the plain
//! 64-round scalar schedule — no unsafe, no SIMD — which is plenty for
//! the integrity layer: hashing happens off the reactor hot path (sink
//! writer threads, resume-time scans), and the bench suite's
//! `hash_ns_per_mb` gate keeps the cost visible.
//!
//! API mirrors the usual digest shape: [`Sha256::new`] →
//! [`Sha256::update`] (any chunking) → [`Sha256::finalize`] →
//! `[u8; 32]`, plus [`sha256`] and [`hex`] conveniences for one-shot
//! hashing and manifest serialization.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 state.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partially filled block awaiting the next `update`/`finalize`.
    block: [u8; 64],
    block_len: usize,
    /// Total message length in bytes (mod 2^61, more than enough).
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// Fresh hasher with the FIPS initial state.
    pub fn new() -> Self {
        Sha256 {
            state: H0,
            block: [0u8; 64],
            block_len: 0,
            total_len: 0,
        }
    }

    /// Absorb `data`; chunking is arbitrary and does not affect the digest.
    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.block_len > 0 {
            let take = (64 - self.block_len).min(data.len());
            self.block[self.block_len..self.block_len + take].copy_from_slice(&data[..take]);
            self.block_len += take;
            data = &data[take..];
            if self.block_len == 64 {
                let block = self.block;
                self.compress(&block);
                self.block_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.block[..data.len()].copy_from_slice(data);
            self.block_len = data.len();
        }
    }

    /// Pad, run the final blocks, and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // Padding: 0x80, zeros to 56 mod 64, then the 64-bit bit length.
        self.update(&[0x80]);
        while self.block_len != 56 {
            self.update(&[0x00]);
        }
        // Appending the length manually (not via update) keeps total_len
        // out of its own encoding.
        self.block[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.block;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes([
                block[i * 4],
                block[i * 4 + 1],
                block[i * 4 + 2],
                block[i * 4 + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

/// One-shot convenience: digest of `data`.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// Lowercase hex encoding of a digest (manifest JSON stores hashes as
/// strings — JSON numbers are f64 and cannot carry 256 bits).
pub fn hex(digest: &[u8; 32]) -> String {
    let mut s = String::with_capacity(64);
    for b in digest {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Parse a 64-char lowercase/uppercase hex digest back to bytes.
pub fn from_hex(s: &str) -> Option<[u8; 32]> {
    if s.len() != 64 || !s.is_ascii() {
        return None;
    }
    let bytes = s.as_bytes();
    let mut out = [0u8; 32];
    for i in 0..32 {
        let hi = (bytes[i * 2] as char).to_digit(16)?;
        let lo = (bytes[i * 2 + 1] as char).to_digit(16)?;
        out[i] = ((hi << 4) | lo) as u8;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / NIST CAVP reference vectors.
    #[test]
    fn nist_vectors() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a() {
        let mut h = Sha256::new();
        let block = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(&block);
        }
        assert_eq!(
            hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_chunking_is_irrelevant() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 7 + 13) as u8).collect();
        let whole = sha256(&data);
        for step in [1usize, 3, 63, 64, 65, 1000] {
            let mut h = Sha256::new();
            for chunk in data.chunks(step) {
                h.update(chunk);
            }
            assert_eq!(h.finalize(), whole, "chunk step {step}");
        }
    }

    #[test]
    fn hex_roundtrip() {
        let d = sha256(b"fastbiodl");
        let s = hex(&d);
        assert_eq!(from_hex(&s), Some(d));
        assert_eq!(from_hex("zz"), None);
        assert_eq!(from_hex(&s[..63]), None);
    }
}
