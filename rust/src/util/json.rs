//! Minimal JSON reader/writer (offline stand-in for `serde_json`).
//!
//! Supports the full JSON grammar minus exotic number forms; used to
//! parse `artifacts/manifest.json` at runtime start-up and to emit
//! machine-readable experiment results alongside the CSV files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so that
/// serialization is deterministic — experiment outputs diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Config(format!(
                "trailing garbage at byte {} of JSON document",
                p.i
            )));
        }
        Ok(v)
    }

    /// Fetch `self[key]` if this is an object containing `key`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with a path description.
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Config(format!("missing JSON field '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for object literals in Rust code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Config(format!(
                "expected '{}' at byte {} of JSON document",
                c as char, self.i
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Config(format!(
                "unexpected {:?} at byte {} of JSON document",
                other.map(|c| c as char),
                self.i
            ))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Config(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Config(format!("bad number '{s}' at byte {start}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Config("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| Error::Config("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Config("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Config("bad \\u escape".into()))?;
                            // Surrogate pairs are not needed for our manifests;
                            // map lone surrogates to U+FFFD.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(Error::Config(format!("bad escape {other:?}")));
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| Error::Config("invalid UTF-8 in string".into()))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(Error::Config(format!(
                        "expected ',' or ']' got {:?} at byte {}",
                        other.map(|c| c as char),
                        self.i
                    )));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(Error::Config(format!(
                        "expected ',' or '}}' got {:?} at byte {}",
                        other.map(|c| c as char),
                        self.i
                    )));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "format": "hlo-text-v1",
            "constants": {"window": 16, "grid": 64},
            "artifacts": {
                "gd_step": {
                    "file": "gd_step.hlo.txt",
                    "inputs": [{"shape": [16], "dtype": "float32"}]
                }
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text-v1"));
        let w = j.get("constants").unwrap().get("window").unwrap();
        assert_eq!(w.as_u64(), Some(16));
        let inputs = j
            .get("artifacts")
            .unwrap()
            .get("gd_step")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn roundtrip_compact() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\ny","c":null,"d":true}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = j.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }
}
