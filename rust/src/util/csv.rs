//! Tiny CSV writer for experiment outputs.
//!
//! Every bench/experiment writes its series to `results/*.csv` so the
//! figures can be re-plotted externally; this module keeps quoting rules
//! in one place.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::Result;

/// Streaming CSV writer with RFC-4180 quoting.
pub struct CsvWriter<W: Write> {
    out: W,
    columns: usize,
}

impl CsvWriter<BufWriter<File>> {
    /// Create `path` (and parent dirs) and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = CsvWriter {
            out: BufWriter::new(File::create(path)?),
            columns: header.len(),
        };
        w.write_row(header)?;
        Ok(w)
    }
}

impl<W: Write> CsvWriter<W> {
    /// Write one row of string fields; panics if the column count drifts.
    pub fn write_row<S: AsRef<str>>(&mut self, fields: &[S]) -> Result<()> {
        assert_eq!(
            fields.len(),
            self.columns,
            "CSV row has {} fields, header has {}",
            fields.len(),
            self.columns
        );
        let mut line = String::new();
        for (i, f) in fields.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&quote(f.as_ref()));
        }
        line.push('\n');
        self.out.write_all(line.as_bytes())?;
        Ok(())
    }

    /// Write one row of f64 values with fixed precision.
    pub fn write_f64_row(&mut self, fields: &[f64]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v:.6}")).collect();
        self.write_row(&strs)
    }

    /// Flush buffered output.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quoting_rules() {
        assert_eq!(quote("plain"), "plain");
        assert_eq!(quote("a,b"), "\"a,b\"");
        assert_eq!(quote("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn writes_rows() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter {
                out: &mut buf,
                columns: 2,
            };
            w.write_row(&["t", "mbps"]).unwrap();
            w.write_f64_row(&[1.0, 701.25]).unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        assert_eq!(text, "t,mbps\n1.000000,701.250000\n");
    }

    #[test]
    #[should_panic(expected = "CSV row has")]
    fn column_drift_panics() {
        let mut buf = Vec::new();
        let mut w = CsvWriter {
            out: &mut buf,
            columns: 2,
        };
        w.write_row(&["only-one"]).unwrap();
    }
}
