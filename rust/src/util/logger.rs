//! Leveled CLI output facade.
//!
//! The binary used to print everything through bare `println!`, which
//! made scripted use (piping a report JSON to another tool) impossible
//! without scraping banners out of stdout. This module centralizes the
//! policy:
//!
//! * **stdout** is for the primary human narrative (suppressed by
//!   `--quiet`); machine-readable artifacts go to files via `--*-out`
//!   flags, never interleaved with chatter.
//! * **stderr** is for diagnostics: errors and warnings always, info
//!   at the default level, debug/trace only under `--verbose` (the
//!   [`log`] crate's macros route here through [`init`]).
//! * **Disabled levels cost nothing**: the [`crate::out!`] /
//!   [`crate::vlog!`] macros check the level *before* evaluating their
//!   format arguments, so `--quiet` runs never format strings.
//!
//! The level lives in a process-global atomic: resolved once from the
//! CLI flags (`-q`/`--quiet`, `-v`/`--verbose`), read everywhere.

use std::sync::atomic::{AtomicU8, Ordering};

/// Output verbosity, lowest to highest.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Errors and warnings only (`--quiet`): scripted stdout stays
    /// clean.
    Quiet = 0,
    /// The default human narrative.
    Normal = 1,
    /// Everything, including per-step diagnostics (`--verbose`).
    Verbose = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Normal as u8);

/// Set the process-wide output level (once, from the CLI flags).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
    log::set_max_level(match level {
        Level::Quiet => log::LevelFilter::Warn,
        Level::Normal => log::LevelFilter::Info,
        Level::Verbose => log::LevelFilter::Trace,
    });
}

/// The current process-wide output level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        1 => Level::Normal,
        _ => Level::Verbose,
    }
}

/// Whether messages at `at` are currently emitted.
pub fn enabled(at: Level) -> bool {
    level() >= at
}

/// The [`log::Log`] bridge: `log::error!`/`warn!` always print to
/// stderr, `info!` at the default level, `debug!`/`trace!` only under
/// `--verbose`.
struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata<'_>) -> bool {
        match metadata.level() {
            log::Level::Error | log::Level::Warn => true,
            log::Level::Info => level() >= Level::Normal,
            log::Level::Debug | log::Level::Trace => level() >= Level::Verbose,
        }
    }

    fn log(&self, record: &log::Record<'_>) {
        if self.enabled(record.metadata()) {
            eprintln!("{}: {}", record.level().as_str().to_ascii_lowercase(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the stderr logger and apply `level`. Safe to call once at
/// startup; a second call (tests running in one process) keeps the
/// already-installed logger and just updates the level.
pub fn init(level: Level) {
    static LOGGER: StderrLogger = StderrLogger;
    let _ = log::set_logger(&LOGGER);
    set_level(level);
}

/// Print a line of the primary narrative to stdout unless `--quiet`.
/// Format arguments are not evaluated when suppressed.
#[macro_export]
macro_rules! out {
    ($($arg:tt)*) => {
        if $crate::util::logger::enabled($crate::util::logger::Level::Normal) {
            println!($($arg)*);
        }
    };
}

/// Print a verbose diagnostic line to stderr under `--verbose` only.
/// Format arguments are not evaluated when suppressed.
#[macro_export]
macro_rules! vlog {
    ($($arg:tt)*) => {
        if $crate::util::logger::enabled($crate::util::logger::Level::Verbose) {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_gate() {
        // Serialized through one test: the level is process-global.
        set_level(Level::Quiet);
        assert!(!enabled(Level::Normal));
        assert!(!enabled(Level::Verbose));
        assert!(enabled(Level::Quiet));

        set_level(Level::Normal);
        assert!(enabled(Level::Normal));
        assert!(!enabled(Level::Verbose));

        set_level(Level::Verbose);
        assert!(enabled(Level::Verbose));

        // The gating macro must not evaluate its arguments when the
        // level suppresses the line.
        set_level(Level::Quiet);
        let mut evaluated = false;
        out!("{}", {
            evaluated = true;
            "never formatted"
        });
        assert!(!evaluated, "suppressed out! evaluated its arguments");
        set_level(Level::Normal);
    }
}
