//! Small self-contained utilities.
//!
//! The build environment is fully offline, so facilities that would
//! normally come from crates.io live here instead: a deterministic PRNG
//! ([`prng`], replacing `rand`), a minimal JSON reader/writer ([`json`],
//! replacing `serde_json` — used for the artifact manifest and metric
//! dumps), a CSV writer ([`csv`]), and a property-based-testing
//! micro-framework ([`prop`], replacing `proptest`) used by the test
//! suite for coordinator/netsim invariants, and a streaming SHA-256
//! ([`sha256`], replacing `sha2`) backing the chunk-integrity layer.

pub mod csv;
pub mod json;
pub mod logger;
pub mod prng;
pub mod prop;
pub mod sha256;

/// Clamp a float into `[lo, hi]` (total-order, NaN maps to `lo`).
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    if x.is_nan() {
        lo
    } else {
        x.max(lo).min(hi)
    }
}

/// Format a byte count using binary units (`1.5 GiB`).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in seconds as `mm:ss.t` (used by progress output).
pub fn fmt_secs(secs: f64) -> String {
    let m = (secs / 60.0).floor() as u64;
    let s = secs - m as f64 * 60.0;
    format!("{m:02}:{s:04.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clampf_basics() {
        assert_eq!(clampf(5.0, 0.0, 3.0), 3.0);
        assert_eq!(clampf(-1.0, 0.0, 3.0), 0.0);
        assert_eq!(clampf(1.5, 0.0, 3.0), 1.5);
        assert_eq!(clampf(f64::NAN, 0.5, 3.0), 0.5);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(22_060_000_000), "20.54 GiB");
    }

    #[test]
    fn fmt_secs_roundtrip() {
        assert_eq!(fmt_secs(0.0), "00:00.0");
        assert_eq!(fmt_secs(160.0), "02:40.0");
    }
}
