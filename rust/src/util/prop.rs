//! Property-based-testing micro-framework (offline stand-in for `proptest`).
//!
//! The test suite uses this to check coordinator/netsim/optimizer
//! invariants over randomized inputs. Each property runs a configurable
//! number of cases from a deterministic seed; failures report the seed,
//! case index and the generated input's `Debug` form so the exact case
//! can be replayed by pinning `PROP_SEED`.
//!
//! ```no_run
//! // (no_run: doctest binaries cannot locate libxla_extension's rpath)
//! use fastbiodl::util::prop::{check, Config};
//!
//! check(Config::default(), "reverse twice is identity", |g| {
//!     let n = g.below(100) as usize;
//!     (0..n).map(|_| g.next_u64()).collect::<Vec<_>>()
//! }, |xs| {
//!     let mut twice = xs.clone();
//!     twice.reverse();
//!     twice.reverse();
//!     if &twice == xs { Ok(()) } else { Err("mismatch".into()) }
//! });
//! ```

use std::fmt::Debug;

use crate::util::prng::Prng;

/// Property-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`. Overridable via `PROP_SEED`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xFA57_B10D);
        let cases = std::env::var("PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(128);
        Config { cases, seed }
    }
}

/// Run `prop` over `cfg.cases` inputs drawn by `gen`. Panics on the
/// first failing case with enough context to replay it.
pub fn check<T, G, P>(cfg: Config, name: &str, gen: G, prop: P)
where
    T: Debug,
    G: Fn(&mut Prng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Prng::new(cfg.seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed\n  case:  {case}/{}\n  seed:  {} (replay with PROP_SEED={})\n  error: {msg}\n  input: {input:#?}",
                cfg.cases,
                cfg.seed,
                cfg.seed.wrapping_add(case as u64),
            );
        }
    }
}

/// Generator helpers for common shapes.
pub mod gen {
    use super::Prng;

    /// Vector of `n in [min_len, max_len]` floats drawn from `[lo, hi)`.
    pub fn vec_f64(
        rng: &mut Prng,
        min_len: usize,
        max_len: usize,
        lo: f64,
        hi: f64,
    ) -> Vec<f64> {
        let n = rng.range_u64(min_len as u64, max_len as u64) as usize;
        (0..n).map(|_| rng.range_f64(lo, hi)).collect()
    }

    /// Vector of `n in [min_len, max_len]` integers from `[lo, hi]`.
    pub fn vec_u64(
        rng: &mut Prng,
        min_len: usize,
        max_len: usize,
        lo: u64,
        hi: u64,
    ) -> Vec<u64> {
        let n = rng.range_u64(min_len as u64, max_len as u64) as usize;
        (0..n).map(|_| rng.range_u64(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            Config { cases: 64, seed: 1 },
            "addition commutes",
            |g| (g.below(1000), g.below(1000)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math is broken".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_reports() {
        check(
            Config { cases: 4, seed: 2 },
            "always fails",
            |g| g.below(10),
            |_| Err("nope".into()),
        );
    }
}
