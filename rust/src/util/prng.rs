//! Deterministic PRNG: xoshiro256++ with splitmix64 seeding.
//!
//! Every stochastic component in the simulator (background traffic,
//! per-connection jitter, file-size sampling) draws from an explicitly
//! seeded [`Prng`], so every experiment run is exactly reproducible from
//! its `(scenario, run_index)` pair — the 5-run round-robin of the paper
//! maps to seeds `base + run_index`.
//!
//! This replaces the `rand` crate (unavailable offline). The generator
//! is Blackman & Vigna's xoshiro256++ 1.0, which passes BigCrush; it is
//! emphatically **not** cryptographic, which is fine — nothing here
//! needs secrecy, only reproducibility and good equidistribution.

/// xoshiro256++ generator state.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive an independent child stream (for per-flow jitter etc.).
    ///
    /// Mixing the label through splitmix64 keeps child streams
    /// decorrelated even for adjacent labels.
    pub fn fork(&mut self, label: u64) -> Prng {
        let mut sm = self.next_u64() ^ label.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform float in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Rejection-free fast path is fine here: bias for n << 2^64 is
        // unmeasurable for simulation purposes, but we reject anyway to
        // keep the property tests honest about uniformity.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: lo > hi");
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.range_f64(-1.0, 1.0);
            let v = self.range_f64(-1.0, 1.0);
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate `lambda` (mean `1/lambda`).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut p = Prng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = p.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_right() {
        let mut p = Prng::new(123);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Prng::new(77);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
