//! Result presentation: ASCII tables, ASCII sparkline figures, and CSV
//! emission under `results/`.
//!
//! Every bench prints the paper-shaped rows through [`Table`] and dumps
//! the raw series through [`write_series_csv`] so figures can be
//! re-plotted externally. ASCII output is deliberate: the benches run
//! in CI/terminals, and the paper comparison is about *numbers and
//! shapes*, not pixels.

use std::path::Path;

use crate::util::csv::CsvWriter;
use crate::Result;

/// Simple column-aligned ASCII table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Render a numeric series as a one-line unicode sparkline (quick
/// visual of the per-second throughput figures in terminal output).
pub fn sparkline(values: &[f64], width: usize) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() || width == 0 {
        return String::new();
    }
    let hi = values.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let lo = values.iter().copied().fold(f64::INFINITY, f64::min);
    let span = (hi - lo).max(1e-12);
    // Downsample to `width` buckets by mean.
    let bucket = (values.len() as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < values.len() && out.chars().count() < width {
        let start = i as usize;
        let end = ((i + bucket) as usize).min(values.len()).max(start + 1);
        let mean = values[start..end].iter().sum::<f64>() / (end - start) as f64;
        let idx = (((mean - lo) / span) * (BARS.len() - 1) as f64).round() as usize;
        out.push(BARS[idx.min(BARS.len() - 1)]);
        i += bucket;
    }
    out
}

/// Write `(x, series...)` columns to `results/<name>.csv`.
pub fn write_series_csv(
    name: &str,
    columns: &[&str],
    rows: impl Iterator<Item = Vec<f64>>,
) -> Result<std::path::PathBuf> {
    let path = Path::new("results").join(format!("{name}.csv"));
    let mut w = CsvWriter::create(&path, columns)?;
    for row in rows {
        w.write_f64_row(&row)?;
    }
    w.flush()?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["Tool", "Speed (Mbps)"]);
        t.row(vec!["prefetch", "517.70 ± 40.12"]);
        t.row(vec!["fastbiodl", "989.12 ± 92.35"]);
        let s = t.render();
        assert!(s.contains("| Tool      | Speed (Mbps)   |"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only one"]);
    }

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0], 8);
        assert_eq!(s.chars().count(), 8);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
        assert_eq!(sparkline(&[], 10), "");
    }
}
