//! Session drivers: complete transfers from accession list to report.
//!
//! The full Algorithm-1 session — resolution charging, chunk
//! scheduling, worker-slot pool reconciliation, monitor sampling, probe
//! aggregation, controller stepping, retry/backoff classification,
//! checkpoint journaling, mirror failover, and report assembly — is
//! implemented **once**, in [`engine`], parameterized by two traits:
//!
//! * [`engine::Transport`] — how connections open and bytes move.
//!   [`sim`] implements it over [`crate::netsim`] (virtual time, fully
//!   deterministic per seed: every paper experiment runs here);
//!   [`real`] implements it over the event-driven socket reactor
//!   ([`crate::transport::reactor`]) against live servers.
//! * [`engine::Clock`] — virtual vs wall time.
//!
//! [`mirrors`] holds the per-mirror health board the engine uses to
//! schedule across a record's mirror list — score-weighted chunk
//! striping with periodic re-probes by default, winner-take-all
//! failover as the selectable baseline
//! ([`crate::config::MirrorStrategy`]).
//!
//! Controllers attach through the fault-aware control plane
//! ([`crate::control`]): the engine assembles one
//! [`crate::control::ControlSignals`] snapshot per probe interval and
//! applies the returned [`crate::control::ControlAction`] to both the
//! worker pool and (with adaptive chunk sizing enabled) the chunk
//! scheduler.
//!
//! Both drivers produce the same [`SessionReport`], so every metric the
//! experiment harness computes is defined identically for simulated
//! and real transfers — and every recovery feature behaves identically
//! too, because it is literally the same code.

pub mod engine;
pub mod mirrors;
pub mod real;
pub mod sim;

pub use engine::{
    Clock, EngineParams, EngineStats, FailureClass, ToolBehavior, Transport, TransportEvent,
    TransportIoStats,
};
pub use mirrors::MirrorBoard;
pub use sim::{run_simulated_download, SimSession, SimSessionParams};

use crate::metrics::recorder::Sample;
use crate::metrics::timeline::Timeline;
use crate::util::json::{obj, Json};

/// Schema tag of the machine-readable session record written by
/// `--report-json` ([`session_report_json`]); bump on breaking layout
/// changes so downstream parsers fail loudly.
pub const REPORT_SCHEMA: &str = "fastbiodl-report-v1";

/// Outcome of one complete transfer session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// Tool label ("fastbiodl", "prefetch", …).
    pub tool: String,
    /// Wall (or virtual) time from start to last byte (s).
    pub duration_s: f64,
    /// Total payload bytes delivered.
    pub total_bytes: u64,
    /// `total_bytes × 8 / duration` (Mbps) — the paper's "Speed" column.
    pub mean_throughput_mbps: f64,
    /// Time-weighted mean of the controller's *target* concurrency —
    /// the paper's "Concurrency" column (fixed tools report exactly
    /// their configured level, e.g. `3.00 ± 0.00`; FastBioDL reports
    /// the optimizer's average, e.g. `3.42 ± 0.62`).
    pub mean_concurrency: f64,
    /// Mean of the per-sample *in-flight* request count (diagnostic:
    /// lower than the target when workers wait on resolution/staging).
    pub mean_inflight: f64,
    /// Peak per-second throughput (Mbps).
    pub peak_mbps: f64,
    /// Per-second mean throughput series (Figure 5's x/y data).
    pub timeline: Timeline,
    /// Raw monitor samples (t, mbps, concurrency).
    pub samples: Vec<Sample>,
    /// `(t, target)` every time the controller moved the target.
    pub concurrency_trace: Vec<(f64, usize)>,
    /// Number of optimizer probes executed.
    pub probes: usize,
    /// Number of files fully delivered.
    pub files_completed: usize,
    /// Chunks returned to the queue and re-requested — connection
    /// resets, transient server errors, and worker parks mid-assignment
    /// all land here. Zero on a healthy network.
    pub chunk_retries: usize,
    /// Connections lost mid-request (injected resets / transport
    /// errors); each forced a reconnect.
    pub connection_resets: usize,
    /// Requests rejected by transient server errors (HTTP 5xx
    /// analogue); the connection survived, the chunk was retried.
    pub server_rejects: usize,
    /// Completed chunks whose SHA-256 mismatched the integrity
    /// manifest (`--verify` only); each was discarded and re-fetched
    /// ([`FailureClass::Corrupt`]). Zero with verification off.
    pub hash_mismatches: usize,
    /// Payload bytes credited to each mirror index (completed chunks
    /// only). Single-mirror transfers have length 1; a multi-mirror
    /// transfer that striped (or failed over) shows bytes on ≥ 2
    /// entries.
    pub mirror_bytes: Vec<u64>,
    /// Times a worker slot released its mirror to rebind elsewhere —
    /// failovers off a collapsing mirror, striping rebalances, and
    /// re-probe releases all count (see [`mirrors::MirrorBoard`]).
    pub mirror_switches: usize,
    /// Whether the transfer ran to completion. `false` only for
    /// checkpoint-interrupted simulated sessions (see
    /// [`sim::SimSession::with_checkpoint_after`]); resuming from
    /// [`SessionReport::frontiers`] finishes the job.
    pub completed: bool,
    /// Per-file contiguous completed prefixes at session end — exactly
    /// what [`crate::coordinator::resume::ProgressJournal`] persists.
    pub frontiers: Vec<u64>,
}

impl SessionReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{:<12} {:>8.1}s  {:>9.1} Mbps mean  {:>9.1} Mbps peak  C̄={:.2}  ({} files, {} probes)",
            self.tool,
            self.duration_s,
            self.mean_throughput_mbps,
            self.peak_mbps,
            self.mean_concurrency,
            self.files_completed,
            self.probes,
        );
        if self.chunk_retries > 0 {
            s.push_str(&format!(
                "  [{} retries: {} resets, {} 5xx]",
                self.chunk_retries, self.connection_resets, self.server_rejects
            ));
        }
        if self.hash_mismatches > 0 {
            s.push_str(&format!("  [{} corrupt chunks re-fetched]", self.hash_mismatches));
        }
        if self.mirror_bytes.len() > 1 {
            let shares: Vec<String> = self
                .mirror_bytes
                .iter()
                .map(|b| crate::util::fmt_bytes(*b))
                .collect();
            s.push_str(&format!(
                "  [mirrors: {} | {} switches]",
                shares.join(" / "),
                self.mirror_switches
            ));
        }
        if !self.completed {
            s.push_str("  [checkpointed]");
        }
        s
    }
}

/// The versioned machine-readable session record (`--report-json`):
/// the [`SessionReport`] outcome fields plus, when the driver kept
/// them, the [`EngineStats`] internals (control-loop and disk-path
/// counters). Deterministic key order via the sorted-map JSON writer;
/// for the same simulated seed the document is byte-identical across
/// runs (timelines and samples are part of the replay).
pub fn session_report_json(report: &SessionReport, stats: Option<&EngineStats>) -> Json {
    let mut fields = vec![
        ("schema", Json::Str(REPORT_SCHEMA.into())),
        ("tool", Json::Str(report.tool.clone())),
        ("duration_s", Json::Num(report.duration_s)),
        ("total_bytes", Json::Num(report.total_bytes as f64)),
        ("mean_throughput_mbps", Json::Num(report.mean_throughput_mbps)),
        ("mean_concurrency", Json::Num(report.mean_concurrency)),
        ("mean_inflight", Json::Num(report.mean_inflight)),
        ("peak_mbps", Json::Num(report.peak_mbps)),
        ("probes", Json::Num(report.probes as f64)),
        ("files_completed", Json::Num(report.files_completed as f64)),
        ("chunk_retries", Json::Num(report.chunk_retries as f64)),
        ("connection_resets", Json::Num(report.connection_resets as f64)),
        ("server_rejects", Json::Num(report.server_rejects as f64)),
        ("hash_mismatches", Json::Num(report.hash_mismatches as f64)),
        ("mirror_switches", Json::Num(report.mirror_switches as f64)),
        ("completed", Json::Bool(report.completed)),
        (
            "mirror_bytes",
            Json::Arr(report.mirror_bytes.iter().map(|b| Json::Num(*b as f64)).collect()),
        ),
        (
            "frontiers",
            Json::Arr(report.frontiers.iter().map(|f| Json::Num(*f as f64)).collect()),
        ),
    ];
    if let Some(st) = stats {
        fields.push((
            "engine",
            obj(vec![
                ("ticks", Json::Num(st.ticks as f64)),
                ("slots_scanned", Json::Num(st.slots_scanned as f64)),
                (
                    "max_probe_releases_per_tick",
                    Json::Num(st.max_probe_releases_per_tick as f64),
                ),
                ("probe_releases", Json::Num(st.probe_releases as f64)),
                ("transport_events", Json::Num(st.transport_events as f64)),
                ("chunks_scaled", Json::Num(st.chunks_scaled as f64)),
                ("write_syscalls", Json::Num(st.write_syscalls as f64)),
                ("sink_queue_peak", Json::Num(st.sink_queue_peak as f64)),
                ("reactor_stall_ns", Json::Num(st.reactor_stall_ns as f64)),
            ]),
        ));
    }
    obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_is_versioned_and_carries_engine_stats() {
        let report = SessionReport {
            tool: "fastbiodl".into(),
            duration_s: 12.5,
            total_bytes: 1_000_000,
            mean_throughput_mbps: 640.0,
            mean_concurrency: 7.5,
            mean_inflight: 6.9,
            peak_mbps: 900.0,
            timeline: Timeline::default(),
            samples: Vec::new(),
            concurrency_trace: Vec::new(),
            probes: 3,
            files_completed: 2,
            chunk_retries: 1,
            connection_resets: 1,
            server_rejects: 0,
            hash_mismatches: 0,
            mirror_bytes: vec![600_000, 400_000],
            mirror_switches: 4,
            completed: true,
            frontiers: vec![500_000, 500_000],
        };
        let bare = session_report_json(&report, None).to_string_compact();
        assert!(bare.contains(REPORT_SCHEMA));
        assert!(bare.contains("\"hash_mismatches\":0"));
        assert!(!bare.contains("\"engine\""), "no stats block without stats");

        let stats = EngineStats {
            ticks: 42,
            ..EngineStats::default()
        };
        let full = session_report_json(&report, Some(&stats)).to_string_compact();
        assert!(full.contains("\"engine\":{"));
        assert!(full.contains("\"ticks\":42"));
        // The document parses back and keeps the deterministic key order.
        let parsed = Json::parse(&full).unwrap();
        assert_eq!(parsed.to_string_compact(), full);
    }
}
