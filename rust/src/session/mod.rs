//! Session drivers: complete transfers from accession list to report.
//!
//! The full Algorithm-1 session — resolution charging, chunk
//! scheduling, worker-slot pool reconciliation, monitor sampling, probe
//! aggregation, controller stepping, retry/backoff classification,
//! checkpoint journaling, mirror failover, and report assembly — is
//! implemented **once**, in [`engine`], parameterized by two traits:
//!
//! * [`engine::Transport`] — how connections open and bytes move.
//!   [`sim`] implements it over [`crate::netsim`] (virtual time, fully
//!   deterministic per seed: every paper experiment runs here);
//!   [`real`] implements it over the event-driven socket reactor
//!   ([`crate::transport::reactor`]) against live servers.
//! * [`engine::Clock`] — virtual vs wall time.
//!
//! [`mirrors`] holds the per-mirror health board the engine uses to
//! schedule across a record's mirror list — score-weighted chunk
//! striping with periodic re-probes by default, winner-take-all
//! failover as the selectable baseline
//! ([`crate::config::MirrorStrategy`]).
//!
//! Controllers attach through the fault-aware control plane
//! ([`crate::control`]): the engine assembles one
//! [`crate::control::ControlSignals`] snapshot per probe interval and
//! applies the returned [`crate::control::ControlAction`] to both the
//! worker pool and (with adaptive chunk sizing enabled) the chunk
//! scheduler.
//!
//! Both drivers produce the same [`SessionReport`], so every metric the
//! experiment harness computes is defined identically for simulated
//! and real transfers — and every recovery feature behaves identically
//! too, because it is literally the same code.

pub mod engine;
pub mod mirrors;
pub mod real;
pub mod sim;

pub use engine::{
    Clock, EngineParams, EngineStats, FailureClass, ToolBehavior, Transport, TransportEvent,
    TransportIoStats,
};
pub use mirrors::MirrorBoard;
pub use sim::{run_simulated_download, SimSession, SimSessionParams};

use crate::metrics::recorder::Sample;
use crate::metrics::timeline::Timeline;

/// Outcome of one complete transfer session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// Tool label ("fastbiodl", "prefetch", …).
    pub tool: String,
    /// Wall (or virtual) time from start to last byte (s).
    pub duration_s: f64,
    /// Total payload bytes delivered.
    pub total_bytes: u64,
    /// `total_bytes × 8 / duration` (Mbps) — the paper's "Speed" column.
    pub mean_throughput_mbps: f64,
    /// Time-weighted mean of the controller's *target* concurrency —
    /// the paper's "Concurrency" column (fixed tools report exactly
    /// their configured level, e.g. `3.00 ± 0.00`; FastBioDL reports
    /// the optimizer's average, e.g. `3.42 ± 0.62`).
    pub mean_concurrency: f64,
    /// Mean of the per-sample *in-flight* request count (diagnostic:
    /// lower than the target when workers wait on resolution/staging).
    pub mean_inflight: f64,
    /// Peak per-second throughput (Mbps).
    pub peak_mbps: f64,
    /// Per-second mean throughput series (Figure 5's x/y data).
    pub timeline: Timeline,
    /// Raw monitor samples (t, mbps, concurrency).
    pub samples: Vec<Sample>,
    /// `(t, target)` every time the controller moved the target.
    pub concurrency_trace: Vec<(f64, usize)>,
    /// Number of optimizer probes executed.
    pub probes: usize,
    /// Number of files fully delivered.
    pub files_completed: usize,
    /// Chunks returned to the queue and re-requested — connection
    /// resets, transient server errors, and worker parks mid-assignment
    /// all land here. Zero on a healthy network.
    pub chunk_retries: usize,
    /// Connections lost mid-request (injected resets / transport
    /// errors); each forced a reconnect.
    pub connection_resets: usize,
    /// Requests rejected by transient server errors (HTTP 5xx
    /// analogue); the connection survived, the chunk was retried.
    pub server_rejects: usize,
    /// Completed chunks whose SHA-256 mismatched the integrity
    /// manifest (`--verify` only); each was discarded and re-fetched
    /// ([`FailureClass::Corrupt`]). Zero with verification off.
    pub hash_mismatches: usize,
    /// Payload bytes credited to each mirror index (completed chunks
    /// only). Single-mirror transfers have length 1; a multi-mirror
    /// transfer that striped (or failed over) shows bytes on ≥ 2
    /// entries.
    pub mirror_bytes: Vec<u64>,
    /// Times a worker slot released its mirror to rebind elsewhere —
    /// failovers off a collapsing mirror, striping rebalances, and
    /// re-probe releases all count (see [`mirrors::MirrorBoard`]).
    pub mirror_switches: usize,
    /// Whether the transfer ran to completion. `false` only for
    /// checkpoint-interrupted simulated sessions (see
    /// [`sim::SimSession::with_checkpoint_after`]); resuming from
    /// [`SessionReport::frontiers`] finishes the job.
    pub completed: bool,
    /// Per-file contiguous completed prefixes at session end — exactly
    /// what [`crate::coordinator::resume::ProgressJournal`] persists.
    pub frontiers: Vec<u64>,
}

impl SessionReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{:<12} {:>8.1}s  {:>9.1} Mbps mean  {:>9.1} Mbps peak  C̄={:.2}  ({} files, {} probes)",
            self.tool,
            self.duration_s,
            self.mean_throughput_mbps,
            self.peak_mbps,
            self.mean_concurrency,
            self.files_completed,
            self.probes,
        );
        if self.chunk_retries > 0 {
            s.push_str(&format!(
                "  [{} retries: {} resets, {} 5xx]",
                self.chunk_retries, self.connection_resets, self.server_rejects
            ));
        }
        if self.hash_mismatches > 0 {
            s.push_str(&format!("  [{} corrupt chunks re-fetched]", self.hash_mismatches));
        }
        if self.mirror_bytes.len() > 1 {
            let shares: Vec<String> = self
                .mirror_bytes
                .iter()
                .map(|b| crate::util::fmt_bytes(*b))
                .collect();
            s.push_str(&format!(
                "  [mirrors: {} | {} switches]",
                shares.join(" / "),
                self.mirror_switches
            ));
        }
        if !self.completed {
            s.push_str("  [checkpointed]");
        }
        s
    }
}
