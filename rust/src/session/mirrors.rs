//! Per-mirror health scoring for multi-source scheduling.
//!
//! A [`crate::accession::RunRecord`] lists an ordered mirror list; the
//! unified session engine tracks one [`MirrorBoard`] per session and
//! asks it two questions:
//!
//! * **Which mirror should a (re)connecting worker slot bind to?**
//!   ([`MirrorBoard::pick_for_connect`]) — unprobed mirrors are handed
//!   out round-robin first so every endpoint gets a throughput estimate
//!   early; once all mirrors have data, new connections go to the
//!   best-scoring one.
//! * **Should an idle slot abandon its current mirror?**
//!   ([`MirrorBoard::should_failover`]) — yes when the current mirror's
//!   score has fallen below [`FAILOVER_RATIO`] of the best mirror's,
//!   which is how workers drain off a slow or browning-out mirror.
//!
//! The score is an EWMA of per-chunk goodput divided by a decaying
//! failure penalty (connection resets and transient 5xx rejections both
//! count — exactly the quantities [`crate::session::SessionReport`]
//! already surfaces). Everything is pure arithmetic over the session
//! clock, so simulated runs replay bit-identically.

/// Fraction of the best mirror's score below which an idle slot fails
/// over (hysteresis against flapping between comparable mirrors).
pub const FAILOVER_RATIO: f64 = 0.4;

/// EWMA step for per-chunk goodput samples.
const EWMA_ALPHA: f64 = 0.3;

/// Failure-penalty decay time constant (s): a burst of rejects stops
/// haunting a mirror ~a minute after it heals.
const FAIL_DECAY_TAU_S: f64 = 20.0;

/// A mirror that has only ever failed (no completed chunk) stops being
/// treated as "unprobed and worth trying" once its decayed failure
/// weight reaches this level.
const UNPROBED_FAIL_LIMIT: f64 = 3.0;

#[derive(Clone, Debug, Default)]
struct MirrorStat {
    /// EWMA of per-chunk goodput (Mbps); `None` until a chunk completes.
    ewma_mbps: Option<f64>,
    /// Exponentially decayed failure count.
    fail_weight: f64,
    /// Session time of the most recent failure (s).
    last_fail_s: f64,
    /// Payload bytes credited to this mirror (completed chunks only).
    bytes: u64,
    /// Completed chunks.
    successes: u64,
    /// Failures (resets + rejects), undecayed, for the report.
    failures: u64,
}

impl MirrorStat {
    fn decayed_fails(&self, now_s: f64) -> f64 {
        if self.fail_weight <= 0.0 {
            return 0.0;
        }
        let dt = (now_s - self.last_fail_s).max(0.0);
        self.fail_weight * (-dt / FAIL_DECAY_TAU_S).exp()
    }
}

/// Session-wide mirror health board.
#[derive(Clone, Debug)]
pub struct MirrorBoard {
    stats: Vec<MirrorStat>,
    /// Round-robin cursor for spreading slots across unprobed mirrors.
    rr: usize,
}

impl MirrorBoard {
    /// Board over `mirrors >= 1` endpoints.
    pub fn new(mirrors: usize) -> MirrorBoard {
        MirrorBoard {
            stats: vec![MirrorStat::default(); mirrors.max(1)],
            rr: 0,
        }
    }

    /// Number of mirrors tracked.
    pub fn mirror_count(&self) -> usize {
        self.stats.len()
    }

    /// A chunk of `bytes` completed on mirror `m` in `elapsed_s`.
    pub fn on_success(&mut self, m: usize, bytes: u64, elapsed_s: f64) {
        let mbps = bytes as f64 * 8.0 / 1e6 / elapsed_s.max(1e-9);
        let s = &mut self.stats[m];
        s.bytes += bytes;
        s.successes += 1;
        s.ewma_mbps = Some(match s.ewma_mbps {
            Some(prev) => prev + EWMA_ALPHA * (mbps - prev),
            None => mbps,
        });
    }

    /// A chunk failed (reset or transient rejection) on mirror `m`.
    pub fn on_failure(&mut self, m: usize, now_s: f64) {
        let s = &mut self.stats[m];
        s.fail_weight = s.decayed_fails(now_s) + 1.0;
        s.last_fail_s = now_s;
        s.failures += 1;
    }

    /// Health score of mirror `m` (higher is better); `None` until the
    /// mirror has completed at least one chunk.
    pub fn score(&self, m: usize, now_s: f64) -> Option<f64> {
        let s = &self.stats[m];
        s.ewma_mbps.map(|e| e / (1.0 + s.decayed_fails(now_s)))
    }

    /// Mirror a (re)connecting slot should bind to.
    pub fn pick_for_connect(&mut self, now_s: f64) -> usize {
        // Explore endpoints we have no throughput estimate for (unless
        // they have only ever failed), spreading slots round-robin.
        let unprobed: Vec<usize> = (0..self.stats.len())
            .filter(|&m| {
                self.stats[m].ewma_mbps.is_none()
                    && self.stats[m].decayed_fails(now_s) < UNPROBED_FAIL_LIMIT
            })
            .collect();
        if !unprobed.is_empty() {
            let m = unprobed[self.rr % unprobed.len()];
            self.rr += 1;
            return m;
        }
        self.preferred(now_s)
    }

    /// Best-scoring probed mirror (lowest index wins ties; mirror 0
    /// when nothing is probed yet).
    pub fn preferred(&self, now_s: f64) -> usize {
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for m in 0..self.stats.len() {
            if let Some(sc) = self.score(m, now_s) {
                if sc > best_score {
                    best_score = sc;
                    best = m;
                }
            }
        }
        best
    }

    /// Should an idle slot bound to `current` reconnect elsewhere?
    pub fn should_failover(&self, current: usize, now_s: f64) -> bool {
        if self.stats.len() < 2 {
            return false;
        }
        let Some(cur) = self.score(current, now_s) else {
            return false;
        };
        let best = self.preferred(now_s);
        if best == current {
            return false;
        }
        match self.score(best, now_s) {
            Some(best_sc) => cur < best_sc * FAILOVER_RATIO,
            None => false,
        }
    }

    /// Payload bytes credited per mirror (the report's `mirror_bytes`).
    pub fn bytes(&self) -> Vec<u64> {
        self.stats.iter().map(|s| s.bytes).collect()
    }

    /// Failures recorded per mirror (diagnostics).
    pub fn failures(&self) -> Vec<u64> {
        self.stats.iter().map(|s| s.failures).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unprobed_mirrors_are_spread_round_robin() {
        let mut b = MirrorBoard::new(3);
        let picks: Vec<usize> = (0..6).map(|_| b.pick_for_connect(0.0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn connects_prefer_the_faster_probed_mirror() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 1_000_000, 10.0); // 0.8 Mbps
        b.on_success(1, 10_000_000, 1.0); // 80 Mbps
        assert_eq!(b.preferred(10.0), 1);
        assert_eq!(b.pick_for_connect(10.0), 1);
    }

    #[test]
    fn failover_triggers_on_a_dominated_mirror() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 1_000_000, 10.0); // slow: 0.8 Mbps
        b.on_success(1, 10_000_000, 1.0); // fast: 80 Mbps
        assert!(b.should_failover(0, 10.0));
        assert!(!b.should_failover(1, 10.0));
    }

    #[test]
    fn comparable_mirrors_do_not_flap() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 8_000_000, 1.0);
        b.on_success(1, 10_000_000, 1.0);
        assert!(!b.should_failover(0, 1.0));
        assert!(!b.should_failover(1, 1.0));
    }

    #[test]
    fn failures_penalize_and_decay() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 10_000_000, 1.0);
        b.on_success(1, 10_000_000, 1.0);
        for _ in 0..5 {
            b.on_failure(0, 100.0);
        }
        let hurt = b.score(0, 100.0).unwrap();
        let healthy = b.score(1, 100.0).unwrap();
        assert!(hurt < healthy * 0.4, "rejects should crater the score");
        assert!(b.should_failover(0, 100.0));
        // Long after the burst the penalty decays away.
        let later = b.score(0, 400.0).unwrap();
        assert!(later > healthy * 0.9);
        assert_eq!(b.failures(), vec![5, 0]);
    }

    #[test]
    fn single_mirror_never_fails_over() {
        let mut b = MirrorBoard::new(1);
        b.on_success(0, 1_000, 10.0);
        for _ in 0..10 {
            b.on_failure(0, 5.0);
        }
        assert!(!b.should_failover(0, 5.0));
        assert_eq!(b.pick_for_connect(5.0), 0);
    }

    #[test]
    fn byte_attribution() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 100, 1.0);
        b.on_success(1, 250, 1.0);
        b.on_success(1, 50, 1.0);
        assert_eq!(b.bytes(), vec![100, 300]);
    }
}
