//! Per-mirror health scoring for multi-source scheduling.
//!
//! A [`crate::accession::RunRecord`] lists an ordered mirror list; the
//! unified session engine tracks one [`MirrorBoard`] per session and
//! consults it whenever a worker slot (re)connects or sits idle. Two
//! strategies build on the same health score
//! ([`crate::config::MirrorStrategy`]):
//!
//! * **Winner-take-all failover** (the PR 2 baseline, kept selectable):
//!   [`MirrorBoard::pick_for_connect`] hands unprobed mirrors out
//!   round-robin, then binds every new connection to the best-scoring
//!   mirror; [`MirrorBoard::should_failover`] tells an idle slot to
//!   abandon a mirror whose score fell below [`FAILOVER_RATIO`] of the
//!   best one.
//! * **Score-weighted striping** (the default): connections are spread
//!   across mirrors in proportion to their scores.
//!   [`MirrorBoard::pick_for_stripe`] is a deterministic
//!   highest-averages (D'Hondt) pick — it chooses the candidate mirror
//!   maximizing `weight / (connections + 1)`, which converges to a
//!   per-mirror connection count proportional to the weight vector —
//!   and [`MirrorBoard::should_restripe`] releases an idle slot only
//!   when rebinding it would raise its expected share by
//!   [`STRIPE_GAIN`], so comparable mirrors never flap. Weights carry a
//!   configurable floor (a fraction of the best score), and a mirror
//!   that has lost all its connections is **re-probed** every
//!   [`REPROBE_INTERVAL_S`]: one slot reconnects to it and fetches a
//!   chunk, so a healed mirror's goodput estimate recovers and its
//!   share grows back.
//!
//! The score is an EWMA of per-chunk goodput divided by a decaying
//! failure penalty (connection resets and transient 5xx rejections both
//! count — exactly the quantities [`crate::session::SessionReport`]
//! already surfaces) and a mild connect-RTT penalty ([`RTT_WEIGHT`]):
//! bandwidth decides where bulk chunks go, while probe connections —
//! which pay a whole handshake to move one chunk — prefer the
//! lowest-RTT due mirror ([`MirrorBoard::probe_due`]). [`MirrorBoard::concurrency_headroom`] and
//! [`MirrorBoard::fail_pressure`] condense the board into the aggregate
//! health signal carried by every control-plane snapshot (see
//! [`crate::control::MirrorHealth`] /
//! [`crate::control::ControlSignals`]). Everything is pure arithmetic
//! over the session clock, so simulated runs replay bit-identically.

/// Fraction of the best mirror's score below which an idle slot fails
/// over (hysteresis against flapping between comparable mirrors).
/// Only used by [`crate::config::MirrorStrategy::Failover`].
pub const FAILOVER_RATIO: f64 = 0.4;

/// Minimum multiplicative gain in expected per-connection share before
/// [`MirrorBoard::should_restripe`] releases an idle slot — hysteresis
/// against flapping between comparable mirrors under goodput jitter.
pub const STRIPE_GAIN: f64 = 1.25;

/// A mirror that currently has **zero** connections becomes probe-due
/// this many seconds after its last connection attempt: the striping
/// engine dedicates one slot to fetch a chunk from it, refreshing its
/// goodput estimate so a healed mirror is re-admitted.
pub const REPROBE_INTERVAL_S: f64 = 20.0;

/// EWMA step for per-chunk goodput samples.
const EWMA_ALPHA: f64 = 0.3;

/// EWMA step for connect-RTT samples.
const RTT_ALPHA: f64 = 0.3;

/// Latency-aware striping: the health score is divided by
/// `1 + RTT_WEIGHT × rtt_s`. The weight is deliberately small — a
/// 250 ms handshake costs ~3 % of score — so a high-RTT but
/// high-bandwidth mirror still wins the bulk-chunk allocation on
/// goodput, while *probe* connections (which pay the full handshake
/// but move one chunk) prefer the low-RTT endpoint via
/// [`MirrorBoard::probe_due`].
pub const RTT_WEIGHT: f64 = 0.12;

/// Failure-penalty decay time constant (s): a burst of rejects stops
/// haunting a mirror ~a minute after it heals.
const FAIL_DECAY_TAU_S: f64 = 20.0;

/// A mirror that has only ever failed (no completed chunk) stops being
/// treated as "unprobed and worth trying" once its decayed failure
/// weight reaches this level.
const UNPROBED_FAIL_LIMIT: f64 = 3.0;

#[derive(Clone, Debug, Default)]
struct MirrorStat {
    /// EWMA of per-chunk goodput (Mbps); `None` until a chunk completes.
    ewma_mbps: Option<f64>,
    /// EWMA of connect→ready handshake time (s); `None` until the
    /// transport reports a readiness transition for this mirror.
    ewma_rtt_s: Option<f64>,
    /// Exponentially decayed failure count.
    fail_weight: f64,
    /// Session time of the most recent failure (s).
    last_fail_s: f64,
    /// Payload bytes credited to this mirror (completed chunks only).
    bytes: u64,
    /// Completed chunks.
    successes: u64,
    /// Failures (resets + rejects), undecayed, for the report.
    failures: u64,
}

impl MirrorStat {
    fn decayed_fails(&self, now_s: f64) -> f64 {
        if self.fail_weight <= 0.0 {
            return 0.0;
        }
        let dt = (now_s - self.last_fail_s).max(0.0);
        self.fail_weight * (-dt / FAIL_DECAY_TAU_S).exp()
    }
}

/// Session-wide mirror health board.
#[derive(Clone, Debug)]
pub struct MirrorBoard {
    stats: Vec<MirrorStat>,
    /// Round-robin cursor for spreading slots across unprobed mirrors.
    rr: usize,
    /// Session time of the most recent connection attempt per mirror
    /// (`-inf` until first attempted) — drives the re-probe cadence.
    last_attempt_s: Vec<f64>,
}

impl MirrorBoard {
    /// Board over `mirrors >= 1` endpoints.
    pub fn new(mirrors: usize) -> MirrorBoard {
        let n = mirrors.max(1);
        MirrorBoard {
            stats: vec![MirrorStat::default(); n],
            rr: 0,
            last_attempt_s: vec![f64::NEG_INFINITY; n],
        }
    }

    /// Number of mirrors tracked.
    pub fn mirror_count(&self) -> usize {
        self.stats.len()
    }

    /// A chunk of `bytes` completed on mirror `m` in `elapsed_s`.
    pub fn on_success(&mut self, m: usize, bytes: u64, elapsed_s: f64) {
        let mbps = bytes as f64 * 8.0 / 1e6 / elapsed_s.max(1e-9);
        let s = &mut self.stats[m];
        s.bytes += bytes;
        s.successes += 1;
        s.ewma_mbps = Some(match s.ewma_mbps {
            Some(prev) => prev + EWMA_ALPHA * (mbps - prev),
            None => mbps,
        });
    }

    /// Record a connect→ready handshake time observed on mirror `m`
    /// (the per-mirror RTT proxy; fed by the session engine whenever a
    /// transport signals readiness). Folded into [`MirrorBoard::score`]
    /// behind [`RTT_WEIGHT`].
    pub fn note_rtt(&mut self, m: usize, rtt_s: f64) {
        let s = &mut self.stats[m];
        s.ewma_rtt_s = Some(match s.ewma_rtt_s {
            Some(prev) => prev + RTT_ALPHA * (rtt_s - prev),
            None => rtt_s,
        });
    }

    /// Smoothed connect RTT of mirror `m` (s); `None` until observed.
    pub fn rtt(&self, m: usize) -> Option<f64> {
        self.stats[m].ewma_rtt_s
    }

    /// Fleet mean of the per-mirror connect-RTT EWMAs (s); `None`
    /// until any mirror reported a readiness transition. This is the
    /// `connect_rtt_s` field of the control-plane snapshot
    /// ([`crate::control::ControlSignals`]).
    pub fn mean_rtt(&self) -> Option<f64> {
        let (sum, n) = self
            .stats
            .iter()
            .filter_map(|s| s.ewma_rtt_s)
            .fold((0.0f64, 0usize), |(a, c), r| (a + r, c + 1));
        if n == 0 {
            None
        } else {
            Some(sum / n as f64)
        }
    }

    /// A chunk failed (reset or transient rejection) on mirror `m`.
    pub fn on_failure(&mut self, m: usize, now_s: f64) {
        let s = &mut self.stats[m];
        s.fail_weight = s.decayed_fails(now_s) + 1.0;
        s.last_fail_s = now_s;
        s.failures += 1;
    }

    /// Health score of mirror `m` (higher is better); `None` until the
    /// mirror has completed at least one chunk. Goodput EWMA, divided
    /// by the decaying failure penalty and a mild RTT penalty
    /// ([`RTT_WEIGHT`]) — bandwidth dominates, latency tie-breaks.
    pub fn score(&self, m: usize, now_s: f64) -> Option<f64> {
        let s = &self.stats[m];
        let rtt_penalty = 1.0 + RTT_WEIGHT * s.ewma_rtt_s.unwrap_or(0.0).max(0.0);
        s.ewma_mbps.map(|e| e / (1.0 + s.decayed_fails(now_s)) / rtt_penalty)
    }

    /// Mirror a (re)connecting slot should bind to.
    pub fn pick_for_connect(&mut self, now_s: f64) -> usize {
        // Explore endpoints we have no throughput estimate for (unless
        // they have only ever failed), spreading slots round-robin.
        let unprobed: Vec<usize> = (0..self.stats.len())
            .filter(|&m| {
                self.stats[m].ewma_mbps.is_none()
                    && self.stats[m].decayed_fails(now_s) < UNPROBED_FAIL_LIMIT
            })
            .collect();
        if !unprobed.is_empty() {
            let m = unprobed[self.rr % unprobed.len()];
            self.rr += 1;
            return m;
        }
        self.preferred(now_s)
    }

    /// Best-scoring probed mirror (lowest index wins ties; mirror 0
    /// when nothing is probed yet).
    pub fn preferred(&self, now_s: f64) -> usize {
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for m in 0..self.stats.len() {
            if let Some(sc) = self.score(m, now_s) {
                if sc > best_score {
                    best_score = sc;
                    best = m;
                }
            }
        }
        best
    }

    /// Should an idle slot bound to `current` reconnect elsewhere?
    pub fn should_failover(&self, current: usize, now_s: f64) -> bool {
        if self.stats.len() < 2 {
            return false;
        }
        let Some(cur) = self.score(current, now_s) else {
            return false;
        };
        let best = self.preferred(now_s);
        if best == current {
            return false;
        }
        match self.score(best, now_s) {
            Some(best_sc) => cur < best_sc * FAILOVER_RATIO,
            None => false,
        }
    }

    /// Record that a worker slot attempted a connection to mirror `m`
    /// (successful or not): resets the mirror's re-probe timer.
    pub fn note_connect(&mut self, m: usize, now_s: f64) {
        self.last_attempt_s[m] = now_s;
    }

    /// Striping weights at `now_s`, one per mirror, all strictly
    /// positive with a max of exactly the best score (or `1.0` when
    /// nothing is probed yet):
    ///
    /// * probed mirrors use their health [`MirrorBoard::score`],
    ///   floored at `floor × best` so a degraded-but-working mirror
    ///   keeps a proportional trickle of traffic;
    /// * unprobed mirrors that have not persistently failed are
    ///   optimistic (best score) so exploration spreads early
    ///   connections evenly;
    /// * unprobed mirrors past the failure limit get only a token
    ///   weight **below** the floor — re-admission happens through the
    ///   re-probe path, not D'Hondt.
    pub fn weights(&self, now_s: f64, floor: f64) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.stats.len());
        self.weights_into(now_s, floor, &mut out);
        out
    }

    /// [`MirrorBoard::weights`] into a caller-owned buffer — the
    /// engine's per-tick path, so a steady-state control tick performs
    /// no allocation.
    pub fn weights_into(&self, now_s: f64, floor: f64, out: &mut Vec<f64>) {
        out.clear();
        let best = (0..self.stats.len())
            .filter_map(|m| self.score(m, now_s))
            .fold(0.0f64, f64::max);
        let best = if best > 0.0 { best } else { 1.0 };
        let floor = floor.clamp(0.0, 0.5);
        out.extend((0..self.stats.len()).map(|m| match self.score(m, now_s) {
            Some(sc) => sc.max(best * floor).max(best * 1e-3),
            None if self.stats[m].decayed_fails(now_s) < UNPROBED_FAIL_LIMIT => best,
            None => best * 1e-3,
        }));
    }

    /// Mirror `m` is due a probe connection: it has no live connections
    /// and none were attempted for [`REPROBE_INTERVAL_S`].
    /// `conns[m]` is the engine's live per-mirror connection count.
    ///
    /// When several mirrors are due at once the **lowest-RTT** one wins
    /// (ties, and mirrors with no RTT estimate yet — treated as zero —
    /// break toward the lowest index): a probe pays the full handshake
    /// to move a single chunk, so latency dominates its cost in a way
    /// it does not for bulk transfers.
    pub fn probe_due(&self, now_s: f64, conns: &[usize]) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for m in 0..self.stats.len() {
            if conns[m] != 0 || now_s - self.last_attempt_s[m] < REPROBE_INTERVAL_S {
                continue;
            }
            let rtt = self.stats[m].ewma_rtt_s.unwrap_or(0.0);
            match best {
                Some((_, r)) if rtt >= r => {}
                _ => best = Some((m, rtt)),
            }
        }
        best.map(|(m, _)| m)
    }

    /// Striping pick: the mirror a (re)connecting slot should bind to,
    /// or `None` when every mirror is at its connection cap
    /// (`cap == 0` disables the cap).
    ///
    /// Probe-due mirrors win outright; otherwise the highest-averages
    /// rule `weight / (conns + 1)` allocates connections proportionally
    /// to the weight vector, with excess demand spilling onto lower-
    /// weighted mirrors once the leaders hit their caps. Ties break
    /// toward the lowest index, so the pick is fully deterministic.
    pub fn pick_for_stripe(
        &self,
        now_s: f64,
        conns: &[usize],
        cap: usize,
        floor: f64,
    ) -> Option<usize> {
        self.pick_for_stripe_with(now_s, conns, cap, &self.weights(now_s, floor))
    }

    /// [`MirrorBoard::pick_for_stripe`] with a caller-supplied
    /// [`MirrorBoard::weights`] vector. Weights are tick-constant (they
    /// depend only on board scores at `now_s`, not on connection
    /// counts), so the engine computes them once per control tick into
    /// a reused scratch buffer and feeds every (re)connect pick from it
    /// — after a mass disconnect the reconcile pass may reconnect many
    /// slots in one tick, and recomputing (allocating) weights per pick
    /// would undo the allocation-free tick.
    pub fn pick_for_stripe_with(
        &self,
        now_s: f64,
        conns: &[usize],
        cap: usize,
        weights: &[f64],
    ) -> Option<usize> {
        let open = |m: usize| cap == 0 || conns[m] < cap;
        if let Some(m) = self.probe_due(now_s, conns) {
            if open(m) {
                return Some(m);
            }
        }
        let mut best: Option<(usize, f64)> = None;
        for m in 0..self.stats.len() {
            if !open(m) {
                continue;
            }
            let gain = weights[m] / (conns[m] + 1) as f64;
            match best {
                Some((_, g)) if gain <= g => {}
                _ => best = Some((m, gain)),
            }
        }
        best.map(|(m, _)| m)
    }

    /// Should an idle striped slot bound to `current` release its
    /// connection and rebind? Yes when some other mirror (with cap
    /// headroom) offers at least [`STRIPE_GAIN`]× the slot's current
    /// expected share — the weighted analogue of
    /// [`MirrorBoard::should_failover`], with hysteresis so comparable
    /// mirrors never flap under goodput jitter.
    ///
    /// `weights` is a [`MirrorBoard::weights`] vector; the caller
    /// computes it once per engine tick (it does not depend on the
    /// per-mirror connection counts) instead of once per idle slot.
    pub fn should_restripe(
        &self,
        current: usize,
        conns: &[usize],
        cap: usize,
        weights: &[f64],
    ) -> bool {
        if self.stats.len() < 2 || conns[current] == 0 {
            return false;
        }
        let here = weights[current] / conns[current] as f64;
        (0..self.stats.len())
            .filter(|&m| m != current && (cap == 0 || conns[m] < cap))
            .any(|m| weights[m] / (conns[m] + 1) as f64 > here * STRIPE_GAIN)
    }

    /// Effective number of simultaneously useful mirrors in
    /// `[1, mirror_count]` — the inverse participation ratio
    /// `(Σw)² / Σw²` of the striping weights. Two equally healthy
    /// mirrors → 2.0 (concurrency is twice as cheap); one dominant
    /// mirror → ~1.0. Feeds the controllers' utility through
    /// [`crate::control::MirrorHealth`].
    pub fn concurrency_headroom(&self, now_s: f64) -> f64 {
        let w = self.weights(now_s, 0.0);
        let sum: f64 = w.iter().sum();
        let sq: f64 = w.iter().map(|x| x * x).sum();
        if sq <= 0.0 {
            return 1.0;
        }
        (sum * sum / sq).clamp(1.0, self.stats.len() as f64)
    }

    /// Aggregate decayed failure pressure: mean decayed failure weight
    /// per mirror, in units of ~4 recent failures (so a storm of
    /// rejects across the fleet pushes this toward 1.0). Feeds the
    /// controllers' utility through [`crate::control::MirrorHealth`].
    pub fn fail_pressure(&self, now_s: f64) -> f64 {
        let total: f64 = self.stats.iter().map(|s| s.decayed_fails(now_s)).sum();
        total / self.stats.len() as f64 / 4.0
    }

    /// Payload bytes credited per mirror (the report's `mirror_bytes`).
    pub fn bytes(&self) -> Vec<u64> {
        self.stats.iter().map(|s| s.bytes).collect()
    }

    /// Failures recorded per mirror (diagnostics).
    pub fn failures(&self) -> Vec<u64> {
        self.stats.iter().map(|s| s.failures).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unprobed_mirrors_are_spread_round_robin() {
        let mut b = MirrorBoard::new(3);
        let picks: Vec<usize> = (0..6).map(|_| b.pick_for_connect(0.0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn connects_prefer_the_faster_probed_mirror() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 1_000_000, 10.0); // 0.8 Mbps
        b.on_success(1, 10_000_000, 1.0); // 80 Mbps
        assert_eq!(b.preferred(10.0), 1);
        assert_eq!(b.pick_for_connect(10.0), 1);
    }

    #[test]
    fn failover_triggers_on_a_dominated_mirror() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 1_000_000, 10.0); // slow: 0.8 Mbps
        b.on_success(1, 10_000_000, 1.0); // fast: 80 Mbps
        assert!(b.should_failover(0, 10.0));
        assert!(!b.should_failover(1, 10.0));
    }

    #[test]
    fn comparable_mirrors_do_not_flap() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 8_000_000, 1.0);
        b.on_success(1, 10_000_000, 1.0);
        assert!(!b.should_failover(0, 1.0));
        assert!(!b.should_failover(1, 1.0));
    }

    #[test]
    fn failures_penalize_and_decay() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 10_000_000, 1.0);
        b.on_success(1, 10_000_000, 1.0);
        for _ in 0..5 {
            b.on_failure(0, 100.0);
        }
        let hurt = b.score(0, 100.0).unwrap();
        let healthy = b.score(1, 100.0).unwrap();
        assert!(hurt < healthy * 0.4, "rejects should crater the score");
        assert!(b.should_failover(0, 100.0));
        // Long after the burst the penalty decays away.
        let later = b.score(0, 400.0).unwrap();
        assert!(later > healthy * 0.9);
        assert_eq!(b.failures(), vec![5, 0]);
    }

    #[test]
    fn single_mirror_never_fails_over() {
        let mut b = MirrorBoard::new(1);
        b.on_success(0, 1_000, 10.0);
        for _ in 0..10 {
            b.on_failure(0, 5.0);
        }
        assert!(!b.should_failover(0, 5.0));
        assert_eq!(b.pick_for_connect(5.0), 0);
    }

    #[test]
    fn stripe_pick_allocates_proportionally_to_scores() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 1_250_000, 1.0); // 10 Mbps
        b.on_success(1, 3_750_000, 1.0); // 30 Mbps
        b.note_connect(0, 0.0);
        b.note_connect(1, 0.0);
        // Simulate 8 sequential connects, tracking counts like the
        // engine does: allocation should settle near 2:6 (1:3 weights).
        let mut conns = vec![0usize; 2];
        for _ in 0..8 {
            let m = b.pick_for_stripe(1.0, &conns, 0, 0.05).unwrap();
            conns[m] += 1;
        }
        assert_eq!(conns, vec![2, 6], "D'Hondt should track the 1:3 ratio");
    }

    #[test]
    fn stripe_pick_respects_per_mirror_caps_and_spills() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 1_250_000, 1.0); // 10 Mbps
        b.on_success(1, 12_500_000, 1.0); // 100 Mbps: dominant
        b.note_connect(0, 0.0);
        b.note_connect(1, 0.0);
        let mut conns = vec![0usize; 2];
        for _ in 0..6 {
            if let Some(m) = b.pick_for_stripe(1.0, &conns, 3, 0.05) {
                conns[m] += 1;
            }
        }
        // The dominant mirror fills to its cap, the rest spill over.
        assert_eq!(conns, vec![3, 3]);
        // Everything capped: no pick.
        assert_eq!(b.pick_for_stripe(1.0, &conns, 3, 0.05), None);
    }

    #[test]
    fn restripe_has_hysteresis_but_drains_a_slow_mirror() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 1_000_000, 1.0); // 8 Mbps
        b.on_success(1, 1_250_000, 1.0); // 10 Mbps: comparable
        // Comparable mirrors: no flapping in either direction.
        let w = b.weights(1.0, 0.05);
        assert!(!b.should_restripe(0, &[1, 1], 0, &w));
        assert!(!b.should_restripe(1, &[1, 1], 0, &w));
        // Crater mirror 0: its idle slots should rebind.
        for _ in 0..6 {
            b.on_failure(0, 2.0);
        }
        let w = b.weights(2.0, 0.05);
        assert!(b.should_restripe(0, &[1, 1], 0, &w));
        // ... but not when the healthy mirror is at its cap.
        assert!(!b.should_restripe(0, &[1, 1], 1, &w));
    }

    #[test]
    fn idle_mirror_becomes_probe_due_and_pick_prefers_it() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 1_250_000, 1.0);
        b.on_success(1, 12_500_000, 1.0);
        b.note_connect(0, 0.0);
        b.note_connect(1, 0.0);
        // Mirror 0 has no connections but was attempted recently.
        assert_eq!(b.probe_due(5.0, &[0, 3]), None);
        // Past the re-probe interval it is due, and the pick takes it
        // even though mirror 1 dominates on weight.
        let t = REPROBE_INTERVAL_S + 1.0;
        assert_eq!(b.probe_due(t, &[0, 3]), Some(0));
        assert_eq!(b.pick_for_stripe(t, &[0, 3], 0, 0.05), Some(0));
        // A fresh attempt resets the timer.
        b.note_connect(0, t);
        assert_eq!(b.probe_due(t + 1.0, &[0, 3]), None);
    }

    #[test]
    fn headroom_counts_effectively_healthy_mirrors() {
        let mut b = MirrorBoard::new(2);
        assert!((b.concurrency_headroom(0.0) - 2.0).abs() < 1e-9, "unprobed = optimistic");
        b.on_success(0, 1_250_000, 1.0); // 10 Mbps
        b.on_success(1, 1_250_000, 1.0); // 10 Mbps
        assert!((b.concurrency_headroom(1.0) - 2.0).abs() < 1e-6);
        // One mirror craters: headroom collapses toward 1.
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 125_000, 1.0); // 1 Mbps
        b.on_success(1, 1_250_000, 1.0); // 10 Mbps
        let h = b.concurrency_headroom(1.0);
        assert!(h < 1.3, "dominated mirror should not count: {h}");
        assert!(b.fail_pressure(1.0) == 0.0);
        b.on_failure(0, 1.0);
        assert!(b.fail_pressure(1.0) > 0.0);
    }

    #[test]
    fn rtt_penalty_is_mild_so_bandwidth_still_wins_bulk() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 12_500_000, 1.0); // 100 Mbps, but slow handshake
        b.on_success(1, 5_000_000, 1.0); // 40 Mbps, snappy handshake
        b.note_rtt(0, 1.0);
        b.note_rtt(1, 0.05);
        let s0 = b.score(0, 1.0).unwrap();
        let s1 = b.score(1, 1.0).unwrap();
        assert!(s0 > s1 * 2.0, "RTT must only tie-break, not dominate: {s0} vs {s1}");
        // D'Hondt still allocates the bulk share to the fat pipe.
        b.note_connect(0, 0.0);
        b.note_connect(1, 0.0);
        let mut conns = vec![0usize; 2];
        for _ in 0..8 {
            let m = b.pick_for_stripe(1.0, &conns, 0, 0.05).unwrap();
            conns[m] += 1;
        }
        assert!(
            conns[0] > conns[1],
            "high-RTT/high-bandwidth mirror lost its bulk share: {conns:?}"
        );
    }

    #[test]
    fn probes_prefer_the_low_rtt_mirror() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 12_500_000, 1.0);
        b.on_success(1, 5_000_000, 1.0);
        b.note_rtt(0, 1.0);
        b.note_rtt(1, 0.05);
        // Both mirrors drained and past the re-probe interval: the
        // low-RTT endpoint gets the probe despite its lower bandwidth.
        let t = REPROBE_INTERVAL_S + 5.0;
        assert_eq!(b.probe_due(t, &[0, 0]), Some(1));
        // With the low-RTT mirror busy, the other is still due.
        assert_eq!(b.probe_due(t, &[0, 2]), Some(0));
        // No RTT estimates at all: ties break to the lowest index (the
        // pre-RTT behaviour).
        let fresh = MirrorBoard::new(3);
        assert_eq!(fresh.probe_due(t, &[0, 0, 0]), Some(0));
    }

    #[test]
    fn rtt_ewma_smooths_samples() {
        let mut b = MirrorBoard::new(1);
        assert_eq!(b.rtt(0), None);
        b.note_rtt(0, 0.2);
        b.note_rtt(0, 0.4);
        let r = b.rtt(0).unwrap();
        assert!(r > 0.2 && r < 0.4, "EWMA should land between samples: {r}");
    }

    #[test]
    fn mean_rtt_averages_only_observed_mirrors() {
        let mut b = MirrorBoard::new(3);
        assert_eq!(b.mean_rtt(), None);
        b.note_rtt(0, 0.2);
        assert!((b.mean_rtt().unwrap() - 0.2).abs() < 1e-12);
        b.note_rtt(2, 0.4);
        let m = b.mean_rtt().unwrap();
        assert!(
            (m - 0.3).abs() < 1e-12,
            "unobserved mirror 1 must not drag the mean: {m}"
        );
    }

    #[test]
    fn weights_into_matches_weights_without_allocating_growth() {
        let mut b = MirrorBoard::new(3);
        b.on_success(0, 1_250_000, 1.0);
        b.on_success(2, 2_500_000, 1.0);
        let expect = b.weights(5.0, 0.05);
        let mut buf = Vec::with_capacity(3);
        b.weights_into(5.0, 0.05, &mut buf);
        assert_eq!(buf, expect);
        // Reuse keeps the same capacity.
        let cap = buf.capacity();
        b.weights_into(9.0, 0.05, &mut buf);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn byte_attribution() {
        let mut b = MirrorBoard::new(2);
        b.on_success(0, 100, 1.0);
        b.on_success(1, 250, 1.0);
        b.on_success(1, 50, 1.0);
        assert_eq!(b.bytes(), vec![100, 300]);
    }
}
