//! Virtual-time session driver: the [`crate::session::engine`] over
//! [`crate::netsim`].
//!
//! All control logic (Algorithm 1, retries, checkpoints, mirror
//! failover) lives in the unified engine; this module only adapts the
//! simulator to the engine's [`Transport`]/[`Clock`] traits:
//!
//! * [`SimTransport`] maps engine slots to simulator flows, opens each
//!   connection against the slot's bound mirror (so per-mirror fault
//!   injection lands on the right flows), and translates
//!   [`crate::netsim::FlowEvent`]s into [`TransportEvent`]s.
//! * [`VirtualClock`] is a shared cell the transport advances on every
//!   step — wall-clock cost is microseconds per simulated second, and
//!   determinism is total given `(params, seed)`.

use std::cell::Cell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use crate::accession::RunRecord;
use crate::config::DownloadConfig;
use crate::control::Controller;
use crate::coordinator::scheduler::Chunk;
use crate::metrics::recorder::ThroughputRecorder;
use crate::netsim::{FlowId, NetSim, NetSimConfig, StepReport};
use crate::runtime::XlaRuntime;
use crate::session::engine::{
    run_session_with_stats, Clock, EngineParams, EngineStats, FailureClass, Transport,
    TransportEvent,
};
use crate::session::SessionReport;
use crate::{Error, Result};

pub use crate::session::engine::ToolBehavior;

/// Virtual session clock: a shared cell the simulated transport writes
/// after every step. `park` is a no-op — stepping *is* time passing.
#[derive(Clone, Default)]
pub struct VirtualClock(Rc<Cell<f64>>);

impl VirtualClock {
    /// Fresh clock at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Move virtual time forward (called by the transport's poll).
    pub fn advance_to(&self, t_s: f64) {
        self.0.set(t_s);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.0.get()
    }

    fn park(&self, _secs: f64) {}
}

/// The engine's transport over the virtual-time network simulator.
pub struct SimTransport {
    sim: NetSim,
    /// Engine slot → simulator flow.
    flows: Vec<Option<FlowId>>,
    /// Simulator flow → engine slot: the inverse of `flows`, kept in
    /// lockstep so translating a step's events is O(1) per event
    /// instead of a scan over all `c_max` slots.
    flow_slots: HashMap<FlowId, usize>,
    recorder: Arc<ThroughputRecorder>,
    clock: VirtualClock,
    /// Per-mirror connection cap (0 = unlimited), mirrored into the
    /// simulator so the flow table enforces it too.
    per_mirror_conns: usize,
    /// Reused step-report buffer ([`NetSim::step_into`]) so polling the
    /// simulator allocates nothing in steady state.
    scratch: StepReport,
}

impl SimTransport {
    /// Build over a fresh simulator for `capacity` engine slots.
    /// `per_mirror_conns` caps simultaneous connections per mirror
    /// (0 = unlimited), enforced both here and in the flow table.
    pub fn new(
        cfg: NetSimConfig,
        seed: u64,
        capacity: usize,
        per_mirror_conns: usize,
        recorder: Arc<ThroughputRecorder>,
        clock: VirtualClock,
    ) -> Result<SimTransport> {
        let mut sim = NetSim::new(cfg, seed)?;
        sim.set_per_mirror_connection_cap(per_mirror_conns);
        Ok(SimTransport {
            sim,
            flows: vec![None; capacity],
            flow_slots: HashMap::new(),
            recorder,
            clock,
            per_mirror_conns,
            scratch: StepReport::default(),
        })
    }
}

impl Transport for SimTransport {
    fn connect(&mut self, slot: usize, mirror: usize) -> Result<bool> {
        if self.sim.open_flows() >= self.sim.config().server.max_connections {
            return Ok(false);
        }
        if self.per_mirror_conns > 0 && self.sim.open_flows_to(mirror) >= self.per_mirror_conns {
            return Ok(false); // this mirror is at its connection cap
        }
        let id = self.sim.open_flow_to(mirror)?;
        if let Some(old) = self.flows[slot].replace(id) {
            self.flow_slots.remove(&old);
        }
        self.flow_slots.insert(id, slot);
        Ok(true)
    }

    fn disconnect(&mut self, slot: usize) {
        if let Some(id) = self.flows[slot].take() {
            self.flow_slots.remove(&id);
            self.sim.close_flow(id);
        }
    }

    fn is_ready(&self, slot: usize) -> bool {
        self.flows[slot]
            .map(|id| self.sim.flow_ready(id))
            .unwrap_or(false)
    }

    fn begin_fetch(
        &mut self,
        slot: usize,
        _record: &RunRecord,
        chunk: &Chunk,
        _mirror: usize,
    ) -> Result<()> {
        let id = self.flows[slot]
            .ok_or_else(|| Error::Sim(format!("begin_fetch on disconnected slot {slot}")))?;
        self.sim
            .begin_request(id, chunk.len as f64, chunk.cold, slot as u64)
    }

    fn poll(&mut self, events: &mut Vec<TransportEvent>) -> Result<()> {
        self.sim.step_into(None, &mut self.scratch);
        self.clock.advance_to(self.scratch.now_s);
        for ev in &self.scratch.events {
            let Some(&slot) = self.flow_slots.get(&ev.id) else {
                continue; // flow already released by the engine
            };
            if ev.failed {
                // The simulator killed the flow.
                self.flows[slot] = None;
                self.flow_slots.remove(&ev.id);
                events.push(TransportEvent::Failed {
                    slot,
                    class: FailureClass::Transport,
                    error: "injected connection reset".into(),
                });
                continue;
            }
            if ev.rejected {
                events.push(TransportEvent::Failed {
                    slot,
                    class: FailureClass::Reject,
                    error: "transient server rejection".into(),
                });
                continue;
            }
            if ev.bytes > 0.0 {
                self.recorder.add_bytes(ev.bytes as u64);
            }
            if ev.request_done {
                events.push(TransportEvent::Completed { slot });
            } else if ev.became_ready {
                events.push(TransportEvent::Ready { slot });
            }
        }
        Ok(())
    }

    fn set_open_files(&mut self, n: usize) {
        self.sim.set_open_files(n);
    }
}

/// Everything a simulated session needs.
pub struct SimSessionParams<'a> {
    /// Transfer configuration (chunking, optimizer, mirror policy).
    pub download: DownloadConfig,
    /// Tool-level behaviour (chunked vs whole-file, keep-alive, …).
    pub behavior: ToolBehavior,
    /// Simulated network topology and fault schedule.
    pub netsim: NetSimConfig,
    /// Resolved files (with their mirror lists) to download.
    pub records: Vec<RunRecord>,
    /// Controller (already built for the tool's policy).
    pub controller: Box<dyn Controller + 'a>,
    /// XLA runtime for probe aggregation (None → pure-Rust mirror;
    /// adaptive controllers carry their own runtime handle for the
    /// decision step regardless).
    pub runtime: Option<&'a XlaRuntime>,
    /// Simulation seed: identical `(params, seed)` replay bit-identically.
    pub seed: u64,
}

/// The simulated driver: parameter plumbing around the unified engine.
pub struct SimSession<'a> {
    params: SimSessionParams<'a>,
    done_prefix: Option<Vec<u64>>,
    checkpoint_after_s: Option<f64>,
}

impl<'a> SimSession<'a> {
    /// Wrap parameters into a runnable session.
    pub fn new(params: SimSessionParams<'a>) -> SimSession<'a> {
        SimSession {
            params,
            done_prefix: None,
            checkpoint_after_s: None,
        }
    }

    /// Resume: `prefix[i]` bytes of file `i` are already on disk (a
    /// [`crate::coordinator::resume::ProgressJournal`]'s frontiers) and
    /// are never re-requested.
    pub fn with_progress(mut self, prefix: Vec<u64>) -> SimSession<'a> {
        self.done_prefix = Some(prefix);
        self
    }

    /// Interrupt the session after `secs` of virtual transfer time —
    /// the simulated analogue of a crash/Ctrl-C, used to test
    /// checkpoint/restore across injected failures.
    pub fn with_checkpoint_after(mut self, secs: f64) -> SimSession<'a> {
        self.checkpoint_after_s = Some(secs);
        self
    }

    /// Run to completion (or checkpoint); returns the report.
    pub fn run(self) -> Result<SessionReport> {
        self.run_with_stats().map(|(report, _)| report)
    }

    /// [`SimSession::run`], additionally returning the engine's
    /// control-loop cost counters (the `fastbiodl bench` measurement
    /// path; see [`EngineStats`]).
    pub fn run_with_stats(self) -> Result<(SessionReport, EngineStats)> {
        let SimSession {
            params,
            done_prefix,
            checkpoint_after_s,
        } = self;
        let recorder = Arc::new(ThroughputRecorder::new());
        let clock = VirtualClock::new();
        let mut transport = SimTransport::new(
            params.netsim,
            params.seed,
            params.download.optimizer.c_max,
            params.download.mirror.per_mirror_conns,
            recorder.clone(),
            clock.clone(),
        )?;
        run_session_with_stats(
            EngineParams {
                download: params.download,
                behavior: params.behavior,
                records: params.records,
                controller: params.controller,
                runtime: params.runtime,
                recorder,
                done_prefix,
                checkpoint_after_s,
                journal_dir: None,
                // Simulated fault schedules are adversarial by design;
                // recovery must outlast them rather than give up.
                give_up_after: usize::MAX,
            },
            &mut transport,
            &clock,
        )
    }
}

/// Convenience wrapper: run FastBioDL (adaptive GD) over a record list
/// on a scenario profile. Used by the quickstart example and the CLI.
pub fn run_simulated_download(
    cfg: &DownloadConfig,
    netsim: &NetSimConfig,
    records: Vec<RunRecord>,
    runtime: crate::runtime::SharedRuntime,
    seed: u64,
) -> Result<SessionReport> {
    let controller = crate::optimizer::build_controller_with(
        &cfg.optimizer,
        &cfg.control,
        Some(runtime.clone()),
    )?;
    let params = SimSessionParams {
        download: cfg.clone(),
        behavior: ToolBehavior::fastbiodl(cfg),
        netsim: netsim.clone(),
        records,
        controller,
        runtime: Some(&runtime),
        seed,
    };
    SimSession::new(params).run()
}
