//! Virtual-time session driver.
//!
//! Runs a complete transfer against the [`crate::netsim`] engine:
//! resolution → chunk scheduling → a worker-slot pool reconciled
//! against the Algorithm 1 status array → monitor sampling → probing
//! optimizer loop → completion. Wall-clock cost is microseconds per
//! simulated second; determinism is total given `(params, seed)`.
//!
//! The per-tool behavioural differences (DESIGN.md §2) are all
//! expressed as [`ToolBehavior`] fields, so FastBioDL and the baselines
//! run through *identical* machinery and differ only in policy:
//! scheduling granularity, connection reuse, resolution cost, and the
//! concurrency controller.

use crate::accession::resolver::ResolutionCost;
use crate::accession::RunRecord;
use crate::config::DownloadConfig;
use crate::coordinator::pool::StatusArray;
use crate::coordinator::probe::ProbeWindow;
use crate::coordinator::scheduler::{Chunk, ChunkScheduler, SchedulerMode};
use crate::metrics::recorder::ThroughputRecorder;
use crate::metrics::timeline::per_second_bins;
use crate::netsim::{FlowId, NetSim, NetSimConfig};
use crate::optimizer::{ConcurrencyController, Probe};
use crate::runtime::XlaRuntime;
use crate::session::SessionReport;
use crate::{Error, Result};

/// Tool-level behaviour knobs (what distinguishes FastBioDL from the
/// baseline tools besides the controller).
#[derive(Clone, Debug)]
pub struct ToolBehavior {
    /// Display label.
    pub name: String,
    /// Range-chunked vs whole-file requests.
    pub mode: SchedulerMode,
    /// Reuse connections across requests (keep-alive). Baselines open
    /// a fresh connection per file.
    pub keep_alive: bool,
    /// Metadata resolution cost model.
    pub resolution: ResolutionCost,
}

impl ToolBehavior {
    /// FastBioDL: chunked, keep-alive, batch resolution (paper §4).
    pub fn fastbiodl(cfg: &DownloadConfig) -> ToolBehavior {
        ToolBehavior {
            name: "fastbiodl".into(),
            mode: SchedulerMode::Chunked {
                chunk_bytes: cfg.chunk_bytes,
                max_open_files: cfg.max_open_files,
            },
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 1.5 },
        }
    }
}

/// Everything a simulated session needs.
pub struct SimSessionParams<'a> {
    pub download: DownloadConfig,
    pub behavior: ToolBehavior,
    pub netsim: NetSimConfig,
    pub records: Vec<RunRecord>,
    /// Controller (already built for the tool's policy).
    pub controller: Box<dyn ConcurrencyController + 'a>,
    /// XLA runtime for probe aggregation (None → pure-Rust mirror;
    /// adaptive controllers carry their own runtime handle for the
    /// decision step regardless).
    pub runtime: Option<&'a XlaRuntime>,
    pub seed: u64,
}

/// Slot backoff bounds (virtual seconds) after a failed or rejected
/// chunk: doubles per consecutive failure, resets on success.
const BACKOFF_MIN_S: f64 = 0.25;
const BACKOFF_MAX_S: f64 = 4.0;

/// Per-worker-slot state.
#[derive(Debug)]
struct WorkerSlot {
    flow: Option<FlowId>,
    chunk: Option<Chunk>,
    /// Chunk assigned but request not yet issued (serialized resolution
    /// or connection still in setup); issue when `now >= wait_until`.
    wait_until: f64,
    /// Request currently in flight.
    in_flight: bool,
    /// No new request before this time (failure backoff).
    next_allowed: f64,
    /// Current backoff span; doubles per consecutive failure.
    backoff_s: f64,
}

impl Default for WorkerSlot {
    fn default() -> Self {
        WorkerSlot {
            flow: None,
            chunk: None,
            wait_until: 0.0,
            in_flight: false,
            next_allowed: 0.0,
            backoff_s: BACKOFF_MIN_S,
        }
    }
}

impl WorkerSlot {
    /// Register a failed/rejected attempt: next request waits out an
    /// exponentially growing backoff.
    fn penalize(&mut self, now: f64) {
        self.next_allowed = now + self.backoff_s;
        self.backoff_s = (self.backoff_s * 2.0).min(BACKOFF_MAX_S);
    }

    fn reward(&mut self) {
        self.backoff_s = BACKOFF_MIN_S;
    }
}

/// The driver.
pub struct SimSession<'a> {
    params: SimSessionParams<'a>,
    /// Bytes already on disk per file (resume from a prior journal).
    done_prefix: Option<Vec<u64>>,
    /// Stop (checkpoint) after this much virtual transfer time; the
    /// report then has `completed == false` and carries the frontiers
    /// a follow-up session resumes from.
    checkpoint_after_s: Option<f64>,
}

impl<'a> SimSession<'a> {
    pub fn new(params: SimSessionParams<'a>) -> SimSession<'a> {
        SimSession {
            params,
            done_prefix: None,
            checkpoint_after_s: None,
        }
    }

    /// Resume: `prefix[i]` bytes of file `i` are already on disk (a
    /// [`crate::coordinator::resume::ProgressJournal`]'s frontiers) and
    /// are never re-requested.
    pub fn with_progress(mut self, prefix: Vec<u64>) -> SimSession<'a> {
        self.done_prefix = Some(prefix);
        self
    }

    /// Interrupt the session after `secs` of virtual transfer time —
    /// the simulated analogue of a crash/Ctrl-C, used to test
    /// checkpoint/restore across injected failures.
    pub fn with_checkpoint_after(mut self, secs: f64) -> SimSession<'a> {
        self.checkpoint_after_s = Some(secs);
        self
    }

    /// Run to completion (or checkpoint); returns the report.
    pub fn run(mut self) -> Result<SessionReport> {
        let done_prefix = self.done_prefix.take();
        let checkpoint_after_s = self.checkpoint_after_s;
        let p = &mut self.params;
        p.download.validate()?;
        let mut sim = NetSim::new(p.netsim.clone(), p.seed)?;
        let mut sched =
            ChunkScheduler::new_with_progress(&p.records, p.behavior.mode, done_prefix.as_deref());
        let capacity = p.download.optimizer.c_max;
        let status = StatusArray::new(capacity);
        let recorder = ThroughputRecorder::new();
        let mut window = ProbeWindow::new(
            p.runtime.map(|r| r.constants().samples).unwrap_or(256),
            0.98,
        );
        let mut slots: Vec<WorkerSlot> = (0..capacity).map(|_| WorkerSlot::default()).collect();

        // Metadata resolution: batch pays upfront; serialized pays per
        // cold file via `res_free`.
        let upfront = p.behavior.resolution.upfront_latency(p.records.len());
        while sim.now() < upfront {
            sim.step(None);
        }
        let mut res_free = sim.now();

        let mut target = status.set_target(p.controller.current());
        let mut trace = vec![(sim.now(), target)];
        let start = sim.now();
        let sample_dt = 1.0 / p.download.monitor_hz;
        let probe_dt = p.download.optimizer.probe_interval_s;
        let mut next_sample = start + sample_dt;
        let mut next_probe = start + probe_dt;
        let mut probes = 0usize;
        // Time-weighted target integral for the paper's Concurrency column.
        let mut target_time = 0.0f64;
        // Recovery accounting (fault injection / hostile scenarios).
        let mut chunk_retries = 0usize;
        let mut connection_resets = 0usize;
        let mut server_rejects = 0usize;
        let mut completed = true;
        let hard_timeout = if p.download.timeout_s > 0.0 {
            p.download.timeout_s
        } else {
            48.0 * 3600.0
        };

        while !sched.all_done() {
            let now = sim.now();
            if let Some(limit) = checkpoint_after_s {
                if now - start >= limit {
                    completed = false;
                    break;
                }
            }
            if now - start > hard_timeout {
                status.stop_all();
                return Err(Error::Session(format!(
                    "transfer timed out after {:.0}s (delivered {}/{} bytes)",
                    now - start,
                    sched.progress().0,
                    sched.progress().1
                )));
            }

            // --- Reconcile worker slots against the status array. ---
            for (i, slot) in slots.iter_mut().enumerate() {
                let running = status.is_running(i);
                if running && slot.flow.is_none() {
                    // Bring the worker up: open its connection.
                    if sim.open_flows() < sim.config().server.max_connections {
                        slot.flow = Some(sim.open_flow()?);
                    }
                } else if !running && !slot.in_flight {
                    // Parked and drained: release the connection, and
                    // requeue any chunk that was assigned but never
                    // issued (waiting on resolution/handshake) — a
                    // parked worker must not strand outstanding work.
                    if let Some(f) = slot.flow.take() {
                        sim.close_flow(f);
                    }
                    if let Some(chunk) = slot.chunk.take() {
                        sched.chunk_failed(chunk);
                        chunk_retries += 1;
                    }
                }
            }

            // --- Assign work to ready workers. ---
            for (i, slot) in slots.iter_mut().enumerate() {
                if !status.is_running(i) || slot.in_flight {
                    continue;
                }
                let Some(flow) = slot.flow else { continue };
                if !sim.flow_ready(flow) {
                    continue; // still in handshake
                }
                if slot.chunk.is_none() {
                    // Pull the next chunk, charging serialized
                    // resolution for cold files where applicable, and
                    // honoring the slot's failure backoff.
                    let per_file = p.behavior.resolution.per_file_latency();
                    if let Some(chunk) = sched.next_chunk() {
                        let mut wait = now.max(slot.next_allowed);
                        if chunk.cold && per_file > 0.0 {
                            let begin = res_free.max(wait);
                            res_free = begin + per_file;
                            wait = begin + per_file;
                        }
                        slot.wait_until = wait;
                        slot.chunk = Some(chunk);
                    }
                }
                if let Some(chunk) = &slot.chunk {
                    if now >= slot.wait_until {
                        sim.begin_request(flow, chunk.len as f64, chunk.cold, i as u64)?;
                        slot.in_flight = true;
                    }
                }
            }

            sim.set_open_files(sched.open_files());

            // --- Advance the world. ---
            let t_before = sim.now();
            let rep = sim.step(None);
            target_time += target as f64 * (rep.now_s - t_before);

            // --- Account deliveries. ---
            for ev in &rep.events {
                if ev.failed || ev.rejected {
                    // Connection reset (flow is dead) or transient
                    // server rejection (flow survives): requeue the
                    // remaining work and back the slot off before its
                    // next attempt.
                    if let Some(slot) = slots.iter_mut().find(|s| s.flow == Some(ev.id)) {
                        if let Some(chunk) = slot.chunk.take() {
                            // Bytes already delivered for this chunk are
                            // counted; re-download the whole chunk (range
                            // requests restart cleanly at chunk grain).
                            sched.chunk_failed(chunk);
                            chunk_retries += 1;
                        }
                        slot.in_flight = false;
                        slot.penalize(rep.now_s);
                        if ev.failed {
                            connection_resets += 1;
                            slot.flow = None; // reconcile reopens one
                        } else {
                            server_rejects += 1;
                        }
                    }
                    continue;
                }
                if ev.bytes <= 0.0 && !ev.request_done {
                    continue;
                }
                recorder.add_bytes(ev.bytes as u64);
                if ev.request_done {
                    // Which slot owns this flow?
                    if let Some(slot) = slots.iter_mut().find(|s| s.flow == Some(ev.id)) {
                        let chunk = slot
                            .chunk
                            .take()
                            .expect("request completed with no chunk assigned");
                        sched.chunk_done(&chunk);
                        slot.in_flight = false;
                        slot.reward();
                        if !p.behavior.keep_alive {
                            // Baselines: fresh connection per request.
                            sim.close_flow(ev.id);
                            slot.flow = None;
                        }
                    }
                }
            }

            let now = rep.now_s;

            // --- Monitor sampling. ---
            if now >= next_sample {
                let active = slots.iter().filter(|s| s.in_flight).count();
                let mbps = recorder.sample(now - start, active);
                window.push(mbps);
                next_sample += sample_dt;
            }

            // --- Probing optimizer loop (Algorithm 1 body). ---
            if now >= next_probe {
                let stats = match p.runtime {
                    Some(rt) => window.aggregate_and_reset(rt)?,
                    None => {
                        let s = window.aggregate_mirror();
                        window = ProbeWindow::new(256, 0.98);
                        s
                    }
                };
                probes += 1;
                let new_target = p.controller.on_probe(Probe {
                    concurrency: target as f64,
                    mbps: stats.mean_mbps,
                })?;
                if new_target != target {
                    target = status.set_target(new_target);
                    trace.push((now - start, target));
                }
                next_probe += probe_dt;
            }
        }

        // Algorithm 1 line 9.
        status.stop_all();

        let duration = (sim.now() - start).max(f64::EPSILON);
        let samples = recorder.samples();
        let timeline = per_second_bins(&samples);
        let total_bytes = recorder.total_bytes();
        Ok(SessionReport {
            tool: p.behavior.name.clone(),
            duration_s: duration,
            total_bytes,
            mean_throughput_mbps: total_bytes as f64 * 8.0 / 1e6 / duration,
            mean_concurrency: target_time / duration,
            mean_inflight: recorder.mean_concurrency(),
            peak_mbps: timeline.peak(),
            timeline,
            samples,
            concurrency_trace: trace,
            probes,
            files_completed: sched.files_completed(),
            chunk_retries,
            connection_resets,
            server_rejects,
            completed,
            frontiers: sched.frontiers(),
        })
    }
}

/// Convenience wrapper: run FastBioDL (adaptive GD) over a record list
/// on a scenario profile. Used by the quickstart example and the CLI.
pub fn run_simulated_download(
    cfg: &DownloadConfig,
    netsim: &NetSimConfig,
    records: Vec<RunRecord>,
    runtime: crate::runtime::SharedRuntime,
    seed: u64,
) -> Result<SessionReport> {
    let controller = crate::optimizer::build_controller(&cfg.optimizer, Some(runtime.clone()))?;
    let params = SimSessionParams {
        download: cfg.clone(),
        behavior: ToolBehavior::fastbiodl(cfg),
        netsim: netsim.clone(),
        records,
        controller,
        runtime: Some(&runtime),
        seed,
    };
    SimSession::new(params).run()
}
