//! Virtual-time session driver: the [`crate::session::engine`] over
//! [`crate::netsim`].
//!
//! All control logic (Algorithm 1, retries, checkpoints, mirror
//! failover) lives in the unified engine; this module only adapts the
//! simulator to the engine's [`Transport`]/[`Clock`] traits:
//!
//! * [`SimTransport`] maps engine slots to simulator flows, opens each
//!   connection against the slot's bound mirror (so per-mirror fault
//!   injection lands on the right flows), and translates
//!   [`crate::netsim::FlowEvent`]s into [`TransportEvent`]s.
//! * [`VirtualClock`] is a shared cell the transport advances on every
//!   step — wall-clock cost is microseconds per simulated second, and
//!   determinism is total given `(params, seed)`.

use std::cell::Cell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::Arc;

use crate::accession::RunRecord;
use crate::config::DownloadConfig;
use crate::control::Controller;
use crate::coordinator::manifest::ManifestSet;
use crate::coordinator::scheduler::Chunk;
use crate::metrics::recorder::ThroughputRecorder;
use crate::netsim::{FlowId, NetSim, NetSimConfig, StepReport};
use crate::runtime::XlaRuntime;
use crate::session::engine::{
    run_session_with_stats, Clock, EngineParams, EngineStats, FailureClass, Transport,
    TransportEvent,
};
use crate::session::SessionReport;
use crate::trace::Tracer;
use crate::{Error, Result};

pub use crate::session::engine::ToolBehavior;

/// Ground-truth digest of a simulated chunk.
///
/// The simulator moves byte *counts*, not byte *values*, so the
/// canonical content of chunk `(accession, offset, len)` is defined as
/// the SHA-256 of that triple. The transport computes it on completion
/// and the session pre-records it in the expected manifest — playing
/// the role of provider-published checksums — so a corrupted delivery
/// (digest perturbed) mismatches exactly like a real flipped bit would.
pub fn sim_chunk_digest(accession: &str, offset: u64, len: u64) -> [u8; 32] {
    crate::util::sha256::sha256(format!("{accession}:{offset}+{len}").as_bytes())
}

/// Virtual session clock: a shared cell the simulated transport writes
/// after every step. `park` is a no-op — stepping *is* time passing.
#[derive(Clone, Default)]
pub struct VirtualClock(Rc<Cell<f64>>);

impl VirtualClock {
    /// Fresh clock at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Move virtual time forward (called by the transport's poll).
    pub fn advance_to(&self, t_s: f64) {
        self.0.set(t_s);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.0.get()
    }

    fn park(&self, _secs: f64) {}
}

/// The engine's transport over the virtual-time network simulator.
pub struct SimTransport {
    sim: NetSim,
    /// Engine slot → simulator flow.
    flows: Vec<Option<FlowId>>,
    /// Simulator flow → engine slot: the inverse of `flows`, kept in
    /// lockstep so translating a step's events is O(1) per event
    /// instead of a scan over all `c_max` slots.
    flow_slots: HashMap<FlowId, usize>,
    recorder: Arc<ThroughputRecorder>,
    clock: VirtualClock,
    /// Per-mirror connection cap (0 = unlimited), mirrored into the
    /// simulator so the flow table enforces it too.
    per_mirror_conns: usize,
    /// Reused step-report buffer ([`NetSim::step_into`]) so polling the
    /// simulator allocates nothing in steady state.
    scratch: StepReport,
    /// Whether completions carry a chunk digest (`--verify`). Off by
    /// default so unverified sessions skip the hashing work entirely
    /// and stay bit-identical to pre-integrity behaviour.
    verify: bool,
    /// Per-slot in-flight chunk identities `(accession, offset, len)`,
    /// recorded at `begin_fetch` so the completion digest can be
    /// derived ([`sim_chunk_digest`]). A queue because a pipelined slot
    /// carries several requests on the wire at once; responses resolve
    /// FIFO, so each completion (or rejection) pops the front. Depth 1
    /// keeps at most one entry — identical to the old single cell.
    chunk_meta: Vec<VecDeque<(String, u64, u64)>>,
}

impl SimTransport {
    /// Build over a fresh simulator for `capacity` engine slots.
    /// `per_mirror_conns` caps simultaneous connections per mirror
    /// (0 = unlimited), enforced both here and in the flow table.
    pub fn new(
        cfg: NetSimConfig,
        seed: u64,
        capacity: usize,
        per_mirror_conns: usize,
        recorder: Arc<ThroughputRecorder>,
        clock: VirtualClock,
    ) -> Result<SimTransport> {
        let mut sim = NetSim::new(cfg, seed)?;
        sim.set_per_mirror_connection_cap(per_mirror_conns);
        Ok(SimTransport {
            sim,
            flows: vec![None; capacity],
            flow_slots: HashMap::new(),
            recorder,
            clock,
            per_mirror_conns,
            scratch: StepReport::default(),
            verify: false,
            chunk_meta: vec![VecDeque::new(); capacity],
        })
    }

    /// Enable per-chunk digests on completion events (`--verify`).
    pub fn set_verify(&mut self, on: bool) {
        self.verify = on;
    }
}

impl Transport for SimTransport {
    fn connect(&mut self, slot: usize, mirror: usize) -> Result<bool> {
        if self.sim.open_flows() >= self.sim.config().server.max_connections {
            return Ok(false);
        }
        if self.per_mirror_conns > 0 && self.sim.open_flows_to(mirror) >= self.per_mirror_conns {
            return Ok(false); // this mirror is at its connection cap
        }
        let id = self.sim.open_flow_to(mirror)?;
        if let Some(old) = self.flows[slot].replace(id) {
            self.flow_slots.remove(&old);
        }
        self.flow_slots.insert(id, slot);
        Ok(true)
    }

    fn disconnect(&mut self, slot: usize) {
        if let Some(id) = self.flows[slot].take() {
            self.flow_slots.remove(&id);
            self.sim.close_flow(id);
        }
        self.chunk_meta[slot].clear();
    }

    fn is_ready(&self, slot: usize) -> bool {
        self.flows[slot]
            .map(|id| self.sim.flow_ready(id))
            .unwrap_or(false)
    }

    fn begin_fetch(
        &mut self,
        slot: usize,
        record: &RunRecord,
        chunk: &Chunk,
        _mirror: usize,
    ) -> Result<()> {
        let id = self.flows[slot]
            .ok_or_else(|| Error::Sim(format!("begin_fetch on disconnected slot {slot}")))?;
        if self.verify {
            self.chunk_meta[slot].push_back((record.accession.clone(), chunk.offset, chunk.len));
        }
        // `queue_request` is `begin_request` when the flow is idle, and
        // enqueues behind the in-flight response when the engine
        // pipelines a train chunk onto a busy connection.
        self.sim
            .queue_request(id, chunk.len as f64, chunk.cold, slot as u64)
    }

    fn poll(&mut self, events: &mut Vec<TransportEvent>) -> Result<()> {
        self.sim.step_into(None, &mut self.scratch);
        self.clock.advance_to(self.scratch.now_s);
        for ev in &self.scratch.events {
            let Some(&slot) = self.flow_slots.get(&ev.id) else {
                continue; // flow already released by the engine
            };
            if ev.failed {
                // The simulator killed the flow, and any pipelined
                // requests queued behind the head died with it.
                self.flows[slot] = None;
                self.flow_slots.remove(&ev.id);
                self.chunk_meta[slot].clear();
                events.push(TransportEvent::Failed {
                    slot,
                    class: FailureClass::Transport,
                    error: "injected connection reset".into(),
                });
                continue;
            }
            if ev.rejected {
                // The rejected head consumed its FIFO position (the
                // simulator promotes the next queued request itself).
                self.chunk_meta[slot].pop_front();
                events.push(TransportEvent::Failed {
                    slot,
                    class: FailureClass::Reject,
                    error: "transient server rejection".into(),
                });
                continue;
            }
            if ev.bytes > 0.0 {
                self.recorder.add_bytes(ev.bytes as u64);
            }
            if ev.request_done {
                let digest = if self.verify {
                    self.chunk_meta[slot].pop_front().map(|(acc, off, len)| {
                        let mut d = sim_chunk_digest(&acc, off, len);
                        if ev.corrupted {
                            // Silent in-flight corruption: the payload
                            // that arrived is not the payload that was
                            // sent, so its digest differs.
                            d[0] ^= 0xFF;
                        }
                        d
                    })
                } else {
                    None
                };
                events.push(TransportEvent::Completed { slot, digest });
            } else if ev.became_ready {
                events.push(TransportEvent::Ready { slot });
            }
        }
        Ok(())
    }

    fn set_open_files(&mut self, n: usize) {
        self.sim.set_open_files(n);
    }
}

/// Everything a simulated session needs.
pub struct SimSessionParams<'a> {
    /// Transfer configuration (chunking, optimizer, mirror policy).
    pub download: DownloadConfig,
    /// Tool-level behaviour (chunked vs whole-file, keep-alive, …).
    pub behavior: ToolBehavior,
    /// Simulated network topology and fault schedule.
    pub netsim: NetSimConfig,
    /// Resolved files (with their mirror lists) to download.
    pub records: Vec<RunRecord>,
    /// Controller (already built for the tool's policy).
    pub controller: Box<dyn Controller + 'a>,
    /// XLA runtime for probe aggregation (None → pure-Rust mirror;
    /// adaptive controllers carry their own runtime handle for the
    /// decision step regardless).
    pub runtime: Option<&'a XlaRuntime>,
    /// Simulation seed: identical `(params, seed)` replay bit-identically.
    pub seed: u64,
}

/// The simulated driver: parameter plumbing around the unified engine.
pub struct SimSession<'a> {
    params: SimSessionParams<'a>,
    done_prefix: Option<Vec<u64>>,
    checkpoint_after_s: Option<f64>,
    manifest: Option<ManifestSet>,
    journal_dir: Option<std::path::PathBuf>,
    tracer: Option<Arc<Tracer>>,
}

impl<'a> SimSession<'a> {
    /// Wrap parameters into a runnable session.
    pub fn new(params: SimSessionParams<'a>) -> SimSession<'a> {
        SimSession {
            params,
            done_prefix: None,
            checkpoint_after_s: None,
            manifest: None,
            journal_dir: None,
            tracer: None,
        }
    }

    /// Resume: `prefix[i]` bytes of file `i` are already on disk (a
    /// [`crate::coordinator::resume::ProgressJournal`]'s frontiers) and
    /// are never re-requested.
    pub fn with_progress(mut self, prefix: Vec<u64>) -> SimSession<'a> {
        self.done_prefix = Some(prefix);
        self
    }

    /// Interrupt the session after `secs` of virtual transfer time —
    /// the simulated analogue of a crash/Ctrl-C, used to test
    /// checkpoint/restore across injected failures.
    pub fn with_checkpoint_after(mut self, secs: f64) -> SimSession<'a> {
        self.checkpoint_after_s = Some(secs);
        self
    }

    /// Supply an explicit integrity manifest (e.g. one persisted by an
    /// earlier checkpointed run) instead of the expected manifest the
    /// session otherwise derives from its records when
    /// `integrity.verify` is on. Chunks already marked available are
    /// seeded into the scheduler as verified spans and never
    /// re-requested.
    pub fn with_manifest(mut self, manifest: ManifestSet) -> SimSession<'a> {
        self.manifest = Some(manifest);
        self
    }

    /// Persist checkpoint state (journal + manifest) into `dir`, the
    /// way the real driver does in its output directory.
    pub fn with_journal_dir(mut self, dir: std::path::PathBuf) -> SimSession<'a> {
        self.journal_dir = Some(dir);
        self
    }

    /// Attach a flight recorder ([`crate::trace`]): the engine records
    /// lifecycle events and the simulator records fault injections,
    /// all stamped with virtual time — so a trace of the same
    /// `(params, seed)` replays byte-identically.
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> SimSession<'a> {
        self.tracer = Some(tracer);
        self
    }

    /// Run to completion (or checkpoint); returns the report.
    pub fn run(self) -> Result<SessionReport> {
        self.run_with_stats().map(|(report, _)| report)
    }

    /// [`SimSession::run`], additionally returning the engine's
    /// control-loop cost counters (the `fastbiodl bench` measurement
    /// path; see [`EngineStats`]).
    pub fn run_with_stats(self) -> Result<(SessionReport, EngineStats)> {
        let SimSession {
            params,
            done_prefix,
            checkpoint_after_s,
            manifest,
            journal_dir,
            tracer,
        } = self;
        let verify = params.download.integrity.verify;
        // With verification on and no caller-supplied manifest, derive
        // the expected per-chunk hashes from the records — the
        // simulated analogue of provider-published checksums. No chunk
        // is marked available yet; availability is earned by verified
        // completions (or carried in via [`SimSession::with_manifest`]).
        let manifest = manifest.or_else(|| {
            if !verify {
                return None;
            }
            let mut ms = ManifestSet::new();
            for r in &params.records {
                let m = ms.entry(&r.accession, r.bytes, params.download.chunk_bytes);
                for idx in 0..m.chunk_count() {
                    let offset = idx as u64 * params.download.chunk_bytes;
                    let len = m.chunk_len(idx);
                    let d = sim_chunk_digest(&r.accession, offset, len);
                    m.record_hash(idx, d);
                }
            }
            Some(ms)
        });
        let recorder = Arc::new(ThroughputRecorder::new());
        let clock = VirtualClock::new();
        let mut transport = SimTransport::new(
            params.netsim,
            params.seed,
            params.download.optimizer.c_max,
            params.download.mirror.per_mirror_conns,
            recorder.clone(),
            clock.clone(),
        )?;
        transport.set_verify(verify);
        if let Some(tr) = &tracer {
            // Fault injections are stamped with the simulator's own
            // virtual now — the same timeline the engine's clock reads.
            transport.sim.set_tracer(tr.clone());
        }
        run_session_with_stats(
            EngineParams {
                download: params.download,
                behavior: params.behavior,
                records: params.records,
                controller: params.controller,
                runtime: params.runtime,
                recorder,
                done_prefix,
                checkpoint_after_s,
                journal_dir,
                manifest,
                // Simulated fault schedules are adversarial by design;
                // recovery must outlast them rather than give up.
                give_up_after: usize::MAX,
                tracer,
            },
            &mut transport,
            &clock,
        )
    }
}

/// Convenience wrapper: run FastBioDL (adaptive GD) over a record list
/// on a scenario profile. Used by the quickstart example and the CLI.
pub fn run_simulated_download(
    cfg: &DownloadConfig,
    netsim: &NetSimConfig,
    records: Vec<RunRecord>,
    runtime: crate::runtime::SharedRuntime,
    seed: u64,
) -> Result<SessionReport> {
    let controller = crate::optimizer::build_controller_with(
        &cfg.optimizer,
        &cfg.control,
        Some(runtime.clone()),
    )?;
    let params = SimSessionParams {
        download: cfg.clone(),
        behavior: ToolBehavior::fastbiodl(cfg),
        netsim: netsim.clone(),
        records,
        controller,
        runtime: Some(&runtime),
        seed,
    };
    SimSession::new(params).run()
}
