//! The unified session engine — Algorithm 1, implemented exactly once.
//!
//! Both session drivers used to duplicate the full control loop; this
//! module owns it instead and parameterizes the two things that
//! genuinely differ between simulated and real transfers:
//!
//! * a [`Transport`] — how connections open, how a chunk's bytes move,
//!   and how failures are classified ([`FailureClass`]). The simulated
//!   implementation wraps [`crate::netsim`]; the real one drives the
//!   event-driven socket reactor in [`crate::transport::reactor`].
//! * a [`Clock`] — virtual time (advanced by the simulator's steps) vs
//!   wall time (with a real park between polls).
//!
//! Everything else — resolution charging, chunk scheduling, worker-slot
//! pool reconciliation against the [`StatusArray`], monitor sampling,
//! probe aggregation, controller stepping, retry/backoff
//! classification, checkpoint journaling, and [`SessionReport`]
//! assembly — lives here, exists exactly once, and is therefore
//! deterministically testable in simulation while running unchanged
//! over real sockets.
//!
//! ## Multi-mirror scheduling
//!
//! Records carry ordered mirror lists
//! ([`crate::accession::RunRecord::urls`]); every worker slot binds to
//! one mirror per connection. A per-session
//! [`crate::session::mirrors::MirrorBoard`] scores mirrors by EWMA
//! chunk goodput with a decaying failure penalty, and the configured
//! [`crate::config::MirrorStrategy`] decides how slots are spread:
//!
//! * **`WeightedStripe`** (default): connections are allocated across
//!   mirrors in proportion to their health scores (a deterministic
//!   highest-averages pick), bounded by the per-mirror connection cap
//!   ([`crate::config::MirrorPolicy::per_mirror_conns`]), so chunks
//!   stripe across every healthy endpoint instead of concentrating on
//!   one. Idle slots rebind only when another mirror offers a
//!   markedly better share (hysteresis), and a mirror that lost all
//!   its connections is re-probed periodically so it regains chunk
//!   share after it heals.
//! * **`Failover`** (the PR 2 baseline, kept selectable): every
//!   (re)connecting slot binds to the best-scoring mirror; idle slots
//!   abandon a mirror whose score collapses relative to the best one.
//!
//! ## The control plane
//!
//! Once per probe interval the engine assembles one
//! [`ControlSignals`] snapshot — window goodput, retry/reset/reject
//! rates over the elapsed span, the board condensed into a
//! [`MirrorHealth`] signal (headroom + fail pressure), and the fleet
//! connect-RTT — and hands it to the [`Controller`]. The returned
//! [`crate::control::ControlAction`] drives **two** knobs at once: the
//! worker-pool concurrency target (as before), and a chunk scale that,
//! with [`crate::config::ControlConfig::adaptive_chunks`] enabled,
//! shrinks newly cut chunks under fault pressure. The engine
//! additionally multiplies in a per-mirror degradation factor at issue
//! time (the issuing slot's striping weight relative to the best
//! mirror), so a probe chunk on a deeply slowed mirror stops tying a
//! slot up for many seconds. With the default config (fault penalty 0,
//! adaptive chunks off) every snapshot is consumed exactly the way the
//! old probe path was, and chunks are cut on the unscaled code path —
//! reports are byte-identical to the pre-control-plane engine.
//!
//! ## Failure handling
//!
//! A failed fetch requeues its chunk (byte accounting stays exact),
//! backs the slot off exponentially ([`BACKOFF_MIN_S`]..[`BACKOFF_MAX_S`]),
//! penalizes the mirror, and — for [`FailureClass::Transport`] — drops
//! the connection so the reconcile pass reopens one. Fatal failures
//! (malformed URLs, 4xx, local I/O) abort the session immediately.
//! When a journal directory is configured, frontiers are persisted on
//! **every fault event** in addition to the probe cadence, so a crash
//! right after a fault storm resumes from the freshest state.
//!
//! ## Slot-pool reconciliation cost
//!
//! The engine is the status array's only writer during a session, so
//! the RUNNING set is always the prefix `0..target`. Under the default
//! [`crate::config::ReconcileMode::Batched`] the per-tick
//! reconcile/rebalance/assign passes therefore walk only that live
//! prefix (plus a drain watermark covering slots still winding down
//! after a target shrink) and never read the per-slot atomics — the
//! atomics remain the *worker-facing* truth, written in batch by
//! `set_target`. [`crate::config::ReconcileMode::FullScan`] keeps the
//! naive scan of all `c_max` slots as the measured baseline;
//! `fastbiodl bench` quantifies the difference and
//! `rust/tests/engine_tick.rs` proves report-level equivalence. The
//! slot table itself is sparse: it grows on demand to the live
//! watermark instead of eagerly allocating `c_max` entries, so a
//! `c_max` in the tens of thousands costs nothing until the controller
//! actually drives the target there.

use std::path::PathBuf;
use std::sync::Arc;

use crate::accession::resolver::{mirror_width, ResolutionCost};
use crate::accession::RunRecord;
use crate::config::{DownloadConfig, MirrorStrategy, ReconcileMode};
use crate::control::{ControlSignals, Controller, MirrorHealth};
use crate::coordinator::manifest::ManifestSet;
use crate::coordinator::pool::StatusArray;
use crate::coordinator::probe::ProbeWindow;
use crate::coordinator::resume::ProgressJournal;
use crate::coordinator::scheduler::{Chunk, ChunkScheduler, SchedulerMode};
use crate::metrics::recorder::ThroughputRecorder;
use crate::metrics::timeline::per_second_bins;
use crate::runtime::XlaRuntime;
use crate::session::mirrors::MirrorBoard;
use crate::session::SessionReport;
use crate::trace::{TraceEvent, Tracer};
use crate::{Error, Result};

/// Minimum slot backoff (seconds, virtual or wall) after a failed or
/// rejected chunk: doubles per consecutive failure, resets on success.
pub const BACKOFF_MIN_S: f64 = 0.25;
/// Ceiling of the per-slot failure backoff (see [`BACKOFF_MIN_S`]).
pub const BACKOFF_MAX_S: f64 = 4.0;

/// How long the engine parks between polls when the transport had
/// nothing to report (wall-clock drivers only; virtual clocks ignore
/// it because their transport's poll advances time itself).
const IDLE_PARK_S: f64 = 0.002;

/// A freshly connected striped slot is exempt from rebalancing for
/// this long, so a re-probe connection to a currently-degraded mirror
/// survives until its probe chunk is actually issued (otherwise the
/// weights would immediately rebind it and the mirror could never be
/// re-measured).
const STRIPE_GRACE_S: f64 = 0.5;

/// Session time source. Implementations: a virtual clock advanced by
/// the simulated transport's steps, or a wall clock over
/// `std::time::Instant`.
pub trait Clock {
    /// Seconds since the clock started (monotonic).
    fn now(&self) -> f64;

    /// Yield for ~`secs` when the engine has nothing to do. Virtual
    /// clocks no-op (their transport's poll *is* the passage of time);
    /// the wall clock sleeps.
    fn park(&self, secs: f64);
}

/// Why a fetch attempt failed — drives retry accounting and backoff.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureClass {
    /// Connection-level failure (reset, short body, connect error):
    /// the slot reconnects before retrying.
    Transport,
    /// Transient server rejection (HTTP 5xx / injected window): the
    /// connection survives, the chunk retries after backoff.
    Reject,
    /// The chunk's bytes arrived but their SHA-256 does not match the
    /// manifest (bit-flip in transit, corrupted cache, mid-body swap):
    /// retryable — the connection survives and the chunk is re-fetched.
    /// Only produced when `--verify` is on.
    Corrupt,
    /// Deterministic failure (malformed URL, 4xx, local I/O): retrying
    /// cannot help; the session fails immediately.
    Fatal,
}

impl FailureClass {
    /// Stable tag used in trace records and reports.
    pub fn name(&self) -> &'static str {
        match self {
            FailureClass::Transport => "transport",
            FailureClass::Reject => "reject",
            FailureClass::Corrupt => "corrupt",
            FailureClass::Fatal => "fatal",
        }
    }
}

/// What a transport observed since the last poll, keyed by worker slot.
#[derive(Clone, Debug)]
pub enum TransportEvent {
    /// The slot's connection finished its handshake and is idle.
    Ready { slot: usize },
    /// The slot's in-flight fetch delivered every byte. `digest` is the
    /// streaming SHA-256 of the chunk's payload when the transport
    /// hashes (integrity verification on); `None` means the bytes were
    /// not hashed and the engine skips verification for this chunk.
    Completed {
        slot: usize,
        digest: Option<[u8; 32]>,
    },
    /// The slot's in-flight fetch (or connection) failed.
    Failed {
        slot: usize,
        class: FailureClass,
        error: String,
    },
}

/// Disk-path counters reported by a transport after shutdown. The
/// real transport's write-behind sink fills these; the simulator (and
/// any transport without a disk stage) returns the zeroed default.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportIoStats {
    /// Positional disk writes issued (after coalescing).
    pub write_syscalls: u64,
    /// High-water mark of bytes queued in the sink.
    pub sink_queue_peak: u64,
    /// Total nanoseconds connections spent parked on sink
    /// backpressure.
    pub reactor_stall_ns: u64,
}

/// How bytes move. One implementation over the virtual-time network
/// simulator, one over real sockets; the engine cannot tell them apart.
///
/// Slots are the engine's worker indices (`0..c_max`). A transport must
/// deliver payload bytes into the shared
/// [`ThroughputRecorder`] it was constructed with — chunk-level
/// outcomes come back through [`Transport::poll`] events.
pub trait Transport {
    /// Try to open slot `slot`'s connection to `mirror`. `Ok(false)`
    /// means a resource limit (e.g. the server's connection cap) — the
    /// engine retries on a later reconcile pass. Readiness is signalled
    /// by [`Transport::is_ready`] / [`TransportEvent::Ready`].
    fn connect(&mut self, slot: usize, mirror: usize) -> Result<bool>;

    /// Drop slot `slot`'s connection (idempotent). Parked workers drop
    /// their connection — that *is* the concurrency change at the
    /// socket level.
    fn disconnect(&mut self, slot: usize);

    /// Connection is up and idle (handshake done, no fetch in flight).
    fn is_ready(&self, slot: usize) -> bool;

    /// Start fetching `chunk` of `record` from `mirror` on slot `slot`.
    /// Completion/failure arrives via [`Transport::poll`].
    fn begin_fetch(
        &mut self,
        slot: usize,
        record: &RunRecord,
        chunk: &Chunk,
        mirror: usize,
    ) -> Result<()>;

    /// Advance the world (simulated transports step virtual time here)
    /// and/or drain pending events into `events`.
    fn poll(&mut self, events: &mut Vec<TransportEvent>) -> Result<()>;

    /// Hint: number of distinct files currently being written (drives
    /// the simulator's client-side interleaving penalty).
    fn set_open_files(&mut self, _n: usize) {}

    /// Stop background machinery (join worker threads). Called once
    /// after the control loop exits, before the report is assembled.
    fn shutdown(&mut self) {}

    /// Disk-path counters for the session (read after [`Transport::shutdown`]).
    /// Transports without a disk stage keep the zeroed default.
    fn io_stats(&self) -> TransportIoStats {
        TransportIoStats::default()
    }
}

/// Tool-level behaviour knobs (what distinguishes FastBioDL from the
/// baseline tools besides the controller).
#[derive(Clone, Debug)]
pub struct ToolBehavior {
    /// Display label.
    pub name: String,
    /// Range-chunked vs whole-file requests.
    pub mode: SchedulerMode,
    /// Reuse connections across requests (keep-alive). Baselines open
    /// a fresh connection per file.
    pub keep_alive: bool,
    /// Metadata resolution cost model.
    pub resolution: ResolutionCost,
}

impl ToolBehavior {
    /// FastBioDL: chunked, keep-alive, batch resolution (paper §4).
    /// With `cfg.campaign` set, small files coalesce into pipelined
    /// request trains ([`SchedulerMode::Campaign`]) while large files
    /// keep chunked striping.
    pub fn fastbiodl(cfg: &DownloadConfig) -> ToolBehavior {
        let mode = if cfg.campaign {
            SchedulerMode::Campaign {
                chunk_bytes: cfg.chunk_bytes,
                max_open_files: cfg.max_open_files,
                coalesce_bytes: cfg.coalesce_files_kb.saturating_mul(1024),
            }
        } else {
            SchedulerMode::Chunked {
                chunk_bytes: cfg.chunk_bytes,
                max_open_files: cfg.max_open_files,
            }
        };
        ToolBehavior {
            name: "fastbiodl".into(),
            mode,
            keep_alive: true,
            resolution: ResolutionCost::Batch { latency_s: 1.5 },
        }
    }
}

/// Everything a session needs besides its transport and clock.
pub struct EngineParams<'a> {
    /// Transfer configuration (chunking, optimizer, mirror policy).
    pub download: DownloadConfig,
    /// Tool-level behaviour knobs.
    pub behavior: ToolBehavior,
    /// Resolved files (with their mirror lists) to download.
    pub records: Vec<RunRecord>,
    /// Controller (already built for the tool's policy). Build it with
    /// the same `download.control` this struct carries
    /// ([`crate::optimizer::build_controller_with`]) so the
    /// controller's fault-pressure chunk scale and the engine's
    /// `adaptive_chunks` gate agree.
    pub controller: Box<dyn Controller + 'a>,
    /// XLA runtime for probe aggregation (None → pure-Rust mirror).
    pub runtime: Option<&'a XlaRuntime>,
    /// Shared byte counter; the transport holds a clone and feeds it
    /// from its delivery path.
    pub recorder: Arc<ThroughputRecorder>,
    /// Resume state: `done_prefix[i]` bytes of file `i` are already on
    /// disk and are never re-requested.
    pub done_prefix: Option<Vec<u64>>,
    /// Stop (checkpoint) after this much session time; the report then
    /// has `completed == false` and carries resumable frontiers.
    pub checkpoint_after_s: Option<f64>,
    /// Persist a [`ProgressJournal`] here on every fault event and
    /// probe boundary (removed again on successful completion).
    pub journal_dir: Option<PathBuf>,
    /// Chunk-integrity manifest (`Some` iff `--verify` is on). Carries
    /// any previously known hashes plus availability bits set by the
    /// delta-resume scan; chunks covered by set bits are never
    /// re-requested, completed chunks are verified against their
    /// expected hash (mismatch → [`FailureClass::Corrupt`] re-fetch)
    /// or recorded trust-on-first-use, and the live manifest is
    /// persisted next to the journal — and *kept* after completion.
    pub manifest: Option<ManifestSet>,
    /// A slot aborts the session after this many *consecutive* failed
    /// fetches. Real transfers use a small bound so persistent errors
    /// fail loudly; simulated hostile schedules use `usize::MAX`
    /// because their fault storms are adversarial by construction.
    pub give_up_after: usize,
    /// Flight recorder (`None` = tracing off, the default). When set,
    /// the engine records chunk dispatch/complete/retry/corrupt, mirror
    /// switches, and one [`TraceEvent::Probe`] per controller step —
    /// timestamped through this session's [`Clock`], so simulated
    /// traces are deterministic per seed. Tracing never alters control
    /// flow; with `None` every hook is a skipped branch and the session
    /// is bit-identical to the untraced engine.
    pub tracer: Option<Arc<Tracer>>,
}

/// Per-worker-slot engine state.
#[derive(Debug)]
struct Slot {
    /// Connection open (or opening) on the transport.
    connected: bool,
    /// Mirror this slot's connection is bound to.
    mirror: usize,
    /// When the current connection was opened (striping grace window).
    connected_at: f64,
    /// Chunk assigned but possibly not yet issued (serialized
    /// resolution / failure backoff); issued when `now >= wait_until`.
    chunk: Option<Chunk>,
    wait_until: f64,
    /// Pipelined train chunks issued behind the in-flight head on the
    /// same connection (campaign mode, `--pipeline-depth` > 1).
    /// Responses arrive FIFO: a completion promotes the front to
    /// `chunk`; a dead connection requeues the whole unanswered tail.
    /// Always empty at depth 1.
    train: std::collections::VecDeque<Chunk>,
    /// Fetch currently in flight.
    in_flight: bool,
    /// When the in-flight fetch was issued (mirror goodput samples).
    fetch_started: f64,
    /// No new fetch before this time (failure backoff).
    next_allowed: f64,
    /// Current backoff span; doubles per consecutive failure.
    backoff_s: f64,
    /// Consecutive failed fetches (reset on success).
    fails: usize,
}

impl Default for Slot {
    fn default() -> Slot {
        Slot {
            connected: false,
            mirror: 0,
            connected_at: 0.0,
            chunk: None,
            wait_until: 0.0,
            train: std::collections::VecDeque::new(),
            in_flight: false,
            fetch_started: 0.0,
            next_allowed: 0.0,
            backoff_s: BACKOFF_MIN_S,
            fails: 0,
        }
    }
}

/// Control-loop cost counters, filled by
/// [`run_session_with_stats`]. These are *measurement* outputs — none
/// of them feed back into scheduling — so the `fastbiodl bench`
/// harness can report ticks/sec, slots scanned per tick, and the
/// probe-release invariant without touching the [`SessionReport`]
/// (whose byte-for-byte parity across [`ReconcileMode`]s is a tested
/// guarantee).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Control-loop iterations executed (one per transport poll).
    pub ticks: u64,
    /// Total worker slots examined by the per-tick reconcile pass —
    /// `ticks × c_max` under [`ReconcileMode::FullScan`], the live
    /// prefix + drain watermark under [`ReconcileMode::Batched`].
    pub slots_scanned: u64,
    /// Most probe-slot releases observed in any single tick. The
    /// striping rebalancer frees **at most one** slot per tick for a
    /// due re-probe (PR 3's probe-stampede fix); `rust/tests/
    /// engine_tick.rs` pins this at 1 even with `c_max = 256`.
    pub max_probe_releases_per_tick: u32,
    /// Total probe-slot releases across the session (how often the
    /// re-probe path actually ran).
    pub probe_releases: u64,
    /// Transport events drained across the session.
    pub transport_events: u64,
    /// Chunks cut below their full size by adaptive chunk sizing
    /// (zero unless [`crate::config::ControlConfig::adaptive_chunks`]
    /// is on and fault pressure or mirror degradation was observed).
    pub chunks_scaled: u64,
    /// Positional disk writes the transport issued (after sink
    /// coalescing; zero for the simulator).
    pub write_syscalls: u64,
    /// High-water mark of bytes queued in the transport's write-behind
    /// sink (zero for the simulator and the inline write path).
    pub sink_queue_peak: u64,
    /// Total nanoseconds connections spent parked on sink
    /// backpressure.
    pub reactor_stall_ns: u64,
}

/// Persist the scheduler's frontiers if they changed since the last
/// save. Journal failures must not kill the transfer.
fn save_journal(
    dir: &Option<PathBuf>,
    records: &[RunRecord],
    sched: &ChunkScheduler,
    chunk_bytes: u64,
    last: &mut Option<ProgressJournal>,
) {
    let Some(dir) = dir else { return };
    let journal = ProgressJournal::capture(records, &sched.frontiers(), chunk_bytes);
    if last.as_ref() == Some(&journal) {
        return;
    }
    if let Err(e) = journal.save(dir) {
        log::warn!("journal save failed: {e}");
    }
    *last = Some(journal);
}

/// Persist the chunk manifest when it changed since the last save.
/// Shares the journal's cadence and, like it, must not kill the
/// transfer on I/O trouble.
fn save_manifest(dir: &Option<PathBuf>, manifest: &mut Option<ManifestSet>, dirty: &mut bool) {
    let (Some(dir), Some(ms)) = (dir, manifest) else {
        return;
    };
    if !*dirty {
        return;
    }
    if let Err(e) = ms.save(dir) {
        log::warn!("manifest save failed: {e}");
    }
    *dirty = false;
}

/// A mirror whose striping weight falls below this share of the best
/// mirror's is treated as *degraded* by adaptive chunk sizing; chunks
/// cut for its slots shrink proportionally. Comparable healthy mirrors
/// (normal goodput jitter keeps them well above the threshold) are
/// untouched, so multi-mirror benign runs cut full-size chunks.
const DEGRADED_SHARE: f64 = 0.5;

/// Per-mirror chunk-scale factor for adaptive chunk sizing: `1.0` for
/// healthy mirrors (weight share ≥ [`DEGRADED_SHARE`] of the best) and
/// proportionally smaller — floored at `scale_min` — for degraded
/// ones. `weights` is the engine's per-tick striping-weight scratch;
/// when it is empty (failover strategy, which computes no weights) the
/// factor is neutral.
fn degraded_mirror_factor(weights: &[f64], mirror: usize, scale_min: f64) -> f64 {
    let Some(&w) = weights.get(mirror) else {
        return 1.0;
    };
    let w_max = weights.iter().copied().fold(0.0f64, f64::max);
    if w_max <= 0.0 {
        return 1.0;
    }
    let share = w / w_max;
    if share >= DEGRADED_SHARE {
        1.0
    } else {
        (share / DEGRADED_SHARE).clamp(scale_min, 1.0)
    }
}

/// Run one complete session (Algorithm 1) over the given transport and
/// clock; returns the report.
pub fn run_session(
    params: EngineParams<'_>,
    transport: &mut dyn Transport,
    clock: &dyn Clock,
) -> Result<SessionReport> {
    run_session_with_stats(params, transport, clock).map(|(report, _)| report)
}

/// [`run_session`], additionally returning the control-loop cost
/// counters the benchmark harness consumes (see [`EngineStats`]).
pub fn run_session_with_stats(
    params: EngineParams<'_>,
    transport: &mut dyn Transport,
    clock: &dyn Clock,
) -> Result<(SessionReport, EngineStats)> {
    let EngineParams {
        download,
        behavior,
        records,
        mut controller,
        runtime,
        recorder,
        done_prefix,
        checkpoint_after_s,
        journal_dir,
        mut manifest,
        give_up_after,
        tracer,
    } = params;
    download.validate()?;
    if records.is_empty() {
        return Err(Error::Session("no files to download".into()));
    }

    let mut board = MirrorBoard::new(mirror_width(&records));
    let policy = download.mirror.clone();
    let mirror_count = board.mirror_count();
    // Live connections per mirror — the engine's central view of the
    // per-mirror connection caps (both transports enforce them again).
    let mut mirror_conns: Vec<usize> = vec![0; mirror_count];
    let mut sched =
        ChunkScheduler::new_with_progress(&records, behavior.mode, done_prefix.as_deref());
    // Delta resume: chunks the manifest marks verified-available are
    // already correct on disk — hand the scheduler their spans so only
    // the gaps are ever cut. Manifests whose grid does not match the
    // current transfer contribute nothing (stale hashes are replaced
    // lazily by the verification pass below).
    if let Some(ms) = &manifest {
        for (i, r) in records.iter().enumerate() {
            if let Some(m) = ms.get(&r.accession) {
                if m.total_bytes == r.bytes && m.chunk_bytes == download.chunk_bytes {
                    let spans = m.verified_spans();
                    if !spans.is_empty() {
                        sched.set_verified_spans(i, &spans);
                    }
                }
            }
        }
    }
    let mut manifest_dirty = manifest.is_some();
    let capacity = download.optimizer.c_max;
    let status = StatusArray::new(capacity);
    let mut window = ProbeWindow::new(
        runtime.map(|r| r.constants().samples).unwrap_or(256),
        0.98,
    );
    // Sparse slot table: grown on demand up to the live watermark each
    // tick (below) instead of eagerly allocating `c_max` structs — a
    // c_max of 65536 with a working target of 8 costs 8 slots, not
    // 65536. Slots past the table are by definition in their default
    // state, which is exactly what the dense version held there.
    let mut slots: Vec<Slot> = Vec::new();
    let mut events: Vec<TransportEvent> = Vec::new();

    // Metadata resolution: batch pays upfront; serialized pays per cold
    // file via `res_free` below.
    let upfront = behavior.resolution.upfront_latency(records.len());
    while clock.now() < upfront {
        events.clear();
        transport.poll(&mut events)?;
        clock.park(IDLE_PARK_S);
    }
    let mut res_free = clock.now();

    let mut target = status.set_target(controller.current().concurrency);
    // --- Slot-pool reconciliation state (see `ReconcileMode`). The
    // engine is the status array's only writer, so RUNNING is always
    // the prefix `0..target`; `drain_high` additionally covers slots
    // above a freshly lowered target that still hold a connection,
    // chunk, or in-flight fetch and must be wound down. `stripe_w` is
    // the per-tick striping weight scratch (reused so a steady-state
    // tick allocates nothing).
    let reconcile = download.reconcile;
    let mut drain_high = 0usize;
    let mut stripe_w: Vec<f64> = Vec::with_capacity(mirror_count);
    let mut stats = EngineStats::default();
    let start = clock.now();
    let mut trace = vec![(0.0, target)];
    let sample_dt = 1.0 / download.monitor_hz;
    let probe_dt = download.optimizer.probe_interval_s;
    let mut next_sample = start + sample_dt;
    let mut next_probe = start + probe_dt;
    let mut probes = 0usize;
    // --- Control-plane state: fault-event counts at the last probe
    // (for the per-window rates) and the controller's current chunk
    // scale. `adaptive_chunks` off keeps the scale pinned at 1.0, so
    // the chunk-cutting path is byte-identical to the unscaled engine.
    let adaptive_chunks = download.control.adaptive_chunks;
    let chunk_scale_min = download.control.chunk_scale_min.clamp(f64::MIN_POSITIVE, 1.0);
    // Request pipelining (campaign trains): only meaningful past depth
    // 1, and only when resolution is not serialized per cold file —
    // pipelined requests go on the wire immediately, which would bypass
    // the serialized-resolution cost model. Depth 1 (the default) makes
    // every pipelining branch below a no-op, byte-identical to the
    // unpipelined engine.
    let per_file_latency = behavior.resolution.per_file_latency();
    let pipeline_depth = if per_file_latency == 0.0 {
        download.pipeline_depth.max(1)
    } else {
        1
    };
    let mut action_chunk_scale = 1.0f64;
    let mut last_probe_s = start;
    let mut probe_mark = (0usize, 0usize, 0usize);
    // Time-weighted target integral for the paper's Concurrency column.
    let mut target_time = 0.0f64;
    let mut last_tick = start;
    // Recovery accounting (fault injection / hostile networks).
    let mut chunk_retries = 0usize;
    let mut connection_resets = 0usize;
    let mut server_rejects = 0usize;
    let mut hash_mismatches = 0usize;
    let mut mirror_switches = 0usize;
    let mut completed = true;
    let mut fatal: Option<Error> = None;
    let mut last_journal: Option<ProgressJournal> = None;
    let hard_timeout = if download.timeout_s > 0.0 {
        download.timeout_s
    } else {
        48.0 * 3600.0
    };

    while !sched.all_done() {
        let now = clock.now();
        if let Some(limit) = checkpoint_after_s {
            if now - start >= limit {
                completed = false;
                break;
            }
        }
        if now - start > hard_timeout {
            status.stop_all();
            transport.shutdown();
            return Err(Error::Session(format!(
                "transfer timed out after {:.0}s (delivered {}/{} bytes)",
                now - start,
                sched.progress().0,
                sched.progress().1
            )));
        }

        // --- Reconcile worker slots against the status array. ---
        // Batched mode walks only the live prefix + drain watermark;
        // slots beyond `live` are provably in their default state
        // (parked, disconnected, no chunk), so skipping them cannot
        // change behaviour — the FullScan reference walks everything
        // and reads the per-slot atomics, and `engine_tick.rs` holds
        // the two to identical reports.
        let live = match reconcile {
            ReconcileMode::FullScan => capacity,
            ReconcileMode::Batched => target.max(drain_high).min(capacity),
        };
        if slots.len() < live {
            slots.resize_with(live, Slot::default);
        }
        stats.ticks += 1;
        stats.slots_scanned += live as u64;
        // Striping weights are tick-constant (they depend only on board
        // scores at `now`, not on connection counts): compute them once
        // into the reused scratch so every pick below — including a
        // mass-reconnect tick after a reset storm — allocates nothing.
        match policy.strategy {
            MirrorStrategy::WeightedStripe => {
                board.weights_into(now, policy.stripe_floor, &mut stripe_w)
            }
            MirrorStrategy::Failover => stripe_w.clear(),
        }
        for (i, slot) in slots.iter_mut().enumerate().take(live) {
            let running = match reconcile {
                ReconcileMode::FullScan => status.is_running(i),
                ReconcileMode::Batched => i < target,
            };
            if running && !slot.connected {
                // Bring the worker up on the mirror the strategy picks:
                // the healthiest one (failover) or the most
                // under-allocated by score weight (striping, honoring
                // per-mirror caps and due probes).
                let pick = match policy.strategy {
                    MirrorStrategy::Failover => Some(board.pick_for_connect(now)),
                    MirrorStrategy::WeightedStripe => board.pick_for_stripe_with(
                        now,
                        &mirror_conns,
                        policy.per_mirror_conns,
                        &stripe_w,
                    ),
                };
                if let Some(mirror) = pick {
                    board.note_connect(mirror, now);
                    if transport.connect(i, mirror)? {
                        slot.connected = true;
                        slot.mirror = mirror;
                        slot.connected_at = now;
                        mirror_conns[mirror] += 1;
                    }
                }
            } else if !running && !slot.in_flight {
                // Parked and drained: release the connection, and
                // requeue any chunk that was assigned but never issued
                // — a parked worker must not strand outstanding work.
                if slot.connected {
                    transport.disconnect(i);
                    slot.connected = false;
                    mirror_conns[slot.mirror] = mirror_conns[slot.mirror].saturating_sub(1);
                }
                if let Some(chunk) = slot.chunk.take() {
                    sched.chunk_failed(chunk);
                    chunk_retries += 1;
                }
            }
        }
        // Shrink the drain watermark past slots that finished winding
        // down (they are disconnected with no chunk and no fetch).
        while drain_high > target {
            let s = &slots[drain_high - 1];
            if s.connected || s.in_flight || s.chunk.is_some() {
                break;
            }
            drain_high -= 1;
        }

        // --- Mirror rebalancing: idle slots drain off a collapsing
        // mirror (failover) or rebind toward the score-weighted
        // allocation and due re-probes (striping). `stripe_w` is the
        // per-tick weight scratch computed above the reconcile pass.
        if mirror_count > 1 {
            let mut probe_released = false;
            let mut probe_releases_this_tick = 0u32;
            for (i, slot) in slots.iter_mut().enumerate().take(live) {
                if !slot.connected || slot.in_flight || slot.chunk.is_some() {
                    continue;
                }
                let release = match policy.strategy {
                    MirrorStrategy::Failover => {
                        if board.should_failover(slot.mirror, now) {
                            Some("failover")
                        } else {
                            None
                        }
                    }
                    MirrorStrategy::WeightedStripe => {
                        if now - slot.connected_at < STRIPE_GRACE_S {
                            continue; // fresh (probe) connection
                        }
                        // Free at most one slot per tick for a due
                        // probe (never the last connection of its
                        // mirror); otherwise rebind only when another
                        // mirror offers a markedly better share.
                        let probe = !probe_released
                            && mirror_conns[slot.mirror] >= 2
                            && board.probe_due(now, &mirror_conns).is_some();
                        probe_released |= probe;
                        probe_releases_this_tick += probe as u32;
                        if probe {
                            Some("probe")
                        } else if board.should_restripe(
                            slot.mirror,
                            &mirror_conns,
                            policy.per_mirror_conns,
                            &stripe_w,
                        ) {
                            Some("restripe")
                        } else {
                            None
                        }
                    }
                };
                if let Some(reason) = release {
                    transport.disconnect(i);
                    slot.connected = false;
                    mirror_conns[slot.mirror] = mirror_conns[slot.mirror].saturating_sub(1);
                    mirror_switches += 1;
                    if let Some(tr) = tracer.as_deref() {
                        tr.record(
                            now,
                            TraceEvent::MirrorSwitch {
                                slot: i as u32,
                                mirror: slot.mirror as u32,
                                reason,
                            },
                        );
                    }
                    // The next reconcile pass reconnects via the
                    // strategy's pick.
                }
            }
            stats.max_probe_releases_per_tick =
                stats.max_probe_releases_per_tick.max(probe_releases_this_tick);
            stats.probe_releases += probe_releases_this_tick as u64;
        }

        // --- Assign work to ready workers. ---
        for (i, slot) in slots.iter_mut().enumerate().take(live) {
            let running = match reconcile {
                ReconcileMode::FullScan => status.is_running(i),
                ReconcileMode::Batched => i < target,
            };
            if !running || slot.in_flight || !slot.connected {
                continue;
            }
            if !transport.is_ready(i) {
                continue; // still in handshake
            }
            if slot.chunk.is_none() {
                // Pull the next chunk, charging serialized resolution
                // for cold files where applicable, and honoring the
                // slot's failure backoff. Under adaptive chunk sizing
                // the cut is scaled by the controller's chunk_scale ×
                // the slot's mirror degradation (its striping weight
                // relative to the best mirror, when clearly degraded),
                // so a probe chunk on a crawling mirror stays short.
                let scale = if adaptive_chunks {
                    let mirror_factor =
                        degraded_mirror_factor(&stripe_w, slot.mirror, chunk_scale_min);
                    (action_chunk_scale * mirror_factor).clamp(chunk_scale_min, 1.0)
                } else {
                    1.0
                };
                let per_file = per_file_latency;
                if let Some(chunk) = sched.next_chunk_scaled(scale) {
                    let mut wait = now.max(slot.next_allowed);
                    if chunk.cold && per_file > 0.0 {
                        let begin = res_free.max(wait);
                        res_free = begin + per_file;
                        wait = begin + per_file;
                    }
                    slot.wait_until = wait;
                    slot.chunk = Some(chunk);
                }
            }
            let issue = slot.chunk.is_some() && now >= slot.wait_until;
            if issue {
                let chunk = slot.chunk.clone().expect("chunk checked above");
                transport.begin_fetch(i, &records[chunk.file], &chunk, slot.mirror)?;
                slot.in_flight = true;
                slot.fetch_started = now;
                if let Some(tr) = tracer.as_deref() {
                    tr.record(
                        now,
                        TraceEvent::ChunkDispatch {
                            slot: i as u32,
                            mirror: slot.mirror as u32,
                            file: chunk.file as u32,
                            offset: chunk.offset,
                            len: chunk.len,
                        },
                    );
                }
            }
        }

        // --- Extend request trains (campaign pipelining). A slot whose
        // in-flight head is a train chunk may pipeline further
        // train-eligible whole-file requests behind it on the same
        // connection, up to `pipeline_depth` requests on the wire at
        // once. Each pipelined chunk is fetched immediately — the
        // transport queues it behind the in-flight response — and the
        // scheduler has already marked it outstanding.
        if pipeline_depth > 1 {
            for (i, slot) in slots.iter_mut().enumerate().take(live) {
                let running = match reconcile {
                    ReconcileMode::FullScan => status.is_running(i),
                    ReconcileMode::Batched => i < target,
                };
                if !running || !slot.in_flight || !slot.connected || now < slot.next_allowed {
                    continue;
                }
                if !slot.chunk.as_ref().map(|c| c.train).unwrap_or(false) {
                    continue; // head is not train-eligible
                }
                while slot.train.len() + 1 < pipeline_depth {
                    let Some(chunk) = sched.next_train_chunk() else {
                        break;
                    };
                    transport.begin_fetch(i, &records[chunk.file], &chunk, slot.mirror)?;
                    if let Some(tr) = tracer.as_deref() {
                        tr.record(
                            now,
                            TraceEvent::ChunkDispatch {
                                slot: i as u32,
                                mirror: slot.mirror as u32,
                                file: chunk.file as u32,
                                offset: chunk.offset,
                                len: chunk.len,
                            },
                        );
                    }
                    slot.train.push_back(chunk);
                }
            }
        }

        transport.set_open_files(sched.open_files());

        // --- Advance the world / collect chunk-level outcomes. ---
        events.clear();
        transport.poll(&mut events)?;
        let now = clock.now();
        target_time += target as f64 * (now - last_tick);
        last_tick = now;

        // --- Integrity verification (verify on): a completed chunk
        // whose digest mismatches the manifest's expected hash is
        // reclassified as a retryable `Corrupt` failure before the
        // accounting pass; a chunk without a recorded hash is adopted
        // trust-on-first-use (the hash pins every later resume).
        if let Some(ms) = manifest.as_mut() {
            for idx in 0..events.len() {
                let (i, d) = match &events[idx] {
                    TransportEvent::Completed {
                        slot,
                        digest: Some(d),
                    } => (*slot, *d),
                    _ => continue,
                };
                // Pipelined slots can land several FIFO responses in
                // one poll batch: the first verifies against the head
                // chunk, the k-th against the (k-1)-th train chunk.
                // Rewritten corrupt completions earlier in this pass
                // still consumed their queue position. At depth 1 the
                // prior count is always 0 (one chunk per slot in
                // flight) and this is exactly the head lookup.
                let prior = events[..idx]
                    .iter()
                    .filter(|e| match e {
                        TransportEvent::Completed { slot, .. } => *slot == i,
                        TransportEvent::Failed {
                            slot,
                            class: FailureClass::Corrupt,
                            ..
                        } => *slot == i,
                        _ => false,
                    })
                    .count();
                let Some(chunk) = slots.get(i).and_then(|s| {
                    if prior == 0 {
                        s.chunk.as_ref()
                    } else {
                        s.train.get(prior - 1)
                    }
                }) else {
                    continue;
                };
                let r = &records[chunk.file];
                let m = ms.entry(&r.accession, r.bytes, download.chunk_bytes);
                let idx = m.chunk_index(chunk.offset);
                match m.expected(idx) {
                    Some(expected) if *expected != d => {
                        *ev = TransportEvent::Failed {
                            slot: i,
                            class: FailureClass::Corrupt,
                            error: format!(
                                "chunk hash mismatch: {} offset {}",
                                r.accession, chunk.offset
                            ),
                        };
                    }
                    _ => {
                        m.record_hash(idx, d);
                        m.set_available(idx, true);
                        manifest_dirty = true;
                    }
                }
            }
        }

        // --- Account outcomes. ---
        stats.transport_events += events.len() as u64;
        let mut had_fault = false;
        for ev in &events {
            match ev {
                TransportEvent::Ready { slot: i } => {
                    // Handshake complete: the connect→ready span is the
                    // per-mirror RTT sample feeding latency-aware
                    // striping (transports that never signal readiness
                    // — the real driver's workers connect lazily —
                    // simply leave the board RTT-neutral).
                    let slot = &slots[*i];
                    if slot.connected {
                        board.note_rtt(slot.mirror, (now - slot.connected_at).max(0.0));
                    }
                }
                TransportEvent::Completed { slot: i, digest } => {
                    let slot = &mut slots[*i];
                    let chunk = slot
                        .chunk
                        .take()
                        .expect("fetch completed with no chunk assigned");
                    board.on_success(slot.mirror, chunk.len, now - slot.fetch_started);
                    if let Some(tr) = tracer.as_deref() {
                        tr.record(
                            now,
                            TraceEvent::ChunkComplete {
                                slot: *i as u32,
                                verified: digest.is_some() && manifest.is_some(),
                            },
                        );
                    }
                    sched.chunk_done(&chunk);
                    slot.fails = 0;
                    slot.backoff_s = BACKOFF_MIN_S;
                    if let Some(next) = slot.train.pop_front() {
                        // FIFO promotion: the next pipelined response on
                        // this connection answers the next train chunk.
                        // The request was already issued, so the slot
                        // stays in flight.
                        slot.chunk = Some(next);
                        slot.fetch_started = now;
                    } else {
                        slot.in_flight = false;
                        if !behavior.keep_alive {
                            // Baselines: fresh connection per request.
                            transport.disconnect(*i);
                            slot.connected = false;
                            mirror_conns[slot.mirror] =
                                mirror_conns[slot.mirror].saturating_sub(1);
                        }
                    }
                }
                TransportEvent::Failed {
                    slot: i,
                    class,
                    error,
                } => {
                    let slot = &mut slots[*i];
                    had_fault = true;
                    // Requeue the remaining work (bytes already
                    // delivered are counted; range requests restart
                    // cleanly at chunk grain) and back the slot off.
                    if let Some(chunk) = slot.chunk.take() {
                        sched.chunk_failed(chunk);
                        chunk_retries += 1;
                    }
                    // A dead connection takes the whole unanswered
                    // train with it; a per-request failure (reject,
                    // hash mismatch) consumed exactly one FIFO
                    // response, so the successor is promoted and the
                    // connection keeps draining. Empty train at depth
                    // 1: both branches reduce to `in_flight = false`.
                    let connection_lost =
                        matches!(class, FailureClass::Transport | FailureClass::Fatal);
                    if connection_lost {
                        while let Some(queued) = slot.train.pop_front() {
                            sched.chunk_failed(queued);
                            chunk_retries += 1;
                        }
                        slot.in_flight = false;
                    } else if let Some(next) = slot.train.pop_front() {
                        slot.chunk = Some(next);
                        slot.fetch_started = now;
                    } else {
                        slot.in_flight = false;
                    }
                    slot.next_allowed = now + slot.backoff_s;
                    slot.backoff_s = (slot.backoff_s * 2.0).min(BACKOFF_MAX_S);
                    board.on_failure(slot.mirror, now);
                    match class {
                        FailureClass::Transport => {
                            connection_resets += 1;
                            transport.disconnect(*i);
                            slot.connected = false; // reconcile reopens
                            let m = slot.mirror;
                            mirror_conns[m] = mirror_conns[m].saturating_sub(1);
                        }
                        FailureClass::Reject => {
                            server_rejects += 1;
                        }
                        FailureClass::Corrupt => {
                            // The bytes arrived but failed verification:
                            // the connection is fine, the chunk was
                            // requeued above — just count the mismatch.
                            hash_mismatches += 1;
                        }
                        FailureClass::Fatal => {
                            // First fatal wins; finish accounting the
                            // rest of this event batch (completions on
                            // other slots must still reach the
                            // scheduler before the final journal).
                            if fatal.is_none() {
                                fatal = Some(Error::Session(error.clone()));
                            }
                        }
                    }
                    slot.fails += 1;
                    if let Some(tr) = tracer.as_deref() {
                        match class {
                            FailureClass::Corrupt => {
                                tr.record(now, TraceEvent::ChunkCorrupt { slot: *i as u32 });
                            }
                            _ => {
                                tr.record(
                                    now,
                                    TraceEvent::ChunkRetry {
                                        slot: *i as u32,
                                        class: class.name(),
                                        fails: slot.fails as u32,
                                    },
                                );
                            }
                        }
                    }
                    if slot.fails >= give_up_after && fatal.is_none() {
                        fatal = Some(Error::Session(format!(
                            "worker {i} gave up after {} consecutive failures: {error}",
                            slot.fails
                        )));
                    }
                }
            }
        }
        if fatal.is_some() {
            break;
        }
        if had_fault {
            // Fault-event checkpoint cadence: a crash right after a
            // fault storm resumes from the freshest frontier.
            save_journal(
                &journal_dir,
                &records,
                &sched,
                download.chunk_bytes,
                &mut last_journal,
            );
            save_manifest(&journal_dir, &mut manifest, &mut manifest_dirty);
        }

        // --- Monitor sampling. ---
        if now >= next_sample {
            // In-flight slots are always below `live` (a fetch can only
            // be issued on a running slot, and the drain watermark holds
            // until it lands), so bounding the count scan is exact.
            let active = slots[..live].iter().filter(|s| s.in_flight).count();
            let mbps = recorder.sample(now - start, active);
            window.push(mbps);
            next_sample += sample_dt;
        }

        // --- Probing optimizer loop (Algorithm 1 body). ---
        if now >= next_probe {
            let window_stats = match runtime {
                Some(rt) => window.aggregate_and_reset(rt)?,
                None => window.aggregate_mirror_and_reset(),
            };
            probes += 1;
            // Aggregate mirror health: adaptive controllers rescale
            // their utility penalty so a second healthy mirror raises
            // the concurrency ceiling and sustained failures lower it.
            // Headroom only exists when the engine is striping AND the
            // per-mirror connection cap actually binds the pool — with
            // no cap (or a cap at least as large as the pool) a single
            // endpoint can absorb every worker, and the winner-take-all
            // baseline cannot exploit extra mirrors at all, so in
            // those modes the signal stays neutral. Single-mirror
            // sessions carry the neutral default; either way a benign
            // network leaves the controller bit-identical to a
            // health-unaware one.
            let mirror = if mirror_count > 1 {
                let cap_binds = policy.strategy == MirrorStrategy::WeightedStripe
                    && policy.per_mirror_conns > 0
                    && policy.per_mirror_conns < capacity;
                let headroom = if cap_binds {
                    board.concurrency_headroom(now)
                } else {
                    1.0
                };
                MirrorHealth {
                    headroom,
                    fail_pressure: board.fail_pressure(now),
                }
            } else {
                MirrorHealth::default()
            };
            // One typed snapshot per probe: everything the engine
            // knows that a controller could act on, in one place.
            let window_s = (now - last_probe_s).max(f64::EPSILON);
            let signals = ControlSignals {
                concurrency: target as f64,
                goodput_mbps: window_stats.mean_mbps,
                window_s,
                retry_rate: (chunk_retries - probe_mark.0) as f64 / window_s,
                reset_rate: (connection_resets - probe_mark.1) as f64 / window_s,
                reject_rate: (server_rejects - probe_mark.2) as f64 / window_s,
                mirror,
                connect_rtt_s: board.mean_rtt().unwrap_or(0.0),
            };
            probe_mark = (chunk_retries, connection_resets, server_rejects);
            last_probe_s = now;
            let action = controller.on_signals(&signals)?;
            action_chunk_scale = action.chunk_scale.clamp(chunk_scale_min, 1.0);
            let new_target = action.concurrency;
            if let Some(tr) = tracer.as_deref() {
                tr.record(
                    now,
                    TraceEvent::Probe {
                        concurrency: target as u32,
                        goodput_mbps: signals.goodput_mbps,
                        retry_rate: signals.retry_rate,
                        reset_rate: signals.reset_rate,
                        reject_rate: signals.reject_rate,
                        target: new_target as u32,
                        chunk_scale: action_chunk_scale,
                    },
                );
            }
            if new_target != target {
                let old = target;
                target = status.set_target(new_target);
                if target < old {
                    // Slots in [target, old) wind down over the next
                    // ticks; keep them under the drain watermark.
                    drain_high = drain_high.max(old);
                }
                trace.push((now - start, target));
            }
            // Baseline checkpoint cadence: once per probe interval.
            save_journal(
                &journal_dir,
                &records,
                &sched,
                download.chunk_bytes,
                &mut last_journal,
            );
            save_manifest(&journal_dir, &mut manifest, &mut manifest_dirty);
            next_probe += probe_dt;
        }

        if events.is_empty() {
            clock.park(IDLE_PARK_S);
        }
    }

    // Algorithm 1 line 9: stop workers, then tear the transport down.
    status.stop_all();
    transport.shutdown();
    let io = transport.io_stats();
    stats.write_syscalls = io.write_syscalls;
    stats.sink_queue_peak = io.sink_queue_peak;
    stats.reactor_stall_ns = io.reactor_stall_ns;

    if let Some(e) = fatal {
        // Leave the freshest journal + manifest behind for a resume.
        save_journal(
            &journal_dir,
            &records,
            &sched,
            download.chunk_bytes,
            &mut last_journal,
        );
        save_manifest(&journal_dir, &mut manifest, &mut manifest_dirty);
        if let Some(tr) = tracer.as_deref() {
            tr.record(clock.now(), TraceEvent::SessionFatal);
            tr.blackbox(&e.to_string());
        }
        return Err(e);
    }
    if completed {
        if let Some(dir) = &journal_dir {
            // Transfer complete: the journal is obsolete. The manifest
            // is *not* — it is what lets a future run delta-resume
            // over (or harvest chunks from) the finished artifacts.
            ProgressJournal::remove(dir)?;
        }
        save_manifest(&journal_dir, &mut manifest, &mut manifest_dirty);
    } else {
        save_journal(
            &journal_dir,
            &records,
            &sched,
            download.chunk_bytes,
            &mut last_journal,
        );
        save_manifest(&journal_dir, &mut manifest, &mut manifest_dirty);
    }

    stats.chunks_scaled = sched.chunks_scaled() as u64;
    let duration = (clock.now() - start).max(f64::EPSILON);
    let samples = recorder.samples();
    let timeline = per_second_bins(&samples);
    let total_bytes = recorder.total_bytes();
    let report = SessionReport {
        tool: behavior.name,
        duration_s: duration,
        total_bytes,
        mean_throughput_mbps: total_bytes as f64 * 8.0 / 1e6 / duration,
        mean_concurrency: target_time / duration,
        mean_inflight: recorder.mean_concurrency(),
        peak_mbps: timeline.peak(),
        timeline,
        samples,
        concurrency_trace: trace,
        probes,
        files_completed: sched.files_completed(),
        chunk_retries,
        connection_resets,
        server_rejects,
        hash_mismatches,
        mirror_bytes: board.bytes(),
        mirror_switches,
        completed,
        frontiers: sched.frontiers(),
    };
    Ok((report, stats))
}
