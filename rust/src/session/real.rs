//! Real-socket session driver: Algorithm 1 over actual HTTP.
//!
//! Thread layout (exactly the paper's architecture, Figure 3):
//!
//! * the **calling thread** runs the optimizer loop — it owns the
//!   controller (and through it the PJRT runtime, which is not `Send`),
//!   samples the shared throughput recorder at the monitor cadence,
//!   aggregates each probe window through the `throughput_window`
//!   artifact, and writes the new target into the shared
//!   [`StatusArray`];
//! * `c_max` **worker threads** each own one HTTP connection; between
//!   chunks they poll their status slot — parked workers drop their
//!   connection (that *is* the concurrency change), running workers
//!   pull the next chunk from the mutex-guarded scheduler and stream
//!   it, feeding byte counts into the recorder from the read callback.
//!
//! The scheduler mutex is touched once per chunk (32 MiB default), i.e.
//! a few times per second across all workers — contention-free in
//! practice; the byte hot path is atomics only.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::accession::RunRecord;
use crate::config::DownloadConfig;
use crate::coordinator::pool::StatusArray;
use crate::coordinator::probe::ProbeWindow;
use crate::coordinator::scheduler::{Chunk, ChunkScheduler, SchedulerMode};
use crate::metrics::recorder::ThroughputRecorder;
use crate::metrics::timeline::per_second_bins;
use crate::optimizer::{ConcurrencyController, Probe};
use crate::runtime::XlaRuntime;
use crate::session::SessionReport;
use crate::transport::http_client::HttpConnection;
use crate::{Error, Result};

/// Where downloaded bytes go.
#[derive(Clone, Debug)]
pub enum Sink {
    /// Count but discard (benchmarks).
    Discard,
    /// Write files under this directory (named by accession).
    Directory(String),
}

/// Parameters for a real transfer.
pub struct RealSessionParams<'a> {
    pub download: DownloadConfig,
    pub records: Vec<RunRecord>,
    pub controller: Box<dyn ConcurrencyController + 'a>,
    pub runtime: Option<&'a XlaRuntime>,
    pub sink: Sink,
    /// Tool label for the report.
    pub name: String,
}

/// A worker gives up (and fails the whole session) only after this many
/// consecutive chunk failures — isolated disconnects and transient 5xx
/// responses are retried with backoff instead.
const MAX_CONSECUTIVE_FAILURES: usize = 6;

struct WorkerShared {
    scheduler: Mutex<ChunkScheduler>,
    status: StatusArray,
    recorder: ThroughputRecorder,
    records: Vec<RunRecord>,
    in_flight: AtomicUsize,
    sink: Sink,
    /// First *persistent* worker error (the session fails loudly, not
    /// silently, once retries are exhausted).
    first_error: Mutex<Option<Error>>,
    /// Recovery accounting for the report.
    chunk_retries: AtomicUsize,
    connection_resets: AtomicUsize,
    server_rejects: AtomicUsize,
}

/// Why a chunk attempt failed — drives retry accounting.
enum ChunkFailure {
    /// Connection-level failure (reset, short body, connect error):
    /// the worker reconnects before retrying.
    Transport(Error),
    /// Server said 5xx: the connection may be reusable, but we drop it
    /// too — archives often brown out per-connection state.
    Reject(Error),
    /// Deterministic failure (malformed URL, 4xx, local I/O): retrying
    /// cannot help; fail the session immediately.
    Fatal(Error),
}

impl ChunkFailure {
    fn into_error(self) -> Error {
        match self {
            ChunkFailure::Transport(e) | ChunkFailure::Reject(e) | ChunkFailure::Fatal(e) => e,
        }
    }
}

/// Run a real-socket transfer to completion.
pub fn run_real_session(params: RealSessionParams<'_>) -> Result<SessionReport> {
    params.download.validate()?;
    if params.records.is_empty() {
        return Err(Error::Session("no files to download".into()));
    }
    // Resume: pick up a prior journal's frontiers when writing to a
    // directory; files already (partially) on disk are not re-fetched.
    let mut done_prefix: Option<Vec<u64>> = None;
    if let Sink::Directory(dir) = &params.sink {
        std::fs::create_dir_all(dir)?;
        let dirp = std::path::Path::new(dir);
        if let Some(journal) = crate::coordinator::resume::ProgressJournal::load(dirp)? {
            let frontiers = journal.frontiers_for(&params.records);
            if frontiers.iter().any(|&f| f > 0) {
                log::info!(
                    "resuming: {} bytes already on disk",
                    frontiers.iter().sum::<u64>()
                );
                done_prefix = Some(frontiers);
            }
        }
        // Pre-size the output files so workers can write ranges
        // without coordinating. Existing files keep their contents
        // (set_len only extends/truncates to the expected size).
        for r in &params.records {
            let path = dirp.join(&r.accession);
            let f = std::fs::OpenOptions::new()
                .create(true)
                .truncate(false)
                .write(true)
                .open(&path)?;
            f.set_len(r.bytes)?;
        }
    }

    let mode = SchedulerMode::Chunked {
        chunk_bytes: params.download.chunk_bytes,
        max_open_files: params.download.max_open_files,
    };
    let capacity = params.download.optimizer.c_max;
    let shared = Arc::new(WorkerShared {
        scheduler: Mutex::new(ChunkScheduler::new_with_progress(
            &params.records,
            mode,
            done_prefix.as_deref(),
        )),
        status: StatusArray::new(capacity),
        recorder: ThroughputRecorder::new(),
        records: params.records.clone(),
        in_flight: AtomicUsize::new(0),
        sink: params.sink.clone(),
        first_error: Mutex::new(None),
        chunk_retries: AtomicUsize::new(0),
        connection_resets: AtomicUsize::new(0),
        server_rejects: AtomicUsize::new(0),
    });

    // --- Spawn workers. ---
    let mut handles = Vec::with_capacity(capacity);
    for i in 0..capacity {
        let ws = shared.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("dl-worker-{i}"))
                .spawn(move || worker_loop(i, &ws))
                .map_err(|e| Error::Session(format!("spawn worker {i}: {e}")))?,
        );
    }

    // --- Optimizer loop (Algorithm 1) on this thread. ---
    let mut controller = params.controller;
    let mut window = ProbeWindow::new(
        params.runtime.map(|r| r.constants().samples).unwrap_or(256),
        0.98,
    );
    let start = Instant::now();
    let mut target = shared.status.set_target(controller.current());
    let mut trace = vec![(0.0, target)];
    let sample_dt = Duration::from_secs_f64(1.0 / params.download.monitor_hz);
    let probe_dt = Duration::from_secs_f64(params.download.optimizer.probe_interval_s);
    let mut next_sample = start + sample_dt;
    let mut next_probe = start + probe_dt;
    let mut probes = 0usize;
    let mut target_time = 0.0f64;
    let mut last_tick = start;
    let timeout = if params.download.timeout_s > 0.0 {
        Duration::from_secs_f64(params.download.timeout_s)
    } else {
        Duration::from_secs(24 * 3600)
    };

    let result: Result<()> = loop {
        if shared.scheduler.lock().unwrap().all_done() {
            break Ok(());
        }
        if let Some(err) = shared.first_error.lock().unwrap().take() {
            break Err(err);
        }
        if start.elapsed() > timeout {
            break Err(Error::Session(format!(
                "transfer timed out after {:.0?}",
                timeout
            )));
        }
        let now = Instant::now();
        target_time += target as f64 * now.duration_since(last_tick).as_secs_f64();
        last_tick = now;
        if now >= next_sample {
            let t = start.elapsed().as_secs_f64();
            let active = shared.in_flight.load(Ordering::Relaxed);
            let mbps = shared.recorder.sample(t, active);
            window.push(mbps);
            next_sample += sample_dt;
        }
        if now >= next_probe {
            let stats = match params.runtime {
                Some(rt) => window.aggregate_and_reset(rt)?,
                None => {
                    let s = window.aggregate_mirror();
                    window = ProbeWindow::new(256, 0.98);
                    s
                }
            };
            probes += 1;
            let new_target = controller.on_probe(Probe {
                concurrency: target as f64,
                mbps: stats.mean_mbps,
            })?;
            if new_target != target {
                target = shared.status.set_target(new_target);
                trace.push((start.elapsed().as_secs_f64(), target));
            }
            // Persist resume state once per probe interval.
            if let Sink::Directory(dir) = &params.sink {
                let frontiers = shared.scheduler.lock().unwrap().frontiers();
                let journal = crate::coordinator::resume::ProgressJournal::capture(
                    &params.records,
                    &frontiers,
                    params.download.chunk_bytes,
                );
                // Journal failures must not kill the transfer.
                if let Err(e) = journal.save(std::path::Path::new(dir)) {
                    log::warn!("journal save failed: {e}");
                }
            }
            next_probe += probe_dt;
        }
        std::thread::sleep(Duration::from_millis(2));
    };

    // Algorithm 1 line 9: stop workers, then join.
    shared.status.stop_all();
    for h in handles {
        let _ = h.join();
    }
    result?;
    if let Sink::Directory(dir) = &params.sink {
        // Transfer complete: the journal is obsolete.
        crate::coordinator::resume::ProgressJournal::remove(std::path::Path::new(dir))?;
    }

    let duration = start.elapsed().as_secs_f64().max(f64::EPSILON);
    let samples = shared.recorder.samples();
    let timeline = per_second_bins(&samples);
    let total_bytes = shared.recorder.total_bytes();
    let (files_completed, frontiers) = {
        let sched = shared.scheduler.lock().unwrap();
        (sched.files_completed(), sched.frontiers())
    };
    Ok(SessionReport {
        tool: params.name,
        duration_s: duration,
        total_bytes,
        mean_throughput_mbps: total_bytes as f64 * 8.0 / 1e6 / duration,
        mean_concurrency: target_time / duration,
        mean_inflight: shared.recorder.mean_concurrency(),
        peak_mbps: timeline.peak(),
        timeline,
        samples,
        concurrency_trace: trace,
        probes,
        files_completed,
        chunk_retries: shared.chunk_retries.load(Ordering::Relaxed),
        connection_resets: shared.connection_resets.load(Ordering::Relaxed),
        server_rejects: shared.server_rejects.load(Ordering::Relaxed),
        completed: true,
        frontiers,
    })
}

/// One worker thread: poll status → pull chunk → stream it. Transient
/// failures (disconnects, 5xx) requeue the chunk and retry after
/// backoff; only `MAX_CONSECUTIVE_FAILURES` in a row fail the session.
fn worker_loop(index: usize, shared: &WorkerShared) {
    let mut conn: Option<HttpConnection> = None;
    let mut consecutive_failures = 0usize;
    loop {
        if shared.status.is_stopped(index) {
            return;
        }
        if !shared.status.is_running(index) {
            // Parked: drop the connection (this is what "reducing
            // concurrency" means at the socket level) and wait.
            conn = None;
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        // Pull work.
        let chunk = {
            let mut sched = shared.scheduler.lock().unwrap();
            sched.next_chunk()
        };
        let Some(chunk) = chunk else {
            if shared.scheduler.lock().unwrap().all_done() {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
            continue;
        };

        shared.in_flight.fetch_add(1, Ordering::Relaxed);
        let outcome = stream_chunk(&mut conn, shared, &chunk);
        shared.in_flight.fetch_sub(1, Ordering::Relaxed);

        match outcome {
            Ok(()) => {
                consecutive_failures = 0;
                shared.scheduler.lock().unwrap().chunk_done(&chunk);
            }
            Err(failure) => {
                // Requeue so the outstanding accounting stays exact,
                // then reconnect and retry transient failures;
                // deterministic ones fail the session immediately.
                conn = None;
                shared.scheduler.lock().unwrap().chunk_failed(chunk);
                match &failure {
                    ChunkFailure::Transport(_) => {
                        shared.connection_resets.fetch_add(1, Ordering::Relaxed);
                        shared.chunk_retries.fetch_add(1, Ordering::Relaxed);
                    }
                    ChunkFailure::Reject(_) => {
                        shared.server_rejects.fetch_add(1, Ordering::Relaxed);
                        shared.chunk_retries.fetch_add(1, Ordering::Relaxed);
                    }
                    ChunkFailure::Fatal(_) => {
                        let mut slot = shared.first_error.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(failure.into_error());
                        }
                        return;
                    }
                }
                consecutive_failures += 1;
                if consecutive_failures >= MAX_CONSECUTIVE_FAILURES {
                    let mut slot = shared.first_error.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(failure.into_error());
                    }
                    return;
                }
                // Exponential backoff, capped well under probe cadence.
                let backoff = 20u64 << consecutive_failures.min(5);
                std::thread::sleep(Duration::from_millis(backoff.min(640)));
            }
        }
    }
}

/// Stream one chunk over the worker's (possibly new) connection.
fn stream_chunk(
    conn: &mut Option<HttpConnection>,
    shared: &WorkerShared,
    chunk: &Chunk,
) -> std::result::Result<(), ChunkFailure> {
    let record = &shared.records[chunk.file];
    // A URL that doesn't parse can never succeed: fatal, not retried.
    let (host, port, path) =
        HttpConnection::split_url(&record.url).map_err(ChunkFailure::Fatal)?;
    if conn.is_none() {
        *conn = Some(
            HttpConnection::connect(&host, port, Duration::from_secs(10))
                .map_err(ChunkFailure::Transport)?,
        );
    }
    let c = conn.as_mut().unwrap();

    // Output plumbing. Local I/O failures are deterministic: fatal.
    let mut file = match &shared.sink {
        Sink::Discard => None,
        Sink::Directory(dir) => {
            use std::io::{Seek, SeekFrom};
            let path = std::path::Path::new(dir).join(&record.accession);
            let open = || -> Result<std::fs::File> {
                let mut f = std::fs::OpenOptions::new().write(true).open(&path)?;
                f.seek(SeekFrom::Start(chunk.offset))?;
                Ok(f)
            };
            Some(open().map_err(ChunkFailure::Fatal)?)
        }
    };

    let range = if chunk.offset == 0 && chunk.len == record.bytes {
        None // whole file
    } else {
        Some((chunk.offset, chunk.len))
    };
    let mut written: u64 = 0;
    let resp = c
        .get_range(&path, range, |block| {
            shared.recorder.add_bytes(block.len() as u64);
            written += block.len() as u64;
            if let Some(f) = &mut file {
                use std::io::Write;
                // Errors surface through the length check below.
                let _ = f.write_all(block);
            }
        })
        .map_err(ChunkFailure::Transport)?;
    if resp.status >= 500 {
        // Transient server error: retryable, counted separately.
        return Err(ChunkFailure::Reject(Error::Transport(format!(
            "GET {path} range {:?}: HTTP {}",
            range, resp.status
        ))));
    }
    if !(resp.status == 200 || resp.status == 206) {
        // 4xx and friends are deterministic: retrying cannot help.
        return Err(ChunkFailure::Fatal(Error::Transport(format!(
            "GET {path} range {:?}: HTTP {}",
            range, resp.status
        ))));
    }
    if written != chunk.len {
        return Err(ChunkFailure::Transport(Error::Transport(format!(
            "GET {path}: short body {written} of {} bytes",
            chunk.len
        ))));
    }
    Ok(())
}
