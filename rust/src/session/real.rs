//! Real-socket session driver: the [`crate::session::engine`] over
//! actual HTTP.
//!
//! All control logic (Algorithm 1, retry classification, backoff,
//! checkpoint journaling, mirror failover) lives in the unified engine;
//! this module only adapts real sockets to the engine's
//! [`Transport`]/[`Clock`] traits:
//!
//! * [`RealTransport`] owns `c_max` worker threads, one per engine
//!   slot. Each worker holds one persistent HTTP connection (via
//!   [`crate::transport::fetcher::ChunkFetcher`]) and blocks on a
//!   command channel; the engine pushes fetch assignments and
//!   disconnects, and chunk-level outcomes come back on a shared event
//!   channel. The byte hot path stays atomics-only: workers feed the
//!   shared recorder directly from the read callback.
//! * [`WallClock`] is `std::time::Instant` with a real park.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::accession::resolver::ResolutionCost;
use crate::accession::RunRecord;
use crate::config::DownloadConfig;
use crate::control::Controller;
use crate::coordinator::scheduler::{Chunk, SchedulerMode};
use crate::metrics::recorder::ThroughputRecorder;
use crate::runtime::XlaRuntime;
use crate::session::engine::{
    run_session, Clock, EngineParams, ToolBehavior, Transport, TransportEvent,
};
use crate::session::SessionReport;
use crate::transport::fetcher::ChunkFetcher;
use crate::{Error, Result};

/// A worker gives up (and fails the whole session) only after this many
/// consecutive chunk failures — isolated disconnects and transient 5xx
/// responses are retried with backoff instead.
pub const MAX_CONSECUTIVE_FAILURES: usize = 6;

/// Where downloaded bytes go.
#[derive(Clone, Debug)]
pub enum Sink {
    /// Count but discard (benchmarks).
    Discard,
    /// Write files under this directory (named by accession).
    Directory(String),
}

/// Parameters for a real transfer.
pub struct RealSessionParams<'a> {
    /// Transfer configuration (chunking, optimizer, mirror policy).
    pub download: DownloadConfig,
    /// Resolved files (with their mirror URLs) to download.
    pub records: Vec<RunRecord>,
    /// Controller (already built for the tool's policy).
    pub controller: Box<dyn Controller + 'a>,
    /// XLA runtime for probe aggregation (None → pure-Rust mirror).
    pub runtime: Option<&'a XlaRuntime>,
    /// Where delivered bytes go.
    pub sink: Sink,
    /// Tool label for the report.
    pub name: String,
}

/// Wall-time session clock.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    /// Start counting from now.
    pub fn start() -> WallClock {
        WallClock {
            start: Instant::now(),
        }
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn park(&self, secs: f64) {
        if secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }
}

enum WorkerCmd {
    Fetch {
        url: String,
        out: Option<PathBuf>,
        chunk: Chunk,
        total_bytes: u64,
    },
    Disconnect,
}

/// The engine's transport over real sockets: one thread per slot.
pub struct RealTransport {
    cmd_tx: Vec<Sender<WorkerCmd>>,
    events_rx: Receiver<TransportEvent>,
    joins: Vec<std::thread::JoinHandle<()>>,
    sink: Sink,
    /// Per-mirror connection cap (0 = unlimited), enforced on the
    /// slot→mirror bindings below — the real-socket counterpart of the
    /// simulator's per-mirror flow cap. Bindings are admission
    /// control: a rebinding slot's old socket may linger for the
    /// moment it takes its worker to drain the queued disconnect, so
    /// unlike the simulator's strict flow-table cap this one is
    /// momentarily soft.
    per_mirror_conns: usize,
    /// Mirror each connected slot is bound to (`None` = disconnected).
    slot_mirror: Vec<Option<usize>>,
}

impl RealTransport {
    /// Spawn `capacity` workers sharing the byte recorder.
    /// `per_mirror_conns` caps how many workers may hold a connection
    /// to the same mirror at once (0 = unlimited).
    pub fn spawn(
        capacity: usize,
        sink: Sink,
        per_mirror_conns: usize,
        recorder: Arc<ThroughputRecorder>,
    ) -> Result<RealTransport> {
        let (events_tx, events_rx) = channel::<TransportEvent>();
        let mut cmd_tx = Vec::with_capacity(capacity);
        let mut joins = Vec::with_capacity(capacity);
        for slot in 0..capacity {
            let (tx, rx) = channel::<WorkerCmd>();
            let ev_tx = events_tx.clone();
            let rec = recorder.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("dl-worker-{slot}"))
                    .spawn(move || worker_loop(slot, rx, ev_tx, rec))
                    .map_err(|e| Error::Session(format!("spawn worker {slot}: {e}")))?,
            );
            cmd_tx.push(tx);
        }
        Ok(RealTransport {
            cmd_tx,
            events_rx,
            joins,
            sink,
            per_mirror_conns,
            slot_mirror: vec![None; capacity],
        })
    }

    /// Live slot bindings to mirror `mirror`.
    fn bound_to(&self, mirror: usize) -> usize {
        self.slot_mirror.iter().filter(|m| **m == Some(mirror)).count()
    }
}

impl Transport for RealTransport {
    fn connect(&mut self, slot: usize, mirror: usize) -> Result<bool> {
        // Real connections are opened lazily by the worker on its first
        // fetch (TCP setup happens on the worker thread, not here) —
        // the per-mirror cap is enforced up front on the bindings (see
        // `per_mirror_conns` above for the momentary-softness caveat).
        if self.per_mirror_conns > 0
            && self.slot_mirror[slot] != Some(mirror)
            && self.bound_to(mirror) >= self.per_mirror_conns
        {
            return Ok(false);
        }
        self.slot_mirror[slot] = Some(mirror);
        Ok(true)
    }

    fn disconnect(&mut self, slot: usize) {
        self.slot_mirror[slot] = None;
        // Queued behind any in-flight fetch; the worker drops its
        // connection when it processes the command.
        let _ = self.cmd_tx[slot].send(WorkerCmd::Disconnect);
    }

    fn is_ready(&self, slot: usize) -> bool {
        slot < self.cmd_tx.len()
    }

    fn begin_fetch(
        &mut self,
        slot: usize,
        record: &RunRecord,
        chunk: &Chunk,
        mirror: usize,
    ) -> Result<()> {
        let out = match &self.sink {
            Sink::Discard => None,
            Sink::Directory(dir) => Some(std::path::Path::new(dir).join(&record.accession)),
        };
        self.cmd_tx[slot]
            .send(WorkerCmd::Fetch {
                url: record.mirror_url(mirror).to_string(),
                out,
                chunk: chunk.clone(),
                total_bytes: record.bytes,
            })
            .map_err(|_| Error::Session(format!("worker {slot} is gone")))
    }

    fn poll(&mut self, events: &mut Vec<TransportEvent>) -> Result<()> {
        loop {
            match self.events_rx.try_recv() {
                Ok(ev) => events.push(ev),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        Ok(())
    }

    fn shutdown(&mut self) {
        // Closing the command channels ends every worker loop; join so
        // no worker is still streaming when the report is assembled.
        self.cmd_tx.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for RealTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One worker thread: block on assignments, stream chunks, classify
/// and report outcomes. No scheduling decisions happen here.
fn worker_loop(
    slot: usize,
    rx: Receiver<WorkerCmd>,
    events: Sender<TransportEvent>,
    recorder: Arc<ThroughputRecorder>,
) {
    let mut fetcher = ChunkFetcher::new(recorder);
    while let Ok(cmd) = rx.recv() {
        match cmd {
            WorkerCmd::Disconnect => fetcher.disconnect(),
            WorkerCmd::Fetch {
                url,
                out,
                chunk,
                total_bytes,
            } => {
                let ev = match fetcher.fetch(&url, out.as_deref(), &chunk, total_bytes) {
                    Ok(()) => TransportEvent::Completed { slot },
                    Err((class, error)) => {
                        // Drop the connection on any failure — archives
                        // often brown out per-connection state.
                        fetcher.disconnect();
                        TransportEvent::Failed { slot, class, error }
                    }
                };
                if events.send(ev).is_err() {
                    return; // session is tearing down
                }
            }
        }
    }
}

/// Run a real-socket transfer to completion.
pub fn run_real_session(params: RealSessionParams<'_>) -> Result<SessionReport> {
    let RealSessionParams {
        download,
        records,
        controller,
        runtime,
        sink,
        name,
    } = params;
    download.validate()?;
    if records.is_empty() {
        return Err(Error::Session("no files to download".into()));
    }
    // The real driver is thread-per-slot: every slot gets an OS worker
    // thread up front. The simulated engine scales to thousands of
    // slots (they are plain structs there), but eagerly reserving that
    // many thread stacks here would be a config footgun — refuse it.
    if download.optimizer.c_max > 512 {
        return Err(Error::Config(format!(
            "c_max {} too large for the real driver (max 512: one OS thread per slot)",
            download.optimizer.c_max
        )));
    }

    // Resume: pick up a prior journal's frontiers when writing to a
    // directory; files already (partially) on disk are not re-fetched.
    let mut done_prefix: Option<Vec<u64>> = None;
    let mut journal_dir: Option<PathBuf> = None;
    if let Sink::Directory(dir) = &sink {
        std::fs::create_dir_all(dir)?;
        let dirp = std::path::Path::new(dir);
        if let Some(journal) = crate::coordinator::resume::ProgressJournal::load(dirp)? {
            let frontiers = journal.frontiers_for(&records);
            if frontiers.iter().any(|&f| f > 0) {
                log::info!(
                    "resuming: {} bytes already on disk",
                    frontiers.iter().sum::<u64>()
                );
                done_prefix = Some(frontiers);
            }
        }
        // Pre-size the output files so workers can write ranges
        // without coordinating. Existing files keep their contents
        // (set_len only extends/truncates to the expected size).
        for r in &records {
            let path = dirp.join(&r.accession);
            let f = std::fs::OpenOptions::new()
                .create(true)
                .truncate(false)
                .write(true)
                .open(&path)?;
            f.set_len(r.bytes)?;
        }
        journal_dir = Some(dirp.to_path_buf());
    }

    let behavior = ToolBehavior {
        name,
        mode: SchedulerMode::Chunked {
            chunk_bytes: download.chunk_bytes,
            max_open_files: download.max_open_files,
        },
        keep_alive: true,
        // The caller's resolver has already waited in real time.
        resolution: ResolutionCost::Batch { latency_s: 0.0 },
    };
    let recorder = Arc::new(ThroughputRecorder::new());
    let mut transport = RealTransport::spawn(
        download.optimizer.c_max,
        sink,
        download.mirror.per_mirror_conns,
        recorder.clone(),
    )?;
    let clock = WallClock::start();
    run_session(
        EngineParams {
            download,
            behavior,
            records,
            controller,
            runtime,
            recorder,
            done_prefix,
            checkpoint_after_s: None,
            journal_dir,
            give_up_after: MAX_CONSECUTIVE_FAILURES,
        },
        &mut transport,
        &clock,
    )
}
