//! Real-socket session driver: the [`crate::session::engine`] over
//! actual HTTP.
//!
//! All control logic (Algorithm 1, retry classification, backoff,
//! checkpoint journaling, mirror failover) lives in the unified engine;
//! this module only adapts real sockets to the engine's
//! [`Transport`]/[`Clock`] traits:
//!
//! * [`RealTransport`] is a thin adapter over the event-driven
//!   [`Reactor`](crate::transport::reactor::Reactor): a small fixed
//!   pool of reactor threads drives *all* slot sockets through
//!   non-blocking connect/read state machines, so `c_max` is bounded by
//!   file descriptors, not OS thread stacks — thousands of concurrent
//!   streams are real here, same as on the simulated path. Disk I/O is
//!   decoupled from the poll loop: output files are opened and
//!   pre-sized **once** here, and reactor threads hand payload bytes to
//!   the write-behind sink ([`crate::transport::sink`]), which lands
//!   them with coalesced positional writes and acks completion.
//! * The per-mirror connection cap is enforced strictly at socket
//!   level via the reactor's reservation gauges — open sockets to one
//!   mirror never exceed `per_mirror_conns` (the old thread-per-slot
//!   binding check was momentarily soft during rebinds).
//! * [`WallClock`] is `std::time::Instant` with a real park.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::accession::resolver::{mirror_width, ResolutionCost};
use crate::accession::RunRecord;
use crate::config::DownloadConfig;
use crate::control::Controller;
use crate::coordinator::manifest::{delta_scan, ManifestSet};
use crate::coordinator::scheduler::{Chunk, SchedulerMode};
use crate::metrics::recorder::ThroughputRecorder;
use crate::runtime::XlaRuntime;
use crate::session::engine::{
    run_session_with_stats, Clock, EngineParams, EngineStats, FailureClass, ToolBehavior,
    Transport, TransportEvent, TransportIoStats,
};
use crate::session::SessionReport;
use crate::trace::{Tracer, WallTracer};
use crate::transport::http_client::HttpConnection;
use crate::transport::reactor::{
    FetchSpec, KillSwitch, ProgressPolicy, Reactor, IDLE_REAP_DEFAULT_S,
};
use crate::transport::sink::{SinkConfig, SinkFile};
use crate::{Error, Result};

/// A slot gives up (and fails the whole session) only after this many
/// consecutive chunk failures — isolated disconnects and transient 5xx
/// responses are retried with backoff instead.
pub const MAX_CONSECUTIVE_FAILURES: usize = 6;

/// Where downloaded bytes go.
#[derive(Clone, Debug)]
pub enum Sink {
    /// Count but discard (benchmarks).
    Discard,
    /// Write files under this directory (named by accession).
    Directory(String),
}

/// Parameters for a real transfer.
pub struct RealSessionParams<'a> {
    /// Transfer configuration (chunking, optimizer, mirror policy).
    pub download: DownloadConfig,
    /// Resolved files (with their mirror URLs) to download.
    pub records: Vec<RunRecord>,
    /// Controller (already built for the tool's policy).
    pub controller: Box<dyn Controller + 'a>,
    /// XLA runtime for probe aggregation (None → pure-Rust mirror).
    pub runtime: Option<&'a XlaRuntime>,
    /// Where delivered bytes go.
    pub sink: Sink,
    /// Tool label for the report.
    pub name: String,
    /// Flight recorder (`None` = tracing off). The engine stamps its
    /// events through [`WallClock`]; reactor and sink threads stamp
    /// theirs through a [`WallTracer`] handle sharing this recorder.
    pub tracer: Option<Arc<Tracer>>,
}

/// Wall-time session clock.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    /// Start counting from now.
    pub fn start() -> WallClock {
        WallClock {
            start: Instant::now(),
        }
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn park(&self, secs: f64) {
        if secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }
}

/// The engine's transport over real sockets: a thin adapter binding
/// engine slots to the shared event-driven [`Reactor`].
pub struct RealTransport {
    reactor: Reactor,
    sink: Sink,
    /// Per-mirror connection cap (0 = unlimited), enforced on the
    /// reactor's reservation gauges: the engine thread is the only
    /// incrementer and sockets exist only under a reservation, so open
    /// connections to a mirror never exceed this — strictly.
    per_mirror_conns: usize,
    /// Mirror each connected slot is bound to (`None` = disconnected).
    slot_mirror: Vec<Option<usize>>,
    /// Events raised on the engine thread itself (e.g. a malformed
    /// URL), delivered ahead of reactor events on the next poll.
    pending: Vec<TransportEvent>,
    /// Preopened per-file output handles, indexed by record position
    /// (empty in discard mode). Opened once by [`run_real_session`];
    /// every chunk of file `i` writes positionally through
    /// `files[i]`.
    files: Vec<SinkFile>,
}

impl RealTransport {
    /// Spawn the reactor pool serving `capacity` slots across
    /// `mirror_count` mirrors. `per_mirror_conns` caps how many slots
    /// may hold a connection to the same mirror at once (0 =
    /// unlimited); `progress` is the whole-chunk progress deadline.
    /// `trace` (when tracing) lets reactor and sink threads record
    /// connection-state and write-batch events.
    /// `pipeline_depth` caps HTTP/1.1 requests on the wire per
    /// connection (1 = no pipelining, the pre-campaign behavior).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        capacity: usize,
        sink: Sink,
        per_mirror_conns: usize,
        mirror_count: usize,
        recorder: Arc<ThroughputRecorder>,
        progress: ProgressPolicy,
        sink_cfg: SinkConfig,
        pipeline_depth: usize,
        trace: Option<WallTracer>,
    ) -> Result<RealTransport> {
        let reactor = Reactor::spawn(
            capacity,
            mirror_count,
            recorder,
            progress,
            sink_cfg,
            pipeline_depth,
            IDLE_REAP_DEFAULT_S,
            trace,
        )?;
        Ok(RealTransport {
            reactor,
            sink,
            per_mirror_conns,
            slot_mirror: vec![None; capacity],
            pending: Vec::new(),
            files: Vec::new(),
        })
    }

    /// Install the preopened output handles (one per record, in record
    /// order). Directory mode only; discard mode leaves this empty.
    pub fn set_output_handles(&mut self, files: Vec<SinkFile>) {
        self.files = files;
    }

    /// Handle that can simulate the whole reactor dying mid-session
    /// (regression tests for the dead-worker hang).
    pub fn kill_switch(&self) -> KillSwitch {
        self.reactor.kill_switch()
    }
}

impl Transport for RealTransport {
    fn connect(&mut self, slot: usize, mirror: usize) -> Result<bool> {
        if self.slot_mirror[slot] == Some(mirror) {
            return Ok(true);
        }
        if self.per_mirror_conns > 0 && self.reactor.mirror_open(mirror) >= self.per_mirror_conns {
            return Ok(false);
        }
        if let Some(old) = self.slot_mirror[slot].take() {
            self.reactor.release(slot, old);
        }
        self.reactor.reserve(mirror);
        self.slot_mirror[slot] = Some(mirror);
        Ok(true)
    }

    fn disconnect(&mut self, slot: usize) {
        if let Some(mirror) = self.slot_mirror[slot].take() {
            self.reactor.release(slot, mirror);
        }
    }

    fn is_ready(&self, slot: usize) -> bool {
        slot < self.slot_mirror.len()
    }

    fn begin_fetch(
        &mut self,
        slot: usize,
        record: &RunRecord,
        chunk: &Chunk,
        mirror: usize,
    ) -> Result<()> {
        let (host, port, path) = match HttpConnection::split_url(record.mirror_url(mirror)) {
            Ok(parts) => parts,
            Err(e) => {
                // A malformed URL can never succeed: surface it through
                // the event stream as a deterministic failure.
                self.pending.push(TransportEvent::Failed {
                    slot,
                    class: FailureClass::Fatal,
                    error: e.to_string(),
                });
                return Ok(());
            }
        };
        let out = match &self.sink {
            Sink::Discard => None,
            Sink::Directory(_) => match self.files.get(chunk.file).cloned() {
                Some(handle) => Some(handle),
                None => {
                    // Handles are preopened by the driver; a missing one
                    // is a deterministic local failure.
                    self.pending.push(TransportEvent::Failed {
                        slot,
                        class: FailureClass::Fatal,
                        error: format!("no preopened output handle for file {}", chunk.file),
                    });
                    return Ok(());
                }
            },
        };
        self.reactor.fetch(FetchSpec {
            slot,
            host,
            port,
            path,
            out,
            chunk: chunk.clone(),
            total_bytes: record.bytes,
            mirror,
        })
    }

    fn poll(&mut self, events: &mut Vec<TransportEvent>) -> Result<()> {
        events.append(&mut self.pending);
        self.reactor.drain_events(events)
    }

    fn shutdown(&mut self) {
        self.reactor.shutdown();
    }

    fn io_stats(&self) -> TransportIoStats {
        self.reactor.io_stats()
    }
}

/// Run a real-socket transfer to completion.
pub fn run_real_session(params: RealSessionParams<'_>) -> Result<SessionReport> {
    run_real_session_with_stats(params).map(|(report, _)| report)
}

/// [`run_real_session`], additionally returning the engine's
/// control-loop cost counters (the `--report-json` measurement path;
/// see [`EngineStats`]).
pub fn run_real_session_with_stats(
    params: RealSessionParams<'_>,
) -> Result<(SessionReport, EngineStats)> {
    let RealSessionParams {
        download,
        records,
        controller,
        runtime,
        sink,
        name,
        tracer,
    } = params;
    download.validate()?;
    if records.is_empty() {
        return Err(Error::Session("no files to download".into()));
    }

    // Resume: pick up a prior journal's frontiers when writing to a
    // directory; files already (partially) on disk are not re-fetched.
    // The disk is the source of truth: a frontier is only honored up to
    // the bytes actually present, and a file whose on-disk size exceeds
    // the record restarts from scratch.
    let mut done_prefix: Option<Vec<u64>> = None;
    let mut journal_dir: Option<PathBuf> = None;
    let mut manifest: Option<ManifestSet> = None;
    let mut handles: Vec<SinkFile> = Vec::new();
    if let Sink::Directory(dir) = &sink {
        std::fs::create_dir_all(dir)?;
        let dirp = std::path::Path::new(dir);
        if let Some(journal) = crate::coordinator::resume::ProgressJournal::load(dirp)? {
            let mut frontiers = journal.frontiers_for(&records);
            for (f, r) in frontiers.iter_mut().zip(records.iter()) {
                let path = dirp.join(&r.accession);
                let disk_len = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                if disk_len > r.bytes {
                    log::warn!(
                        "{}: on-disk file is {disk_len} bytes but the record says {} — \
                         restarting this file",
                        r.accession,
                        r.bytes
                    );
                    *f = 0;
                } else if *f > disk_len {
                    log::warn!(
                        "{}: journal frontier {f} exceeds the {disk_len}-byte file on disk — \
                         clamping to what is actually there",
                        r.accession
                    );
                    *f = disk_len;
                }
            }
            if frontiers.iter().any(|&f| f > 0) {
                log::info!(
                    "resuming: {} bytes already on disk",
                    frontiers.iter().sum::<u64>()
                );
                done_prefix = Some(frontiers);
            }
        }
        // Integrity: load (or start) the chunk manifest when verifying.
        // With `reuse_local` the partial files on disk are rehashed
        // against the manifest's expected digests up front (one
        // sequential cold-start read) and only unverified chunks are
        // ever scheduled — the journal's blind byte frontier is
        // superseded by that chunk-level evidence. Without it, nothing
        // on disk is trusted as verified: the manifest keeps its
        // expected hashes for in-flight checks but drops availability.
        if download.integrity.verify {
            let mut ms = ManifestSet::load(dirp)?.unwrap_or_default();
            if download.integrity.reuse_local {
                let mut reused = 0usize;
                for r in &records {
                    let m = ms.entry(&r.accession, r.bytes, download.chunk_bytes);
                    reused += delta_scan(&dirp.join(&r.accession), m)?;
                }
                if reused > 0 {
                    log::info!("delta resume: {reused} chunks verified on disk, reusing them");
                }
                done_prefix = None;
            } else {
                for r in &records {
                    let m = ms.entry(&r.accession, r.bytes, download.chunk_bytes);
                    for i in 0..m.chunk_count() {
                        m.set_available(i, false);
                    }
                }
            }
            manifest = Some(ms);
        }
        // Open + pre-size every output file once, up front. The shared
        // handles let sink writers (or reactor threads in inline mode)
        // land ranges with positional writes — no per-chunk
        // open/seek/close, no coordination. Existing files keep their
        // contents (set_len only extends/truncates to the expected
        // size).
        for r in &records {
            let path = dirp.join(&r.accession);
            let f = std::fs::OpenOptions::new()
                .create(true)
                .truncate(false)
                .write(true)
                .open(&path)?;
            f.set_len(r.bytes)?;
            handles.push(SinkFile {
                file: Arc::new(f),
                path: Arc::new(path),
            });
        }
        journal_dir = Some(dirp.to_path_buf());
    }

    let behavior = ToolBehavior {
        name,
        mode: if download.campaign {
            SchedulerMode::Campaign {
                chunk_bytes: download.chunk_bytes,
                max_open_files: download.max_open_files,
                coalesce_bytes: download.coalesce_files_kb.saturating_mul(1024),
            }
        } else {
            SchedulerMode::Chunked {
                chunk_bytes: download.chunk_bytes,
                max_open_files: download.max_open_files,
            }
        },
        keep_alive: true,
        // The caller's resolver has already waited in real time.
        resolution: ResolutionCost::Batch { latency_s: 0.0 },
    };
    let recorder = Arc::new(ThroughputRecorder::new());
    let progress = ProgressPolicy {
        window_s: download.progress_window_s,
        min_bytes: download.progress_min_bytes,
    };
    // The wall tracer's origin and the wall clock's start are created
    // back to back, so reactor/sink timestamps share the engine's
    // timeline to within spawn latency.
    let wall_trace = tracer.as_ref().map(|t| WallTracer::new(t.clone()));
    let mut transport = RealTransport::spawn(
        download.optimizer.c_max,
        sink,
        download.mirror.per_mirror_conns,
        mirror_width(&records),
        recorder.clone(),
        progress,
        SinkConfig::from_download(&download),
        download.pipeline_depth,
        wall_trace,
    )?;
    transport.set_output_handles(handles);
    let clock = WallClock::start();
    run_session_with_stats(
        EngineParams {
            download,
            behavior,
            records,
            controller,
            runtime,
            recorder,
            done_prefix,
            checkpoint_after_s: None,
            journal_dir,
            manifest,
            give_up_after: MAX_CONSECUTIVE_FAILURES,
            tracer,
        },
        &mut transport,
        &clock,
    )
}
