//! Pure-Rust mirrors of the controller artifact math.
//!
//! These re-implement, in plain f64 Rust, exactly what the L2 graphs
//! (and their L1 Pallas kernels) compute. They exist for two reasons:
//!
//! 1. **Cross-language consistency tests** — the integration suite runs
//!    the same inputs through the XLA artifact and through these
//!    mirrors and asserts agreement to f32 tolerance, pinning the
//!    Python → HLO → PJRT pipeline end to end.
//! 2. **Fast property tests** — invariants like "utility is unimodal in
//!    C with maximum at `C* = 1/ln k`" (paper §4.1) are checked over
//!    thousands of random parameter draws without paying XLA dispatch.
//!
//! Nothing on the request path calls these; the runtime executes the
//! artifacts — with one exception: [`fault_discount`] *is* the
//! production formula. The control plane's fault-penalty term
//! ([`crate::control::discounted_goodput`]) delegates here, so the
//! [`utility`] cross-checks below include the penalty term: the
//! fault-aware utility is exactly
//! `utility(fault_discount(T, rate, weight), C, k)`, and the
//! weight-0 identity (bit-for-bit) is what keeps benign and
//! paper-figure runs unchanged.

/// Utility `U = T / k^C` (paper §4.1).
pub fn utility(throughput: f64, concurrency: f64, k: f64) -> f64 {
    throughput / k.powf(concurrency)
}

/// Fault-penalized throughput feeding [`utility`]:
/// `T_eff = T / (1 + weight × rate)`, where `rate` is the weighted
/// retry/reject rate ([`crate::control::weighted_fault_rate`]) and
/// `weight` is [`crate::config::ControlConfig::fault_penalty`].
///
/// With `weight <= 0` **or** a clean window (`rate <= 0`) the input is
/// returned unchanged — same bits, not just same value — so the
/// fault-blind default cannot perturb a single f64 operation.
pub fn fault_discount(throughput: f64, rate: f64, weight: f64) -> f64 {
    if weight <= 0.0 || rate <= 0.0 {
        return throughput;
    }
    throughput / (1.0 + weight * rate)
}

/// The §4.1 closed form: `C* = 1 / ln k`, the unique maximizer of
/// `U(C) = αC / k^C` on C > 0.
pub fn c_star(k: f64) -> f64 {
    1.0 / k.ln()
}

/// Mirror of the `gd_step` artifact. Inputs exactly as exported by
/// `ProbeHistory::export`; returns `(next_c, grad, step, u_mean)`.
#[allow(clippy::too_many_arguments)]
pub fn gd_step_mirror(
    c_hist: &[f64],
    t_hist: &[f64],
    w: &[f64],
    k: f64,
    lr: f64,
    step_clip: f64,
    c_min: f64,
    c_max: f64,
    c_now: f64,
) -> (f64, f64, f64, f64) {
    const EPS: f64 = 1e-6;
    assert_eq!(c_hist.len(), t_hist.len());
    assert_eq!(c_hist.len(), w.len());
    let u: Vec<f64> = c_hist
        .iter()
        .zip(t_hist)
        .map(|(&c, &t)| utility(t, c, k))
        .collect();
    let s_w: f64 = w.iter().sum();
    let s_c: f64 = w.iter().zip(c_hist).map(|(w, c)| w * c).sum();
    let s_u: f64 = w.iter().zip(&u).map(|(w, u)| w * u).sum();
    let s_cc: f64 = w.iter().zip(c_hist).map(|(w, c)| w * c * c).sum();
    let s_cu: f64 = w
        .iter()
        .zip(c_hist)
        .zip(&u)
        .map(|((w, c), u)| w * c * u)
        .sum();
    let var_c = s_w * s_cc - s_c * s_c;
    let cov_cu = s_w * s_cu - s_c * s_u;
    let grad = cov_cu / (var_c + EPS);
    let u_mean = s_u / s_w.max(EPS);
    let u_scale = u_mean.abs() + EPS;
    let raw = if var_c <= EPS { u_scale } else { lr * grad };
    let step = (raw / u_scale).clamp(-step_clip, step_clip);
    let next_c = (c_now + step).clamp(c_min, c_max);
    (next_c, grad, step, u_mean)
}

/// Mirror of the GP posterior inside `bayes_step`: RBF kernel,
/// huge-noise masking of invalid rows, Cholesky solve. Returns
/// `(mu, std)` on the grid.
pub fn gp_posterior_mirror(
    c_obs: &[f64],
    u_obs: &[f64],
    valid: &[f64],
    grid: &[f64],
    lengthscale: f64,
    noise: f64,
) -> (Vec<f64>, Vec<f64>) {
    let n = c_obs.len();
    let g = grid.len();
    let rbf = |a: f64, b: f64| (-(a - b) * (a - b) / (2.0 * lengthscale * lengthscale)).exp();

    // K_oo + diag(noise + (1-valid)*1e6)
    let mut k_oo = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            k_oo[i * n + j] = rbf(c_obs[i], c_obs[j]);
        }
        k_oo[i * n + i] += noise + (1.0 - valid[i]) * 1.0e6;
    }
    let u_masked: Vec<f64> = u_obs.iter().zip(valid).map(|(u, v)| u * v).collect();

    // Cholesky.
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = k_oo[i * n + j];
            for p in 0..j {
                s -= l[i * n + p] * l[j * n + p];
            }
            if i == j {
                l[i * n + i] = s.max(1e-12).sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    let solve_lower = |b: &[f64]| -> Vec<f64> {
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for p in 0..i {
                s -= l[i * n + p] * y[p];
            }
            y[i] = s / l[i * n + i];
        }
        y
    };
    let solve_upper_t = |y: &[f64]| -> Vec<f64> {
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for p in i + 1..n {
                s -= l[p * n + i] * x[p];
            }
            x[i] = s / l[i * n + i];
        }
        x
    };
    let alpha = solve_upper_t(&solve_lower(&u_masked));

    let mut mu = vec![0.0; g];
    let mut std = vec![0.0; g];
    for (j, &gx) in grid.iter().enumerate() {
        let k_star: Vec<f64> = c_obs.iter().map(|&c| rbf(c, gx)).collect();
        mu[j] = k_star.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        let v = solve_lower(&k_star);
        let var: f64 = 1.0 - v.iter().map(|x| x * x).sum::<f64>();
        std[j] = var.max(0.0).sqrt();
    }
    (mu, std)
}

/// Expected improvement with the same erf approximation as the artifact.
pub fn expected_improvement_mirror(mu: f64, std: f64, best: f64, xi: f64) -> f64 {
    let improve = mu - best - xi;
    if std <= 1e-9 {
        return improve.max(0.0);
    }
    let z = improve / std;
    let pdf = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
    let cdf = 0.5 * (1.0 + erf_approx(z / std::f64::consts::SQRT_2));
    improve * cdf + std * pdf
}

/// Abramowitz–Stegun 7.1.26 (same polynomial as `compile.model._erf`).
pub fn erf_approx(x: f64) -> f64 {
    let (a1, a2, a3, a4, a5) = (
        0.254829592,
        -0.284496736,
        1.421413741,
        -1.453152027,
        1.061405429,
    );
    let p = 0.3275911;
    let sign = x.signum();
    let ax = x.abs();
    let t = 1.0 / (1.0 + p * ax);
    let poly = ((((a5 * t + a4) * t + a3) * t + a2) * t + a1) * t;
    sign * (1.0 - poly * (-ax * ax).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c_star_is_the_maximizer() {
        // U(C) = αC/k^C has its max at C* = 1/ln k (paper §4.1).
        for k in [1.01, 1.02, 1.05, 1.1] {
            let cs = c_star(k);
            let u = |c: f64| c * utility(100.0, c, k); // α=100 per-thread
            assert!(u(cs) > u(cs - 0.5), "k={k}");
            assert!(u(cs) > u(cs + 0.5), "k={k}");
        }
    }

    #[test]
    fn fault_discount_is_identity_at_zero_weight_and_monotone() {
        // Bit-level identity: the default weight must not touch the
        // value at all.
        for t in [0.0, 1.5, 812.25, f64::MAX] {
            assert_eq!(fault_discount(t, 10.0, 0.0).to_bits(), t.to_bits());
            assert_eq!(fault_discount(t, 0.0, 10.0).to_bits(), t.to_bits());
        }
        // Monotone decreasing in both rate and weight.
        let base = fault_discount(1000.0, 1.0, 1.0);
        assert!(base < 1000.0);
        assert!(fault_discount(1000.0, 2.0, 1.0) < base);
        assert!(fault_discount(1000.0, 1.0, 2.0) < base);
        // The fault-aware utility composes: U_eff = U(T_eff, C, k).
        let u_blind = utility(1000.0, 8.0, 1.02);
        let u_aware = utility(fault_discount(1000.0, 4.0, 1.0), 8.0, 1.02);
        assert!((u_aware - u_blind / 5.0).abs() < 1e-9);
    }

    #[test]
    fn gd_mirror_rises_then_clips() {
        // Linear utility rise: gradient positive, step clipped.
        let c = [1.0, 2.0, 3.0, 4.0];
        let t = [100.0, 200.0, 300.0, 400.0];
        let w = [0.5, 0.7, 0.85, 1.0];
        let (next, grad, step, _) =
            gd_step_mirror(&c, &t, &w, 1.02, 100.0, 2.0, 1.0, 64.0, 4.0);
        assert!(grad > 0.0);
        assert_eq!(step, 2.0, "big lr must clip to step_clip");
        assert!((next - 6.0).abs() < 1e-9);
    }

    #[test]
    fn gd_mirror_degenerate_window_explores_up() {
        let c = [3.0, 3.0, 3.0];
        let t = [300.0, 310.0, 305.0];
        let w = [1.0, 1.0, 1.0];
        let (next, _, step, _) = gd_step_mirror(&c, &t, &w, 1.02, 3.0, 4.0, 1.0, 64.0, 3.0);
        assert!((step - 1.0).abs() < 1e-9, "explore step should be +1");
        assert!((next - 4.0).abs() < 1e-9);
    }

    #[test]
    fn gd_mirror_descends_past_optimum() {
        // Utility falls with C: controller must step down.
        let k: f64 = 1.2; // strong penalty => low C*
        let c = [4.0, 5.0, 6.0];
        let t = [400.0, 410.0, 415.0]; // sub-linear gains
        let w = [1.0, 1.0, 1.0];
        let (next, grad, _, _) = gd_step_mirror(&c, &t, &w, k, 3.0, 4.0, 1.0, 64.0, 6.0);
        assert!(grad < 0.0);
        assert!(next < 6.0);
    }

    #[test]
    fn gp_posterior_interpolates_observations() {
        let c = [2.0, 4.0, 8.0];
        let u = [0.5, 0.9, 0.4];
        let valid = [1.0, 1.0, 1.0];
        let grid = [2.0, 4.0, 8.0];
        let (mu, std) = gp_posterior_mirror(&c, &u, &valid, &grid, 1.5, 1e-4);
        for i in 0..3 {
            assert!((mu[i] - u[i]).abs() < 0.02, "mu[{i}]={} u={}", mu[i], u[i]);
            assert!(std[i] < 0.05, "posterior should be tight at data");
        }
    }

    #[test]
    fn gp_posterior_uncertain_far_from_data() {
        let c = [2.0, 3.0];
        let u = [0.5, 0.6];
        let valid = [1.0, 1.0];
        let grid = [2.5, 30.0];
        let (_, std) = gp_posterior_mirror(&c, &u, &valid, &grid, 2.0, 1e-4);
        assert!(std[0] < 0.3);
        assert!(std[1] > 0.9, "far point should be prior-dominated");
    }

    #[test]
    fn invalid_observations_ignored() {
        let c = [2.0, 999.0];
        let u = [0.5, -77.0];
        let valid = [1.0, 0.0];
        let grid = [2.0];
        let (mu, _) = gp_posterior_mirror(&c, &u, &valid, &grid, 2.0, 1e-4);
        assert!((mu[0] - 0.5).abs() < 0.02, "masked row must not leak");
    }

    #[test]
    fn erf_approx_accuracy() {
        // Known values: erf(0)=0, erf(1)≈0.8427, erf(-1)≈-0.8427.
        assert!(erf_approx(0.0).abs() < 1e-7);
        assert!((erf_approx(1.0) - 0.8427008).abs() < 2e-7);
        assert!((erf_approx(-1.0) + 0.8427008).abs() < 2e-7);
        assert!((erf_approx(3.0) - 0.9999779).abs() < 1e-6);
    }

    #[test]
    fn ei_positive_where_improvement_possible() {
        let ei_hi = expected_improvement_mirror(1.0, 0.2, 0.5, 0.01);
        let ei_lo = expected_improvement_mirror(0.1, 0.2, 0.5, 0.01);
        assert!(ei_hi > ei_lo);
        assert!(ei_lo >= 0.0);
        // Zero std, no improvement -> 0.
        assert_eq!(expected_improvement_mirror(0.4, 0.0, 0.5, 0.01), 0.0);
    }
}
