//! Bayesian-optimization controller (the paper's in-system baseline,
//! Figure 4).
//!
//! Every probe the controller refits a GP surrogate over its
//! observation memory and jumps to the expected-improvement argmax on
//! the candidate grid — all inside the `bayes_step` XLA artifact (L1
//! Pallas RBF kernel matrices, unrolled Cholesky solve at L2).
//!
//! Observation memory is *bucketed*: the WINDOW (16) artifact slots
//! are assigned to equal-width concurrency regions, each holding the
//! most recent observation in its region. This is the standard
//! fixed-memory BO design — a plain ring would forget explored regions
//! and re-explore them forever. Even so, the paper's finding reproduces
//! mechanically: the random seeding phase and EI's exploration term
//! send the controller on large concurrency jumps; every jump costs
//! socket churn (connection setup, ramp restart) and lands a noisy
//! sample that skews the surrogate under drifting background traffic.
//! Total transfer time ends ≈20–40 % behind gradient descent
//! (Figure 4 / `fig4_gd_vs_bayes` bench).

use crate::config::{ControlConfig, OptimizerConfig};
use crate::control::{chunk_scale, discounted_goodput, ControlAction, ControlSignals, Controller};
use crate::optimizer::{effective_k, Probe};
use crate::runtime::SharedRuntime;
use crate::util::prng::Prng;
use crate::Result;

/// Bayesian controller driving the `bayes_step` artifact — or, without
/// a runtime ([`BayesController::new_mirror`]), the pure-Rust GP/EI
/// mirrors in [`crate::optimizer::mirror`] (same math, f64 precision).
pub struct BayesController {
    cfg: OptimizerConfig,
    /// Control-plane knobs (fault penalty, adaptive chunk scale);
    /// the fault-blind default unless [`BayesController::with_control`].
    control: ControlConfig,
    runtime: Option<SharedRuntime>,
    /// Bucketed observation memory: slot i covers one concurrency
    /// region; `None` = never observed.
    buckets: Vec<Option<Probe>>,
    /// Region width in concurrency units.
    bucket_width: f64,
    grid: Vec<f32>,
    c_target: usize,
    /// Seeding phase: first `seed_probes` moves are random draws
    /// (standard BO initialization — and the mechanism behind its
    /// instability under drifting conditions).
    seed_probes: usize,
    observed: usize,
    rng: Prng,
    /// Diagnostics: max expected improvement of the last step.
    pub last_ei_max: f64,
    /// Total artifact invocations (mirror steps do not count).
    pub steps_executed: u64,
}

impl BayesController {
    /// Artifact-backed controller over the given runtime.
    pub fn new(cfg: OptimizerConfig, runtime: SharedRuntime) -> BayesController {
        Self::build(cfg, Some(runtime))
    }

    /// Runtime-free controller running the pure-Rust GP/EI mirrors.
    pub fn new_mirror(cfg: OptimizerConfig) -> BayesController {
        Self::build(cfg, None)
    }

    /// Attach control-plane knobs (builder style; the default is the
    /// fault-blind [`ControlConfig::default`]).
    pub fn with_control(mut self, control: ControlConfig) -> BayesController {
        self.control = control;
        self
    }

    fn build(cfg: OptimizerConfig, runtime: Option<SharedRuntime>) -> BayesController {
        let (window, grid_len) = match &runtime {
            Some(rt) => {
                let c = rt.constants();
                (c.window, c.grid)
            }
            None => (
                crate::runtime::EXPECTED_WINDOW,
                crate::runtime::EXPECTED_GRID,
            ),
        };
        let grid: Vec<f32> = (1..=grid_len).map(|i| i as f32).collect();
        let span = (cfg.c_max - cfg.c_min + 1) as f64;
        let bucket_width = (span / window as f64).max(1.0);
        BayesController {
            c_target: cfg.c_init,
            buckets: vec![None; window],
            bucket_width,
            grid,
            seed_probes: 3,
            observed: 0,
            rng: Prng::new(0xBA7E5),
            cfg,
            control: ControlConfig::default(),
            runtime,
            last_ei_max: 0.0,
            steps_executed: 0,
        }
    }

    /// Reseed the exploration RNG (paired runs in experiments).
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Prng::new(seed);
    }

    fn bucket_of(&self, concurrency: f64) -> usize {
        let idx = ((concurrency - self.cfg.c_min as f64) / self.bucket_width).floor();
        (idx.max(0.0) as usize).min(self.buckets.len() - 1)
    }

    /// Pure-Rust replacement for the `bayes_step` artifact: utilities →
    /// GP posterior on the candidate grid → expected-improvement
    /// argmax. Returns the proposed next concurrency.
    ///
    /// `u_norm` is the same rescale the artifact receives in
    /// `params[6]` — the max observed throughput — so mirror and
    /// artifact fit the GP on identically scaled utilities (the xi
    /// term in EI is absolute; a different scale would move the
    /// argmax).
    fn mirror_step(
        &mut self,
        c_obs: &[f32],
        t_obs: &[f32],
        valid: &[f32],
        u_norm: f64,
        k: f64,
    ) -> f64 {
        use crate::optimizer::mirror;
        let c64: Vec<f64> = c_obs.iter().map(|&x| x as f64).collect();
        let v64: Vec<f64> = valid.iter().map(|&x| x as f64).collect();
        let scale = if u_norm > 0.0 { 1.0 / u_norm } else { 1.0 };
        let u64v: Vec<f64> = c64
            .iter()
            .zip(t_obs)
            .zip(&v64)
            .map(|((&c, &t), &v)| {
                if v > 0.5 {
                    mirror::utility(t as f64, c, k) * scale
                } else {
                    0.0
                }
            })
            .collect();
        let grid: Vec<f64> = self.grid.iter().map(|&g| g as f64).collect();
        let (mu, std) = mirror::gp_posterior_mirror(
            &c64,
            &u64v,
            &v64,
            &grid,
            self.cfg.bayes_lengthscale,
            self.cfg.bayes_noise,
        );
        let best = u64v
            .iter()
            .zip(&v64)
            .filter(|&(_, &v)| v > 0.5)
            .map(|(&u, _)| u)
            .fold(0.0f64, f64::max);
        let mut best_c = self.cfg.c_min as f64;
        let mut best_ei = f64::NEG_INFINITY;
        for (j, &g) in grid.iter().enumerate() {
            if g < self.cfg.c_min as f64 || g > self.cfg.c_max as f64 {
                continue;
            }
            let ei = mirror::expected_improvement_mirror(mu[j], std[j], best, self.cfg.bayes_xi);
            if ei > best_ei {
                best_ei = ei;
                best_c = g;
            }
        }
        self.last_ei_max = best_ei;
        best_c
    }

    /// Export the bucket memory in artifact shape.
    fn export(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>, f64) {
        let w = self.buckets.len();
        let mut c = vec![0.0f32; w];
        let mut t = vec![0.0f32; w];
        let mut v = vec![0.0f32; w];
        let mut max_t = 0.0f64;
        for (i, slot) in self.buckets.iter().enumerate() {
            if let Some(p) = slot {
                c[i] = p.concurrency as f32;
                t[i] = p.mbps as f32;
                v[i] = 1.0;
                max_t = max_t.max(p.mbps);
            }
        }
        (c, t, v, max_t)
    }
}

impl Controller for BayesController {
    fn on_signals(&mut self, signals: &ControlSignals) -> Result<ControlAction> {
        // Signal → utility mapping: fault-penalized goodput (identity
        // at the default weight 0) enters the observation memory the
        // GP surrogate is fitted on.
        let probe = Probe {
            concurrency: signals.concurrency,
            mbps: discounted_goodput(signals, self.control.fault_penalty),
        };
        let scale_out = chunk_scale(signals, &self.control);
        let b = self.bucket_of(probe.concurrency);
        self.buckets[b] = Some(probe);
        self.observed += 1;

        // Random seeding phase (standard GP-BO bootstrap).
        if self.observed <= self.seed_probes {
            let hi = (self.cfg.c_max as u64).min(16).max(self.cfg.c_min as u64);
            let c = self.rng.range_u64(self.cfg.c_min as u64, hi) as usize;
            self.c_target = c;
            return Ok(ControlAction {
                concurrency: c,
                chunk_scale: scale_out,
            });
        }

        let (c_obs, t_obs, valid, max_t) = self.export();
        let u_norm = if max_t > 0.0 { max_t } else { 1.0 };
        // Mirror-aware utility: more healthy mirrors flatten the
        // penalty (higher C*), failure pressure steepens it.
        let k = effective_k(self.cfg.k, signals.mirror);
        // Clone the Arc handle so the match holds no borrow of self.
        let runtime = self.runtime.clone();
        let next_c = match runtime {
            Some(rt) => {
                let params: [f32; 8] = [
                    k as f32,
                    self.cfg.bayes_lengthscale as f32,
                    self.cfg.bayes_noise as f32,
                    self.cfg.bayes_xi as f32,
                    self.cfg.c_min as f32,
                    self.cfg.c_max as f32,
                    u_norm as f32,
                    0.0,
                ];
                let out = rt.bayes_step(&c_obs, &t_obs, &valid, &self.grid, &params)?;
                self.steps_executed += 1;
                let g = self.grid.len();
                let ei = &out[2 * g..3 * g];
                self.last_ei_max =
                    ei.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
                out[3 * g + 1] as f64
            }
            None => self.mirror_step(&c_obs, &t_obs, &valid, u_norm, k),
        };
        self.c_target = next_c
            .round()
            .clamp(self.cfg.c_min as f64, self.cfg.c_max as f64) as usize;
        Ok(ControlAction {
            concurrency: self.c_target,
            chunk_scale: scale_out,
        })
    }

    fn current(&self) -> ControlAction {
        ControlAction {
            concurrency: self.c_target,
            chunk_scale: 1.0,
        }
    }

    fn name(&self) -> &'static str {
        "bayesian"
    }
}

#[cfg(test)]
mod tests {
    // Needs compiled artifacts — behavioural tests live in
    // `rust/tests/controller_integration.rs`. Bucket mapping is pure:

    #[test]
    fn bucket_mapping_covers_range() {
        // Can't build a full controller without the runtime; replicate
        // the mapping math directly.
        let c_min = 1.0f64;
        let width = 4.0f64;
        let n = 16usize;
        let bucket = |c: f64| {
            let idx = ((c - c_min) / width).floor();
            (idx.max(0.0) as usize).min(n - 1)
        };
        assert_eq!(bucket(1.0), 0);
        assert_eq!(bucket(4.9), 0);
        assert_eq!(bucket(5.0), 1);
        assert_eq!(bucket(64.0), 15);
        assert_eq!(bucket(1000.0), 15);
        assert_eq!(bucket(0.0), 0);
    }
}
