//! Adaptive concurrency controllers (paper §4).
//!
//! Controllers implement the control plane's
//! [`crate::control::Controller`] trait: once per probing interval they
//! consume a [`crate::control::ControlSignals`] snapshot — goodput,
//! retry/reject rates, mirror headroom/fail-pressure, connect-RTT —
//! and emit a [`crate::control::ControlAction`] (the next concurrency
//! target plus an adaptive chunk scale). Three implementations:
//!
//! * [`gradient::GdController`] — the paper's chosen controller:
//!   gradient descent on `-U(T, C) = -T/k^C`, executed through the
//!   `gd_step` XLA artifact (L2 graph + L1 Pallas kernels).
//! * [`bayesian::BayesController`] — the paper's in-system baseline:
//!   GP surrogate + expected improvement through the `bayes_step`
//!   artifact. Loses to GD by ≈20 % (Figure 4) because every surrogate
//!   miss costs a large concurrency jump and socket churn.
//! * [`fixed::FixedController`] — static concurrency (what prefetch /
//!   pysradb do), the baseline of Figures 5–6.
//!
//! [`history::ProbeHistory`] is the shared probe ring; [`mirror`] holds
//! pure-Rust re-implementations of the artifact math used only by
//! tests to cross-check the XLA path (including the fault-penalty
//! discount, [`mirror::fault_discount`]).
//!
//! The signal → utility mapping of the adaptive controllers has two
//! fault-aware ingredients, both neutral by default:
//!
//! * the snapshot's [`crate::control::MirrorHealth`] rescales the
//!   utility penalty through [`effective_k`], so the controller grows
//!   concurrency when a second healthy mirror opens headroom and backs
//!   off under sustained failures (single-mirror sessions carry the
//!   neutral signal — bit-identical behaviour);
//! * with [`crate::config::ControlConfig::fault_penalty`] `> 0`, the
//!   window goodput is discounted by the weighted retry/reject rate
//!   ([`crate::control::discounted_goodput`]) before entering the
//!   utility, so throughput bought with retries stops looking optimal.

pub mod bayesian;
pub mod fixed;
pub mod gradient;
pub mod history;
pub mod mirror;

pub use bayesian::BayesController;
pub use fixed::FixedController;
pub use gradient::GdController;
pub use history::ProbeHistory;

use crate::config::{ControlConfig, OptimizerConfig, OptimizerKind};
use crate::control::{Controller, MirrorHealth};
use crate::runtime::SharedRuntime;
use crate::Result;

/// One probe observation (the probe-history element of the adaptive
/// controllers; assembled from a [`crate::control::ControlSignals`]
/// snapshot after the fault-penalty discount).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Probe {
    /// Concurrency the probe ran at.
    pub concurrency: f64,
    /// Mean throughput over the probe window (Mbps).
    pub mbps: f64,
}

/// Mirror-aware utility penalty: rescale the coefficient `k` of
/// `U = T / k^C` by the fleet's health. This is an internal detail of
/// the controllers' signal → utility mapping; the engine only ships
/// the [`MirrorHealth`] snapshot.
///
/// A second healthy mirror opens concurrency headroom — per-connection
/// caps and staging queues are per-endpoint, so the marginal cost of a
/// connection drops roughly with the number of endpoints sharing the
/// load. Conversely, sustained failures make connections *more*
/// expensive (each one risks a retry storm). Both effects enter the
/// §4.1 utility as an exponent rescale:
///
/// `k_eff = 1 + (k − 1) · (1 + fail_pressure) / headroom`
///
/// clamped to `[1 + (k−1)/8, 1 + (k−1)·4]` so a noisy health signal
/// can never flatten the penalty entirely or dwarf the throughput
/// term. With the neutral [`MirrorHealth::default`] this is exactly
/// `k`, so single-mirror transfers are unchanged. Since
/// `C* = 1 / ln k_eff`, two equally healthy mirrors roughly double the
/// concurrency ceiling the gradient controller steers toward.
pub fn effective_k(k: f64, health: MirrorHealth) -> f64 {
    let headroom = health.headroom.max(1.0);
    let pressure = 1.0 + health.fail_pressure.max(0.0);
    let k_eff = 1.0 + (k - 1.0) * pressure / headroom;
    k_eff.clamp(1.0 + (k - 1.0) / 8.0, 1.0 + (k - 1.0) * 4.0)
}

/// Build the controller selected by `cfg.kind` with the fault-blind
/// default [`ControlConfig`] (fault penalty off, full-size chunks) —
/// the pre-control-plane behaviour, used by the paper experiments and
/// most tests. See [`build_controller_with`] for the fault-aware
/// variant.
pub fn build_controller(
    cfg: &OptimizerConfig,
    runtime: Option<SharedRuntime>,
) -> Result<Box<dyn Controller>> {
    build_controller_with(cfg, &ControlConfig::default(), runtime)
}

/// Build the controller selected by `cfg.kind` carrying the given
/// control-plane knobs.
///
/// With `runtime == Some(..)` the adaptive controllers execute the XLA
/// artifacts; with `None` they fall back to the pure-Rust mirrors of
/// the same math — identical control flow, f64 precision — so fault
/// matrices and artifact-less environments still exercise GD/Bayes.
/// `Fixed` ignores both the runtime and the `fault_penalty` knob (a
/// static baseline never moves its level); note that engine-side
/// adaptive chunk sizing is gated by the *engine's*
/// `DownloadConfig::control`, so it applies to any controller.
///
/// Pass the same [`ControlConfig`] the session's
/// `DownloadConfig::control` carries (every built-in driver does) —
/// a controller built with a different config would emit chunk scales
/// the engine's own `adaptive_chunks` gate does not expect.
pub fn build_controller_with(
    cfg: &OptimizerConfig,
    control: &ControlConfig,
    runtime: Option<SharedRuntime>,
) -> Result<Box<dyn Controller>> {
    cfg.validate()?;
    control.validate()?;
    match cfg.kind {
        OptimizerKind::GradientDescent => {
            let gd = match runtime {
                Some(rt) => GdController::new(cfg.clone(), rt),
                None => GdController::new_mirror(cfg.clone()),
            };
            Ok(Box::new(gd.with_control(control.clone())))
        }
        OptimizerKind::Bayesian => {
            let bo = match runtime {
                Some(rt) => BayesController::new(cfg.clone(), rt),
                None => BayesController::new_mirror(cfg.clone()),
            };
            Ok(Box::new(bo.with_control(control.clone())))
        }
        OptimizerKind::Fixed => Ok(Box::new(FixedController::new(cfg.fixed_level))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_k_is_identity_on_neutral_health() {
        for k in [1.01, 1.02, 1.05] {
            let k_eff = effective_k(k, MirrorHealth::default());
            assert!((k_eff - k).abs() < 1e-12, "k={k} -> {k_eff}");
        }
    }

    #[test]
    fn second_healthy_mirror_halves_the_penalty() {
        let h = MirrorHealth {
            headroom: 2.0,
            fail_pressure: 0.0,
        };
        let k_eff = effective_k(1.02, h);
        assert!((k_eff - 1.01).abs() < 1e-12);
        // C* = 1/ln(k_eff) roughly doubles.
        assert!(1.0 / k_eff.ln() > 1.9 / 1.02f64.ln());
    }

    #[test]
    fn failure_pressure_raises_the_penalty_within_clamps() {
        let hurt = MirrorHealth {
            headroom: 1.0,
            fail_pressure: 2.0,
        };
        let k_eff = effective_k(1.02, hurt);
        assert!(k_eff > 1.02);
        assert!(k_eff <= 1.0 + 0.02 * 4.0 + 1e-12);
        // Extreme inputs stay clamped.
        let extreme = MirrorHealth {
            headroom: 1000.0,
            fail_pressure: 0.0,
        };
        assert!(effective_k(1.02, extreme) >= 1.0 + 0.02 / 8.0 - 1e-12);
    }

    #[test]
    fn fixed_controller_ignores_control_knobs() {
        let cfg = OptimizerConfig {
            kind: OptimizerKind::Fixed,
            fixed_level: 5,
            ..Default::default()
        };
        let hot = ControlConfig {
            fault_penalty: 10.0,
            adaptive_chunks: true,
            chunk_scale_min: 0.25,
        };
        let c = build_controller_with(&cfg, &hot, None).unwrap();
        assert_eq!(c.current().concurrency, 5);
        assert_eq!(c.current().chunk_scale, 1.0);
    }
}
