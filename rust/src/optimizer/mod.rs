//! Adaptive concurrency controllers (paper §4).
//!
//! A [`ConcurrencyController`] consumes one probe observation per
//! probing interval — `(concurrency used, mean throughput measured)` —
//! and emits the next target concurrency. Three implementations:
//!
//! * [`gradient::GdController`] — the paper's chosen controller:
//!   gradient descent on `-U(T, C) = -T/k^C`, executed through the
//!   `gd_step` XLA artifact (L2 graph + L1 Pallas kernels).
//! * [`bayesian::BayesController`] — the paper's in-system baseline:
//!   GP surrogate + expected improvement through the `bayes_step`
//!   artifact. Loses to GD by ≈20 % (Figure 4) because every surrogate
//!   miss costs a large concurrency jump and socket churn.
//! * [`fixed::FixedController`] — static concurrency (what prefetch /
//!   pysradb do), the baseline of Figures 5–6.
//!
//! [`history::ProbeHistory`] is the shared probe ring; [`mirror`] holds
//! pure-Rust re-implementations of the artifact math used only by
//! tests to cross-check the XLA path.
//!
//! Multi-mirror sessions additionally feed the adaptive controllers an
//! aggregate [`MirrorHealth`] signal each probe; [`effective_k`]
//! rescales the §4.1 utility penalty so the controller grows
//! concurrency when a second healthy mirror opens headroom and backs
//! off under sustained failures.

pub mod bayesian;
pub mod fixed;
pub mod gradient;
pub mod history;
pub mod mirror;

pub use bayesian::BayesController;
pub use fixed::FixedController;
pub use gradient::GdController;
pub use history::ProbeHistory;

use crate::config::{OptimizerConfig, OptimizerKind};
use crate::runtime::SharedRuntime;
use crate::Result;

/// One probe observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Probe {
    /// Concurrency the probe ran at.
    pub concurrency: f64,
    /// Mean throughput over the probe window (Mbps).
    pub mbps: f64,
}

/// Aggregate mirror-health signal the session engine feeds the
/// adaptive controllers once per probe (multi-mirror transfers only;
/// single-mirror sessions never emit it, so their behaviour is
/// bit-identical to a health-unaware controller).
///
/// Derived from the per-session
/// [`crate::session::mirrors::MirrorBoard`]: `headroom` is the
/// effective number of simultaneously useful mirrors
/// ([`crate::session::mirrors::MirrorBoard::concurrency_headroom`]),
/// `fail_pressure` the decayed failure rate across the fleet
/// ([`crate::session::mirrors::MirrorBoard::fail_pressure`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MirrorHealth {
    /// Effective number of healthy mirrors, in `[1, mirror_count]`.
    pub headroom: f64,
    /// Decayed failure pressure across mirrors (0 = clean).
    pub fail_pressure: f64,
}

impl Default for MirrorHealth {
    /// Neutral signal: one mirror, no failures —
    /// [`effective_k`] returns `k` unchanged.
    fn default() -> Self {
        MirrorHealth {
            headroom: 1.0,
            fail_pressure: 0.0,
        }
    }
}

/// Mirror-aware utility penalty: rescale the coefficient `k` of
/// `U = T / k^C` by the fleet's health.
///
/// A second healthy mirror opens concurrency headroom — per-connection
/// caps and staging queues are per-endpoint, so the marginal cost of a
/// connection drops roughly with the number of endpoints sharing the
/// load. Conversely, sustained failures make connections *more*
/// expensive (each one risks a retry storm). Both effects enter the
/// §4.1 utility as an exponent rescale:
///
/// `k_eff = 1 + (k − 1) · (1 + fail_pressure) / headroom`
///
/// clamped to `[1 + (k−1)/8, 1 + (k−1)·4]` so a noisy health signal
/// can never flatten the penalty entirely or dwarf the throughput
/// term. With the neutral [`MirrorHealth::default`] this is exactly
/// `k`, so single-mirror transfers are unchanged. Since
/// `C* = 1 / ln k_eff`, two equally healthy mirrors roughly double the
/// concurrency ceiling the gradient controller steers toward.
pub fn effective_k(k: f64, health: MirrorHealth) -> f64 {
    let headroom = health.headroom.max(1.0);
    let pressure = 1.0 + health.fail_pressure.max(0.0);
    let k_eff = 1.0 + (k - 1.0) * pressure / headroom;
    k_eff.clamp(1.0 + (k - 1.0) / 8.0, 1.0 + (k - 1.0) * 4.0)
}

/// A concurrency controller: Algorithm 1's decision step.
///
/// Deliberately **not** `Send`: the PJRT client (and thus the XLA-backed
/// controllers) lives on the coordinating thread, exactly like the
/// paper's single optimizer thread. Worker threads never touch the
/// controller — they observe the [`crate::coordinator::StatusArray`]
/// it writes through the session driver.
pub trait ConcurrencyController {
    /// Consume one probe, return the next target concurrency.
    fn on_probe(&mut self, probe: Probe) -> Result<usize>;

    /// Current target without new information (initial value).
    fn current(&self) -> usize;

    /// Display name for logs/reports.
    fn name(&self) -> &'static str;

    /// Receive the aggregate mirror-health signal for the upcoming
    /// probe (multi-mirror sessions only). Adaptive controllers rescale
    /// their utility penalty through [`effective_k`]; the default
    /// implementation ignores it (static controllers, baselines).
    fn on_mirror_health(&mut self, _health: MirrorHealth) {}
}

/// Build the controller selected by `cfg.kind`.
///
/// With `runtime == Some(..)` the adaptive controllers execute the XLA
/// artifacts; with `None` they fall back to the pure-Rust mirrors of
/// the same math — identical control flow, f64 precision — so fault
/// matrices and artifact-less environments still exercise GD/Bayes.
/// `Fixed` ignores the runtime either way.
pub fn build_controller(
    cfg: &OptimizerConfig,
    runtime: Option<SharedRuntime>,
) -> Result<Box<dyn ConcurrencyController>> {
    cfg.validate()?;
    match cfg.kind {
        OptimizerKind::GradientDescent => Ok(Box::new(match runtime {
            Some(rt) => GdController::new(cfg.clone(), rt),
            None => GdController::new_mirror(cfg.clone()),
        })),
        OptimizerKind::Bayesian => Ok(Box::new(match runtime {
            Some(rt) => BayesController::new(cfg.clone(), rt),
            None => BayesController::new_mirror(cfg.clone()),
        })),
        OptimizerKind::Fixed => Ok(Box::new(FixedController::new(cfg.fixed_level))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_k_is_identity_on_neutral_health() {
        for k in [1.01, 1.02, 1.05] {
            let k_eff = effective_k(k, MirrorHealth::default());
            assert!((k_eff - k).abs() < 1e-12, "k={k} -> {k_eff}");
        }
    }

    #[test]
    fn second_healthy_mirror_halves_the_penalty() {
        let h = MirrorHealth {
            headroom: 2.0,
            fail_pressure: 0.0,
        };
        let k_eff = effective_k(1.02, h);
        assert!((k_eff - 1.01).abs() < 1e-12);
        // C* = 1/ln(k_eff) roughly doubles.
        assert!(1.0 / k_eff.ln() > 1.9 / 1.02f64.ln());
    }

    #[test]
    fn failure_pressure_raises_the_penalty_within_clamps() {
        let hurt = MirrorHealth {
            headroom: 1.0,
            fail_pressure: 2.0,
        };
        let k_eff = effective_k(1.02, hurt);
        assert!(k_eff > 1.02);
        assert!(k_eff <= 1.0 + 0.02 * 4.0 + 1e-12);
        // Extreme inputs stay clamped.
        let extreme = MirrorHealth {
            headroom: 1000.0,
            fail_pressure: 0.0,
        };
        assert!(effective_k(1.02, extreme) >= 1.0 + 0.02 / 8.0 - 1e-12);
    }
}
