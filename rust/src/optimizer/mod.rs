//! Adaptive concurrency controllers (paper §4).
//!
//! A [`ConcurrencyController`] consumes one probe observation per
//! probing interval — `(concurrency used, mean throughput measured)` —
//! and emits the next target concurrency. Three implementations:
//!
//! * [`gradient::GdController`] — the paper's chosen controller:
//!   gradient descent on `-U(T, C) = -T/k^C`, executed through the
//!   `gd_step` XLA artifact (L2 graph + L1 Pallas kernels).
//! * [`bayesian::BayesController`] — the paper's in-system baseline:
//!   GP surrogate + expected improvement through the `bayes_step`
//!   artifact. Loses to GD by ≈20 % (Figure 4) because every surrogate
//!   miss costs a large concurrency jump and socket churn.
//! * [`fixed::FixedController`] — static concurrency (what prefetch /
//!   pysradb do), the baseline of Figures 5–6.
//!
//! [`history::ProbeHistory`] is the shared probe ring; [`mirror`] holds
//! pure-Rust re-implementations of the artifact math used only by
//! tests to cross-check the XLA path.

pub mod bayesian;
pub mod fixed;
pub mod gradient;
pub mod history;
pub mod mirror;

pub use bayesian::BayesController;
pub use fixed::FixedController;
pub use gradient::GdController;
pub use history::ProbeHistory;

use crate::config::{OptimizerConfig, OptimizerKind};
use crate::runtime::SharedRuntime;
use crate::Result;

/// One probe observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Probe {
    /// Concurrency the probe ran at.
    pub concurrency: f64,
    /// Mean throughput over the probe window (Mbps).
    pub mbps: f64,
}

/// A concurrency controller: Algorithm 1's decision step.
///
/// Deliberately **not** `Send`: the PJRT client (and thus the XLA-backed
/// controllers) lives on the coordinating thread, exactly like the
/// paper's single optimizer thread. Worker threads never touch the
/// controller — they observe the [`crate::coordinator::StatusArray`]
/// it writes through the session driver.
pub trait ConcurrencyController {
    /// Consume one probe, return the next target concurrency.
    fn on_probe(&mut self, probe: Probe) -> Result<usize>;

    /// Current target without new information (initial value).
    fn current(&self) -> usize;

    /// Display name for logs/reports.
    fn name(&self) -> &'static str;
}

/// Build the controller selected by `cfg.kind`.
///
/// With `runtime == Some(..)` the adaptive controllers execute the XLA
/// artifacts; with `None` they fall back to the pure-Rust mirrors of
/// the same math — identical control flow, f64 precision — so fault
/// matrices and artifact-less environments still exercise GD/Bayes.
/// `Fixed` ignores the runtime either way.
pub fn build_controller(
    cfg: &OptimizerConfig,
    runtime: Option<SharedRuntime>,
) -> Result<Box<dyn ConcurrencyController>> {
    cfg.validate()?;
    match cfg.kind {
        OptimizerKind::GradientDescent => Ok(Box::new(match runtime {
            Some(rt) => GdController::new(cfg.clone(), rt),
            None => GdController::new_mirror(cfg.clone()),
        })),
        OptimizerKind::Bayesian => Ok(Box::new(match runtime {
            Some(rt) => BayesController::new(cfg.clone(), rt),
            None => BayesController::new_mirror(cfg.clone()),
        })),
        OptimizerKind::Fixed => Ok(Box::new(FixedController::new(cfg.fixed_level))),
    }
}
