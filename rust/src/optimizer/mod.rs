//! Adaptive concurrency controllers (paper §4).
//!
//! A [`ConcurrencyController`] consumes one probe observation per
//! probing interval — `(concurrency used, mean throughput measured)` —
//! and emits the next target concurrency. Three implementations:
//!
//! * [`gradient::GdController`] — the paper's chosen controller:
//!   gradient descent on `-U(T, C) = -T/k^C`, executed through the
//!   `gd_step` XLA artifact (L2 graph + L1 Pallas kernels).
//! * [`bayesian::BayesController`] — the paper's in-system baseline:
//!   GP surrogate + expected improvement through the `bayes_step`
//!   artifact. Loses to GD by ≈20 % (Figure 4) because every surrogate
//!   miss costs a large concurrency jump and socket churn.
//! * [`fixed::FixedController`] — static concurrency (what prefetch /
//!   pysradb do), the baseline of Figures 5–6.
//!
//! [`history::ProbeHistory`] is the shared probe ring; [`mirror`] holds
//! pure-Rust re-implementations of the artifact math used only by
//! tests to cross-check the XLA path.

pub mod bayesian;
pub mod fixed;
pub mod gradient;
pub mod history;
pub mod mirror;

pub use bayesian::BayesController;
pub use fixed::FixedController;
pub use gradient::GdController;
pub use history::ProbeHistory;

use crate::config::{OptimizerConfig, OptimizerKind};
use crate::runtime::SharedRuntime;
use crate::Result;

/// One probe observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Probe {
    /// Concurrency the probe ran at.
    pub concurrency: f64,
    /// Mean throughput over the probe window (Mbps).
    pub mbps: f64,
}

/// A concurrency controller: Algorithm 1's decision step.
///
/// Deliberately **not** `Send`: the PJRT client (and thus the XLA-backed
/// controllers) lives on the coordinating thread, exactly like the
/// paper's single optimizer thread. Worker threads never touch the
/// controller — they observe the [`crate::coordinator::StatusArray`]
/// it writes through the session driver.
pub trait ConcurrencyController {
    /// Consume one probe, return the next target concurrency.
    fn on_probe(&mut self, probe: Probe) -> Result<usize>;

    /// Current target without new information (initial value).
    fn current(&self) -> usize;

    /// Display name for logs/reports.
    fn name(&self) -> &'static str;
}

/// Build the controller selected by `cfg.kind`.
///
/// `runtime` is required for the adaptive controllers (they execute
/// XLA artifacts); `Fixed` ignores it.
pub fn build_controller(
    cfg: &OptimizerConfig,
    runtime: Option<SharedRuntime>,
) -> Result<Box<dyn ConcurrencyController>> {
    cfg.validate()?;
    match cfg.kind {
        OptimizerKind::GradientDescent => {
            let rt = runtime.ok_or_else(|| {
                crate::Error::Config("gradient-descent controller needs the XLA runtime".into())
            })?;
            Ok(Box::new(GdController::new(cfg.clone(), rt)))
        }
        OptimizerKind::Bayesian => {
            let rt = runtime.ok_or_else(|| {
                crate::Error::Config("bayesian controller needs the XLA runtime".into())
            })?;
            Ok(Box::new(BayesController::new(cfg.clone(), rt)))
        }
        OptimizerKind::Fixed => Ok(Box::new(FixedController::new(cfg.fixed_level))),
    }
}
