//! Fixed-concurrency controller — the static baseline.
//!
//! Models what `prefetch` (3 threads), `pysradb` (8 threads) and the
//! fixed-3 / fixed-5 arms of Figure 6 do: pick a level once, never
//! move. Exists so the baselines and the adaptive system run through
//! the *identical* session machinery and differ only in this policy.

use crate::optimizer::{ConcurrencyController, Probe};
use crate::Result;

/// Static concurrency.
#[derive(Clone, Debug)]
pub struct FixedController {
    level: usize,
}

impl FixedController {
    /// Controller pinned at `level >= 1` workers.
    pub fn new(level: usize) -> FixedController {
        assert!(level >= 1, "fixed level must be >= 1");
        FixedController { level }
    }
}

impl ConcurrencyController for FixedController {
    fn on_probe(&mut self, _probe: Probe) -> Result<usize> {
        Ok(self.level)
    }

    fn current(&self) -> usize {
        self.level
    }

    fn name(&self) -> &'static str {
        "fixed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_moves() {
        let mut c = FixedController::new(5);
        assert_eq!(c.current(), 5);
        for t in [0.0, 100.0, 10_000.0] {
            let next = c
                .on_probe(Probe {
                    concurrency: 5.0,
                    mbps: t,
                })
                .unwrap();
            assert_eq!(next, 5);
        }
    }

    #[test]
    #[should_panic(expected = "fixed level must be >= 1")]
    fn rejects_zero() {
        FixedController::new(0);
    }
}
