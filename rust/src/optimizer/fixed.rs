//! Fixed-concurrency controller — the static baseline.
//!
//! Models what `prefetch` (3 threads), `pysradb` (8 threads) and the
//! fixed-3 / fixed-5 arms of Figure 6 do: pick a level once, never
//! move. Exists so the baselines and the adaptive system run through
//! the *identical* session machinery and differ only in this policy.

use crate::control::{ControlAction, ControlSignals, Controller};
use crate::Result;

/// Static concurrency.
#[derive(Clone, Debug)]
pub struct FixedController {
    level: usize,
}

impl FixedController {
    /// Controller pinned at `level >= 1` workers.
    pub fn new(level: usize) -> FixedController {
        assert!(level >= 1, "fixed level must be >= 1");
        FixedController { level }
    }
}

impl Controller for FixedController {
    fn on_signals(&mut self, _signals: &ControlSignals) -> Result<ControlAction> {
        // A static baseline ignores every signal — level and chunk
        // size never move, whatever the network does.
        Ok(ControlAction::concurrency_only(self.level))
    }

    fn current(&self) -> ControlAction {
        ControlAction::concurrency_only(self.level)
    }

    fn name(&self) -> &'static str {
        "fixed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_moves() {
        let mut c = FixedController::new(5);
        assert_eq!(c.current().concurrency, 5);
        for t in [0.0, 100.0, 10_000.0] {
            let action = c.on_signals(&ControlSignals::probe(5.0, t)).unwrap();
            assert_eq!(action, ControlAction::concurrency_only(5));
        }
    }

    #[test]
    #[should_panic(expected = "fixed level must be >= 1")]
    fn rejects_zero() {
        FixedController::new(0);
    }
}
