//! Probe-history ring buffer feeding the controller artifacts.
//!
//! Keeps the last `WINDOW` probes `(concurrency, mbps)` and produces
//! the padded, masked, recency-weighted arrays the fixed-shape XLA
//! artifacts expect (oldest first, zeros beyond `len`).
//!
//! Probes are derived from the control plane's per-interval
//! [`crate::control::ControlSignals`] snapshot: the adaptive
//! controllers push `(signals.concurrency, discounted goodput)`, where
//! the discount is the fault-penalty term
//! ([`crate::control::discounted_goodput`] — identity at the default
//! weight 0, so a fault-blind history is bit-identical to the
//! pre-control-plane one).

use crate::optimizer::Probe;

/// Ring of recent probes with artifact-shaped exports.
#[derive(Clone, Debug)]
pub struct ProbeHistory {
    window: usize,
    probes: Vec<Probe>,
    half_life: f64,
}

impl ProbeHistory {
    /// `window` must equal the artifact WINDOW constant (16);
    /// `half_life` is the recency decay in probes.
    pub fn new(window: usize, half_life: f64) -> ProbeHistory {
        assert!(window > 0 && half_life > 0.0);
        ProbeHistory {
            window,
            probes: Vec::with_capacity(window),
            half_life,
        }
    }

    /// Append a probe, evicting the oldest beyond the window.
    pub fn push(&mut self, probe: Probe) {
        if self.probes.len() == self.window {
            self.probes.remove(0);
        }
        self.probes.push(probe);
    }

    /// Probes currently held (≤ window).
    pub fn len(&self) -> usize {
        self.probes.len()
    }

    /// No probes recorded yet.
    pub fn is_empty(&self) -> bool {
        self.probes.is_empty()
    }

    /// Most recent probe.
    pub fn last(&self) -> Option<Probe> {
        self.probes.last().copied()
    }

    /// Number of *distinct* concurrency levels in the window — the GD
    /// gradient is only identified when this is ≥ 2.
    pub fn distinct_concurrency(&self) -> usize {
        let mut cs: Vec<i64> = self
            .probes
            .iter()
            .map(|p| (p.concurrency * 1000.0).round() as i64)
            .collect();
        cs.sort_unstable();
        cs.dedup();
        cs.len()
    }

    /// Export `(c_hist, t_hist, weights)` padded to the window size,
    /// oldest-first, with validity×recency weights (newest = 1).
    pub fn export(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let n = self.probes.len();
        let mut c = vec![0.0f32; self.window];
        let mut t = vec![0.0f32; self.window];
        let mut w = vec![0.0f32; self.window];
        for (i, p) in self.probes.iter().enumerate() {
            c[i] = p.concurrency as f32;
            t[i] = p.mbps as f32;
            let age = (n - 1 - i) as f64;
            w[i] = 2f64.powf(-age / self.half_life) as f32;
        }
        (c, t, w)
    }

    /// Export `(c_obs, t_obs, valid)` for the Bayesian artifact
    /// (uniform validity mask instead of recency weights — the GP's
    /// noise term handles staleness).
    pub fn export_masked(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let n = self.probes.len();
        let mut c = vec![0.0f32; self.window];
        let mut t = vec![0.0f32; self.window];
        let mut v = vec![0.0f32; self.window];
        for (i, p) in self.probes.iter().enumerate() {
            c[i] = p.concurrency as f32;
            t[i] = p.mbps as f32;
            v[i] = 1.0;
        }
        let _ = n;
        (c, t, v)
    }

    /// Max observed throughput (the Bayesian u-normalizer).
    pub fn max_mbps(&self) -> f64 {
        self.probes.iter().map(|p| p.mbps).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(c: f64, t: f64) -> Probe {
        Probe {
            concurrency: c,
            mbps: t,
        }
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut h = ProbeHistory::new(3, 2.0);
        for i in 0..5 {
            h.push(probe(i as f64, 100.0 * i as f64));
        }
        assert_eq!(h.len(), 3);
        let (c, _, _) = h.export();
        assert_eq!(&c[..3], &[2.0, 3.0, 4.0]);
    }

    #[test]
    fn export_pads_and_weights() {
        let mut h = ProbeHistory::new(4, 1.0);
        h.push(probe(1.0, 100.0));
        h.push(probe(2.0, 200.0));
        let (c, t, w) = h.export();
        assert_eq!(c, vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(t, vec![100.0, 200.0, 0.0, 0.0]);
        // Newest weight 1, previous halved (half_life 1), padding 0.
        assert!((w[1] - 1.0).abs() < 1e-6);
        assert!((w[0] - 0.5).abs() < 1e-6);
        assert_eq!(w[2], 0.0);
    }

    #[test]
    fn distinct_concurrency_counts() {
        let mut h = ProbeHistory::new(8, 2.0);
        h.push(probe(1.0, 10.0));
        h.push(probe(1.0, 12.0));
        assert_eq!(h.distinct_concurrency(), 1);
        h.push(probe(2.0, 20.0));
        assert_eq!(h.distinct_concurrency(), 2);
    }

    #[test]
    fn masked_export_uniform_validity() {
        let mut h = ProbeHistory::new(4, 2.0);
        h.push(probe(3.0, 300.0));
        let (_, _, v) = h.export_masked();
        assert_eq!(v, vec![1.0, 0.0, 0.0, 0.0]);
        assert_eq!(h.max_mbps(), 300.0);
    }
}
